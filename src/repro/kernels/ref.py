"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests).

These are intentionally straightforward: no tiling, no padding tricks — the
kernels must match them bit-for-bit-ish (fp tolerance) across shape/dtype
sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantizers as qz


def quant_matmul_ref(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                     bits: int, c_in: int, out_dtype=jnp.float32
                     ) -> jnp.ndarray:
    """x (..., c_in) @ dequant(packed (n, c_in_pad/f), scale (n,)).T.

    Matches serving.dq_linear's jnp path for one precision group.
    """
    w_int = qz.unpack_int(packed, bits)[..., :c_in]          # (n, c_in) int8
    w = w_int.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
    y = jnp.einsum("...i,oi->...o", x.astype(jnp.float32), w)
    return y.astype(out_dtype)


def fused_mix_ref(w: jnp.ndarray, gamma_hat: jnp.ndarray, alpha: jnp.ndarray,
                  bitwidths=(2, 4, 8)) -> jnp.ndarray:
    """Eq. (5) effective weight: sum_p gamma_hat[:, p] * FQ(w, alpha, p).

    w (n, k) float32; gamma_hat (n, |P|) softmax'd; alpha (n,) clips.
    """
    out = jnp.zeros_like(w, dtype=jnp.float32)
    a = alpha[:, None]
    for i, b in enumerate(bitwidths):
        out = out + gamma_hat[:, i:i + 1] * qz.quantize_weight(
            w.astype(jnp.float32), a, b)
    return out
