"""Pallas decode-attention kernel over the packed channel-wise KV cache.

The cache analog of the fused expert GEMM's ``dequant_first`` contract
(kernels/quant_matmul.py): the K/V rings are stored as packed sub-byte bytes
(models/kv_quant.py — contiguous channel groups at 2/4/8 bits, one scale per
token per group) and this kernel unpacks + scales each tile **in VMEM**
right before the dot, so HBM cache traffic stays the packed bytes.  One
``pallas_call`` serves the whole one-token GQA decode attention:

    grid (B, KV): block ``(b, g)`` loads query rows ``q[b, g*rep:(g+1)*rep]``
    (the GQA head group sharing kv-head ``g`` — no materialized
    ``jnp.repeat``), the packed K/V rings ``(S, packed_bytes)`` and scales
    ``(S, n_groups)`` of that kv head, dequantizes in VMEM, and computes
    masked softmax attention over positions ``<= pos[b]``.

The arithmetic mirrors ``models/attention.gqa_decode``'s jnp reference op
for op (bf16 score dot -> f32 mask/softmax -> bf16 value dot), so the fused
path produces the same tokens as the jnp packed path and — at 8-bit — as
the legacy int8 engine (the bit-parity harness in tests/test_kv_quant.py).

Callers pass GATHERED per-slot ring views: the paged engine's page gather
(cache/paged.gather_pages) is a pure index copy of packed bytes, so pages
stream packed end to end and the kernel is oblivious to the page table —
the same composition contract as PR 6's dense-ring equivalence.

Static parameters are plain ``(bits, sizes)`` tuples rather than the
KVQuantSpec object so the kernels layer stays import-independent of
``repro.models``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# interpret=True executes the kernel body in Python on CPU (validation);
# mirrors kernels/ops.INTERPRET for the matmul family.
INTERPRET = True


def _unpack_group(packed: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(S, nb) uint8 -> (S, nb * 8/bits) int8, sign-extended.

    Same byte layout contract as ``core.quantizers.unpack_int`` and
    ``quant_matmul._unpack_block``: value ``j`` of byte ``b`` at bit
    ``j * bits``, interleaved back via stack+reshape.
    """
    if bits == 8:
        return jax.lax.bitcast_convert_type(packed, jnp.int8)
    f = 8 // bits
    mask = (1 << bits) - 1
    sign = 1 << (bits - 1)
    parts = []
    for i in range(f):
        u = (packed >> (i * bits)) & mask                    # uint8 lanes
        s = u.astype(jnp.int32)
        s = jnp.where(s >= sign, s - (1 << bits), s)
        parts.append(s.astype(jnp.int8))
    stacked = jnp.stack(parts, axis=-1)                      # (S, nb, f)
    return stacked.reshape(packed.shape[0], packed.shape[1] * f)


def _dequant_tile(packed, scales, bits, sizes, dtype):
    """In-VMEM dequant of one ring tile: ``(S, packed_bytes)`` -> ``(S, feat)``.

    Elementwise-identical to ``models.kv_quant.dequant_channelwise`` (unpack
    -> f32 -> per-group scale -> cast), so the fused and jnp paths agree.
    """
    outs, lo = [], 0
    for g, (b, n) in enumerate(zip(bits, sizes)):
        nb = n * b // 8
        q = _unpack_group(packed[:, lo:lo + nb], b)
        lo += nb
        outs.append((q.astype(jnp.float32)
                     * scales[:, g:g + 1]).astype(dtype))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)


def _kernel(q_ref, kp_ref, ks_ref, vp_ref, vs_ref, pos_ref, o_ref, *,
            bits, sizes, compute_dtype):
    q = q_ref[0, 0]                          # (rep, hd) compute_dtype
    kf = _dequant_tile(kp_ref[0, 0], ks_ref[0, 0], bits, sizes,
                       compute_dtype)        # (S, hd)
    vf = _dequant_tile(vp_ref[0, 0], vs_ref[0, 0], bits, sizes,
                       compute_dtype)
    S, hd = kf.shape
    # same promotion semantics as the reference einsum: result_type(q, kf)
    # first (bf16 q -> rounded bf16 scores, f32 q -> f32), THEN the f32 cast
    s = jnp.dot(q, kf.T).astype(jnp.float32) / math.sqrt(hd)   # (rep, S)
    valid = jnp.arange(S)[None, :] <= pos_ref[0]
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(compute_dtype)
    o_ref[0, 0] = jnp.dot(w, vf)


@functools.partial(jax.jit, static_argnames=("bits", "sizes", "out_dtype",
                                             "interpret"))
def decode_attention(q: jnp.ndarray, k_packed: jnp.ndarray,
                     k_scales: jnp.ndarray, v_packed: jnp.ndarray,
                     v_scales: jnp.ndarray, pos: jnp.ndarray,
                     bits: tuple, sizes: tuple, out_dtype=jnp.bfloat16,
                     interpret: bool = INTERPRET) -> jnp.ndarray:
    """Fused packed-cache GQA decode attention.

    ``q (B, KV, rep, hd)`` query head groups in their NATIVE dtype (f32
    after RoPE — the score dot then promotes exactly like the reference
    einsum, which is what keeps the fused path token-identical to jnp);
    ``k_packed``/``v_packed (B, KV, S, packed_bytes)`` uint8 ring views;
    ``k_scales``/``v_scales (B, KV, S, n_groups)`` f32; ``pos (B,)`` int32
    per-slot positions (attend to ``<= pos[b]``).  Returns
    ``(B, KV, rep, hd)`` in ``out_dtype``.
    """
    B, KV, rep, hd = q.shape
    S, NB = k_packed.shape[2], k_packed.shape[3]
    G = k_scales.shape[3]
    assert sum(sizes) == hd and sum(n * b // 8 for b, n in
                                    zip(bits, sizes)) == NB, (bits, sizes,
                                                              hd, NB)
    ring = lambda nf: pl.BlockSpec((1, 1, S, nf), lambda b, g: (b, g, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, sizes=sizes,
                          compute_dtype=out_dtype),
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, g: (b, g, 0, 0)),
            ring(NB), ring(G), ring(NB), ring(G),
            pl.BlockSpec((1,), lambda b, g: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, g: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, hd), out_dtype),
        interpret=interpret,
    )(q, k_packed, k_scales, v_packed, v_scales, pos)
