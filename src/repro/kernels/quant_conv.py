"""im2col patch extraction for the packed quantized conv path.

The deployed conv of a searched layer (Sec. III-C) never materializes a
dense float kernel: the NHWC input is lowered to im2col patches whose
feature axis matches the ``QTensor`` contraction layout, and the patch-GEMM
runs through the Pallas quant_matmul kernels (kernels/quant_matmul.py) —
with the tile-aligned fused layout ALL precision groups of the conv run in
one single ``pallas_call`` over the shared patches; the per-group path
(one launch per group, the paper's literal "parallel sub-convolutions")
remains as the ``backend="pallas-pergroup"`` reference.

Layout contract (load-bearing, asserted by tests/test_kernels.py):
``lax.conv_general_dilated_patches`` with NHWC dimension numbers emits the
patch feature axis **channel-major** — feature ``c * kh * kw + i * kw + j``
is input channel ``c`` at kernel tap ``(i, j)`` — which is exactly how a
``(c_out, c_in, kh, kw)`` weight flattens to the ``(c_out, c_in * kh * kw)``
contraction matrix a ``QTensor`` packs.  Patches therefore multiply packed
groups directly, with no re-ordering in between.

Depthwise convolutions (DS-CNN / MobileNetV1 ``dwconv``) contract only over
the ``kh * kw`` taps of each channel — not a single GEMM — so they take the
grouped-patch fall-back: :func:`depthwise_patches` exposes the per-channel
patch view and the per-precision-group contraction happens in
``QTensor.conv2d`` (still packed in HBM; only the tiny ``(rows, kh*kw)``
group slices unpack, same as the jnp matmul fall-back).
"""
from __future__ import annotations

from typing import Sequence, Union

import jax.numpy as jnp
from jax import lax


def _norm_stride(stride: Union[int, Sequence[int]]) -> tuple:
    return (stride, stride) if isinstance(stride, int) else tuple(stride)


def im2col(x: jnp.ndarray, kh: int, kw: int, stride=1,
           padding: str = "SAME") -> jnp.ndarray:
    """NHWC ``x (N, H, W, C)`` -> patches ``(N, Ho, Wo, C * kh * kw)``.

    Feature axis is channel-major (see module docstring), so
    ``patches @ w.reshape(c_out, -1).T`` equals the dense conv.
    """
    return lax.conv_general_dilated_patches(
        x, (kh, kw), _norm_stride(stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def depthwise_patches(x: jnp.ndarray, kh: int, kw: int, stride=1,
                      padding: str = "SAME") -> jnp.ndarray:
    """NHWC ``x (N, H, W, C)`` -> ``(N, Ho, Wo, C, kh * kw)``.

    The per-channel patch view of a depthwise conv: output channel ``c``
    contracts its own ``kh * kw`` taps only.  The reshape is free because
    the im2col feature axis is channel-major.
    """
    p = im2col(x, kh, kw, stride, padding)
    return p.reshape(*p.shape[:-1], x.shape[-1], kh * kw)
