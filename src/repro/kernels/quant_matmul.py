"""Pallas TPU kernel: packed sub-byte weight matmul with in-VMEM dequant.

The deployment hot-spot of the paper's technique on TPU (DESIGN.md §6): one
per-precision channel group of a deployed linear is

    y[m, n] = scale[n] * sum_k x[m, k] * w_int[n, k]

with ``w_int`` stored *packed* (4x int2 / 2x int4 / 1x int8 per uint8 byte)
in HBM.  The kernel streams packed bytes HBM->VMEM (the point: weight
bandwidth scales with the searched bit-width), unpacks + sign-extends in
VMEM registers, runs the MXU dot at bf16/f32, and applies the per-channel
scale once at the end of the K loop.

Tiling: grid (M/bm, N/bn, K/bk); x block (bm, bk), packed block
(bn, bk/pack_factor), output block (bm, bn) accumulated across the K grid
axis (output revisiting — the standard Pallas matmul reduction pattern).
Block defaults bm=bn=128, bk=512 keep the working set
(128*512*2 + 128*512 + 128*128*4)B ≈ 0.4 MB well under the ~16 MB VMEM
budget while keeping the MXU dimensions 128-aligned.

Validated in interpret mode on CPU against ref.quant_matmul_ref across a
shape/dtype/bits sweep (tests/test_kernels.py); ``interpret=False`` is the
real-TPU path.

Fused multi-precision launch (``quant_matmul_fused_2d``)
--------------------------------------------------------
The deployed realization of the paper's parallel per-precision
sub-convolutions used to be literal: one ``pallas_call`` per precision
group, a concat, and an order-restore gather on every forward.  For the
edge-class GEMMs this repo serves, that dispatch-and-stitch tax dominates.
The fused kernel runs **all** precision groups of a deployed weight in a
single launch:

* deploy-time packing is *tile-aligned* — every precision group's channel
  count is padded up to the ``tile_n`` output tile, so each ``tile_n``-wide
  output tile has exactly one static bit-width;
* the per-group packed buffers concatenate into one ragged-packed 1-D HBM
  byte buffer (a ``tile_n x Kp*b/8``-byte segment per tile, tight — low-bit
  tiles really occupy fewer bytes);
* one grid ``(M/bm, T)`` walks all output tiles; the per-tile bit-width and
  byte offset come from a **static schedule** (``tile_bits``), unrolled as
  ``pl.when`` branches, so each tile streams exactly its own bytes and
  unpacks at its own width — no per-group launches, no concat;
* the tile walk order is chosen at deploy time (api/qtensor.py): when the
  canonical-order restore is tile-granular the schedule itself visits tiles
  in canonical output order and the restore folds into the (identity)
  output BlockSpec index map — the old ``_concat_restore`` gather
  disappears from the hot path entirely.

K is not gridded: edge GEMMs have small contractions, so each tile does one
MXU dot over the whole (padded) ``Kp <= K_SINGLE_STEP_MAX``.  This is also
what makes the fused path bit-exact with the per-group path at
``compute_dtype=f32`` — both reduce K in a single dot of identical length.

Batched / expert axis (``quant_matmul_fused_3d``)
-------------------------------------------------
MoE expert stacks carry a leading ``E`` axis on every buffer (the
``init_deployed_linear(expert_axis=E)`` layout: one static tile schedule
shared by all experts, per-expert packed bytes and scales).  The fused
kernel extends with one more grid dimension — ``grid (E, M/bm, T)`` — so a
whole ``einsum("ecd,efd->ecf")``-shaped grouped expert GEMM is still ONE
``pallas_call``; the per-tile static bit schedule is unchanged, each grid
step just streams expert ``e``'s ragged byte segment.  The 2-D entry point
is the ``E == 1`` slice of the same kernel body.

Two in-kernel scale placements (static ``dequant_first`` flag):

* ``False`` (the 2-D / single-weight path): the per-channel step multiplies
  the f32 *accumulator* after the dot — bit-exact with the per-group
  ``_kernel`` (PR 3's contract).
* ``True`` (the expert-batched path): the step multiplies the unpacked
  integer tile *before* the dot (in-VMEM dequant; HBM traffic is still the
  packed bytes).  The products then match a dense reference
  ``einsum("ecd,efd->ecf", x, w_int * scale)`` element for element, so at
  f32 compute the fused expert GEMM is **bit-exact with the dense einsum
  it replaces** (`models/serving._deployed_moe`'s old
  ``dq_expert_weights`` path) — the PR 4 acceptance contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import quantizers as qz

# Contractions up to this many (padded) columns run as ONE K step — a single
# MXU dot — in both the per-group and the fused kernel.  Keeping the two
# paths on the same K schedule is what makes them bit-exact at f32 compute
# (f32 addition is not associative; identical reduction shape => identical
# rounding).  Larger K falls back to the chunked-accumulation grid.
K_SINGLE_STEP_MAX = 2048

# Byte granularity every fused buffer pads K to: the largest pack factor
# (int2 -> 4 values/byte), so one common Kp serves all bit-widths.
FUSED_K_ALIGN = 4


def _unpack_block(packed: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(bn, bkp) uint8 -> (bn, bkp * 8/bits) int8, sign-extended."""
    if bits == 8:
        return packed.astype(jnp.int8)
    f = 8 // bits
    mask = (1 << bits) - 1
    sign = 1 << (bits - 1)
    parts = []
    for i in range(f):
        u = (packed >> (i * bits)) & mask                   # uint8 lanes
        s = u.astype(jnp.int32)
        s = jnp.where(s >= sign, s - (1 << bits), s)
        parts.append(s.astype(jnp.int8))
    # interleave: value j of byte b sits at column b*f + j
    stacked = jnp.stack(parts, axis=-1)                     # (bn, bkp, f)
    return stacked.reshape(packed.shape[0], packed.shape[1] * f)


def _kernel(x_ref, p_ref, s_ref, o_ref, *, bits: int, k_steps: int,
            out_dtype, compute_dtype):
    k = pl.program_id(2)
    w_int = _unpack_block(p_ref[...], bits)                 # (bn, bk) int8
    x = x_ref[...]                                          # (bm, bk)
    acc = jnp.dot(x.astype(compute_dtype), w_int.astype(compute_dtype).T,
                  preferred_element_type=jnp.float32)       # (bm, bn)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += acc

    @pl.when(k == k_steps - 1)
    def _scale():
        o_ref[...] *= s_ref[...][None, :].astype(jnp.float32)


def pick_bk(Kp: int, f: int, bk: int = 512) -> int:
    """K tile size shared by the per-group and fused paths.

    Single step (``bk = Kp``) whenever the padded contraction fits
    ``K_SINGLE_STEP_MAX`` — the edge-GEMM case and the bit-exactness
    contract with the fused kernel; otherwise the largest power-of-two
    divisor of ``Kp`` not above ``bk`` (falling back to one step when no
    pack-compatible divisor exists).
    """
    if Kp <= K_SINGLE_STEP_MAX:
        return Kp
    bk_ = bk
    while Kp % bk_ or (bk_ % f):
        bk_ //= 2
        if bk_ < f:
            return Kp
    return bk_


def fused_tile_bytes(bits: int, Kp: int, tile_n: int) -> int:
    """Byte footprint of ONE output tile in the ragged fused buffer."""
    return tile_n * (Kp // qz.pack_factor(bits))


def fused_tile_offsets(tile_bits, Kp: int, tile_n: int) -> tuple:
    """Static per-tile byte offsets into the fused buffer (walk order)."""
    offs, off = [], 0
    for b in tile_bits:
        offs.append(off)
        off += fused_tile_bytes(b, Kp, tile_n)
    return tuple(offs)


def tp_chunk(tile_bits, parts: int):
    """Per-shard tile schedule for ``parts``-way tensor parallelism.

    shard_map traces ONE program for every shard, so the fused buffer can
    only split across devices when the schedule is periodic with period
    T/parts — each device then owns the same sequence of whole static-bit
    tiles (and therefore the same byte count).  Returns that per-shard
    schedule, or None when the schedule does not divide (caller replicates).
    """
    if not tile_bits or parts <= 1:
        return None
    T = len(tile_bits)
    if T % parts:
        return None
    chunk = tuple(tile_bits[:T // parts])
    if tuple(tile_bits) != chunk * parts:
        return None
    return chunk


def _fused_kernel(x_ref, p_ref, s_ref, o_ref, *, tile_bits, offsets,
                  tile_n: int, Kp: int, compute_dtype,
                  dequant_first: bool):
    """One grid step = one (bm, tile_n) output tile of one batch slice at
    its static bit-width.

    Every ref carries a leading size-1 batch/expert block (the grid's first
    axis walks E).  The (bits, byte offset) schedule is unrolled into
    per-tile ``pl.when`` branches: every slice start/size below is a Python
    int, so each branch streams exactly its tile's ragged byte segment and
    unpacks at the tile's own width.  Exactly one branch fires per grid
    step.  ``dequant_first`` picks the scale placement (module docstring):
    accumulator-scaled (per-group bit-parity) vs weight-scaled in VMEM
    (dense-einsum bit-parity, the expert path).
    """
    j = pl.program_id(2)
    x = x_ref[...][0]                                       # (bm, Kp)
    for t, (b, off) in enumerate(zip(tile_bits, offsets)):
        @pl.when(j == t)
        def _tile(b=b, off=off):
            f = qz.pack_factor(b)
            flat = pl.load(p_ref, (pl.dslice(0, 1),
                                   pl.dslice(off, tile_n * (Kp // f))))
            w_int = _unpack_block(flat.reshape(tile_n, Kp // f), b)
            s = s_ref[...][0].astype(jnp.float32)           # (tile_n,)
            if dequant_first:
                w = (w_int.astype(jnp.float32) * s[:, None]
                     ).astype(compute_dtype)
                out = jnp.dot(x.astype(compute_dtype), w.T,
                              preferred_element_type=jnp.float32)
            else:
                acc = jnp.dot(x.astype(compute_dtype),
                              w_int.astype(compute_dtype).T,
                              preferred_element_type=jnp.float32)
                out = acc * s[None, :]
            o_ref[...] = out[None]


def quant_matmul_fused_3d(x: jnp.ndarray, fused_packed: jnp.ndarray,
                          fused_scales: jnp.ndarray, tile_bits: tuple, *,
                          Kp: int, tile_n: int, bm: int = 128,
                          interpret: bool = True, out_dtype=jnp.float32,
                          compute_dtype=jnp.float32,
                          dequant_first: bool = True) -> jnp.ndarray:
    """Batched (expert-axis) single-launch multi-precision grouped GEMM.

    ``x (E, M, Kp)`` (M a ``bm`` multiple, Kp the common pack-padded
    contraction) x ``fused_packed (E, sum_t tile_bytes)`` uint8 ->
    ``(E, M, T * tile_n)`` f32 in tile walk order: the whole
    ``einsum("ecd,efd->ecf")``-shaped expert GEMM in ONE ``pallas_call``,
    grid ``(E, M/bm, T)``.  ``tile_bits`` is the static per-tile bit-width
    schedule shared by every expert; ``fused_scales (E, T * tile_n)``
    carries the per-expert per-channel dequant steps (0 for tile-padding
    rows).  ``dequant_first=True`` (the expert default) scales the unpacked
    integer tile in VMEM before the MXU dot — bit-exact at f32 with the
    dense einsum over ``w_int * scale`` this kernel replaces.
    """
    E, M = x.shape[0], x.shape[1]
    T = len(tile_bits)
    assert M % bm == 0 and x.shape[2] == Kp, (x.shape, bm, Kp)
    assert Kp % FUSED_K_ALIGN == 0 and Kp <= K_SINGLE_STEP_MAX, Kp
    offsets = fused_tile_offsets(tile_bits, Kp, tile_n)
    nbytes = offsets[-1] + fused_tile_bytes(tile_bits[-1], Kp, tile_n)
    assert fused_packed.shape == (E, nbytes), \
        (fused_packed.shape, E, nbytes, "fused buffer does not match schedule")
    assert fused_scales.shape == (E, T * tile_n), fused_scales.shape
    kern = functools.partial(_fused_kernel, tile_bits=tuple(tile_bits),
                             offsets=offsets, tile_n=tile_n, Kp=Kp,
                             compute_dtype=compute_dtype,
                             dequant_first=dequant_first)
    out = pl.pallas_call(
        kern,
        grid=(E, M // bm, T),
        in_specs=[
            pl.BlockSpec((1, bm, Kp), lambda e, i, j: (e, i, 0)),
            # one expert's whole ragged buffer is resident (edge weights are
            # small); an i/j-constant index map fetches it once per expert
            pl.BlockSpec((1, nbytes), lambda e, i, j: (e, 0)),
            pl.BlockSpec((1, tile_n), lambda e, i, j: (e, j)),
        ],
        # identity index map: when the deploy transform orders the schedule
        # by canonical output tile, this map IS the order restore
        out_specs=pl.BlockSpec((1, bm, tile_n), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, T * tile_n), jnp.float32),
        interpret=interpret,
    )(x, fused_packed, fused_scales)
    return out.astype(out_dtype)


def quant_matmul_fused_2d(x: jnp.ndarray, fused_packed: jnp.ndarray,
                          fused_scales: jnp.ndarray, tile_bits: tuple, *,
                          Kp: int, tile_n: int, bm: int = 128,
                          interpret: bool = True, out_dtype=jnp.float32,
                          compute_dtype=jnp.float32) -> jnp.ndarray:
    """Single-launch multi-precision GEMM over a ragged-packed buffer.

    ``x (M, Kp)`` (M a ``bm`` multiple, Kp the common pack-padded
    contraction) x ``fused_packed (sum_t tile_bytes,)`` uint8 ->
    ``(M, T * tile_n)`` f32 in tile walk order.  ``tile_bits`` is the static
    per-tile bit-width schedule; ``fused_scales (T * tile_n,)`` carries the
    per-channel dequant steps (0 for tile-padding rows).  One ``pallas_call``
    regardless of how many precisions the weight mixes.

    The ``E == 1`` slice of :func:`quant_matmul_fused_3d` with the
    accumulator-scale placement (``dequant_first=False``) — bit-exact at
    f32 with the per-group ``_kernel`` path, PR 3's contract.
    """
    assert fused_scales.shape == (len(tile_bits) * tile_n,), \
        fused_scales.shape
    out = quant_matmul_fused_3d(
        x[None], fused_packed[None], fused_scales[None], tile_bits, Kp=Kp,
        tile_n=tile_n, bm=bm, interpret=interpret, out_dtype=out_dtype,
        compute_dtype=compute_dtype, dequant_first=False)
    return out[0]


def quant_matmul_2d(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                    bits: int, *, bm: int = 128, bn: int = 128,
                    bk: int = 512, interpret: bool = True,
                    out_dtype=jnp.float32,
                    compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x (M, K) x packed (N, K/f) -> (M, N) f32; M/N/K already padded.

    ``compute_dtype`` is the MXU input dtype: bf16 (default, the TPU fast
    path — int weights <= 127 are bf16-exact so only the activations round)
    or f32 (full-precision parity with the fake-quant reference at the cost
    of MXU passes — what ``QTensor.matmul``/``conv2d`` use by default).
    Accumulation is always f32.
    """
    M, K = x.shape
    N = packed.shape[0]
    f = qz.pack_factor(bits)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % f == 0 and packed.shape[1] == K // f
    k_steps = K // bk
    kern = functools.partial(_kernel, bits=bits, k_steps=k_steps,
                             out_dtype=out_dtype, compute_dtype=compute_dtype)
    out = pl.pallas_call(
        kern,
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk // f), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, packed, scale)
    return out.astype(out_dtype)
