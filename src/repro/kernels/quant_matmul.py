"""Pallas TPU kernel: packed sub-byte weight matmul with in-VMEM dequant.

The deployment hot-spot of the paper's technique on TPU (DESIGN.md §6): one
per-precision channel group of a deployed linear is

    y[m, n] = scale[n] * sum_k x[m, k] * w_int[n, k]

with ``w_int`` stored *packed* (4x int2 / 2x int4 / 1x int8 per uint8 byte)
in HBM.  The kernel streams packed bytes HBM->VMEM (the point: weight
bandwidth scales with the searched bit-width), unpacks + sign-extends in
VMEM registers, runs the MXU dot at bf16/f32, and applies the per-channel
scale once at the end of the K loop.

Tiling: grid (M/bm, N/bn, K/bk); x block (bm, bk), packed block
(bn, bk/pack_factor), output block (bm, bn) accumulated across the K grid
axis (output revisiting — the standard Pallas matmul reduction pattern).
Block defaults bm=bn=128, bk=512 keep the working set
(128*512*2 + 128*512 + 128*128*4)B ≈ 0.4 MB well under the ~16 MB VMEM
budget while keeping the MXU dimensions 128-aligned.

Validated in interpret mode on CPU against ref.quant_matmul_ref across a
shape/dtype/bits sweep (tests/test_kernels.py); ``interpret=False`` is the
real-TPU path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import quantizers as qz


def _unpack_block(packed: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(bn, bkp) uint8 -> (bn, bkp * 8/bits) int8, sign-extended."""
    if bits == 8:
        return packed.astype(jnp.int8)
    f = 8 // bits
    mask = (1 << bits) - 1
    sign = 1 << (bits - 1)
    parts = []
    for i in range(f):
        u = (packed >> (i * bits)) & mask                   # uint8 lanes
        s = u.astype(jnp.int32)
        s = jnp.where(s >= sign, s - (1 << bits), s)
        parts.append(s.astype(jnp.int8))
    # interleave: value j of byte b sits at column b*f + j
    stacked = jnp.stack(parts, axis=-1)                     # (bn, bkp, f)
    return stacked.reshape(packed.shape[0], packed.shape[1] * f)


def _kernel(x_ref, p_ref, s_ref, o_ref, *, bits: int, k_steps: int,
            out_dtype, compute_dtype):
    k = pl.program_id(2)
    w_int = _unpack_block(p_ref[...], bits)                 # (bn, bk) int8
    x = x_ref[...]                                          # (bm, bk)
    acc = jnp.dot(x.astype(compute_dtype), w_int.astype(compute_dtype).T,
                  preferred_element_type=jnp.float32)       # (bm, bn)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += acc

    @pl.when(k == k_steps - 1)
    def _scale():
        o_ref[...] *= s_ref[...][None, :].astype(jnp.float32)


def quant_matmul_2d(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                    bits: int, *, bm: int = 128, bn: int = 128,
                    bk: int = 512, interpret: bool = True,
                    out_dtype=jnp.float32,
                    compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x (M, K) x packed (N, K/f) -> (M, N) f32; M/N/K already padded.

    ``compute_dtype`` is the MXU input dtype: bf16 (default, the TPU fast
    path — int weights <= 127 are bf16-exact so only the activations round)
    or f32 (full-precision parity with the fake-quant reference at the cost
    of MXU passes — what ``QTensor.matmul``/``conv2d`` use by default).
    Accumulation is always f32.
    """
    M, K = x.shape
    N = packed.shape[0]
    f = qz.pack_factor(bits)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % f == 0 and packed.shape[1] == K // f
    k_steps = K // bk
    kern = functools.partial(_kernel, bits=bits, k_steps=k_steps,
                             out_dtype=out_dtype, compute_dtype=compute_dtype)
    out = pl.pallas_call(
        kern,
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk // f), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, packed, scale)
    return out.astype(out_dtype)
