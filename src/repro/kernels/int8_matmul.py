"""Pallas TPU kernel: dynamic int8 x int8 -> int32 GEMM for *training*.

The training-compute counterpart of ``quant_matmul.py`` (which serves packed
*static* weights): both operands are quantized **dynamically per row** of
their contraction axis — symmetric, absmax-scaled, the gau-nernst/quant-train
recipe — multiplied on the MXU as int8 with int32 accumulation, and
dequantized in a fused epilogue:

    y[m, n] = (sum_k a_i8[m, k] * b_i8[n, k]) * sa[m] * sb[n]

Because int32 accumulation of int8 products is exact (no rounding anywhere
in the reduction), the kernel's output is **bitwise identical** to the jnp
reference :func:`scaled_int8_mm_ref` for any K schedule — the float epilogue
multiplies in one fixed order (acc * sa then * sb).  That is the acceptance
contract the forward path of ``repro.qtrain`` tests against, and it also
means zero-padding M/N/K to tile multiples is exact, not approximate.

Quantization (:func:`rowwise_quantize`) supports two rounding modes:

* deterministic round-to-nearest (``key=None``) — the forward pass;
* **stochastic rounding** (``key`` given) — ``floor(x/s + u)``,
  ``u ~ U[0, 1)``: unbiased (``E[q] = x/s``), exact on already-representable
  values, deterministic per PRNG key.  The backward matmuls use this so the
  quantization noise of ``dy``/``x``/``w`` does not bias the gradient
  estimate across steps (Schaefer et al., 2206.07741).

The SR uniforms come from ``jax.random`` *outside* the kernel: the TPU
in-kernel PRNG (``pltpu.prng_random_bits``) has no interpret-mode
implementation, and quantization is bandwidth-trivial next to the GEMM.

Tiling: grid (M/bm, N/bn); K is not gridded — training GEMMs here contract
at most a few thousand columns, one MXU dot each (same single-K-step
rationale as ``quant_matmul.K_SINGLE_STEP_MAX``).  int32 overflow needs
``K * 127 * 127 < 2^31`` i.e. K < ~133k, far above any model dimension in
this repo; guarded with an explicit error.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# K ceiling for exact int32 accumulation: K * 127 * 127 <= 2^31 - 1.
K_INT32_EXACT_MAX = (2 ** 31 - 1) // (127 * 127)

_DIM_NUMS = (((1,), (1,)), ((), ()))    # contract last axis of both operands


def rowwise_quantize(x: jnp.ndarray, key=None):
    """Symmetric per-row int8 quantization over the last axis.

    ``x (..., K) -> (q int8 (..., K), scale f32 (...,))`` with
    ``scale = max(|row|) / 127`` (floored at 1e-6/127, matching the
    quantizer epsilon used everywhere else in this repo).  ``key=None``
    rounds to nearest; with a PRNG key the round is stochastic:
    ``floor(x/s + u)``, unbiased and exact on representable values.
    """
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    y = x32 / scale[..., None]
    if key is None:
        q = jnp.round(y)
    else:
        q = jnp.floor(y + jax.random.uniform(key, x.shape, jnp.float32))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale


def scaled_int8_mm_ref(a: jnp.ndarray, b: jnp.ndarray, sa: jnp.ndarray,
                       sb: jnp.ndarray) -> jnp.ndarray:
    """jnp reference for :func:`scaled_int8_mm` — bitwise identical to the
    kernel (exact int32 reduction; epilogue multiplies in the same order)."""
    acc = jax.lax.dot_general(a, b, _DIM_NUMS,
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sa.astype(jnp.float32)[:, None] \
        * sb.astype(jnp.float32)[None, :]


def _int8_mm_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref):
    acc = jax.lax.dot_general(a_ref[...], b_ref[...], _DIM_NUMS,
                              preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * sa_ref[...].astype(jnp.float32)[:, None]
    o_ref[...] = out * sb_ref[...].astype(jnp.float32)[None, :]


def _pad_axis(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_tile(n: int, t: int) -> int:
    """Shrink a tile to the next pow2 >= n for small dims (same policy as
    ``ops._pick_bm`` so tiny training batches do not pad to 128)."""
    return min(t, max(8, 1 << (n - 1).bit_length())) if n < t else t


@functools.partial(jax.jit,
                   static_argnames=("backend", "bm", "bn", "interpret"))
def scaled_int8_mm(a: jnp.ndarray, b: jnp.ndarray, sa: jnp.ndarray,
                   sb: jnp.ndarray, backend: str = "pallas",
                   bm: int = 128, bn: int = 128,
                   interpret=None) -> jnp.ndarray:
    """``a_i8 (M, K) @ b_i8 (N, K)^T * sa[:, None] * sb[None, :] -> f32``.

    The int8 training GEMM with the dequant epilogue fused into the kernel.
    ``backend="jnp"`` runs the (bitwise-identical) reference — used under
    vmap and as the CI cross-check.  ``interpret`` defaults to the global
    ``ops.INTERPRET`` flag (CPU validation vs real TPU lowering).
    """
    M, K = a.shape
    N = b.shape[0]
    if K != b.shape[1]:
        raise ValueError(f"contraction mismatch: a {a.shape} vs b {b.shape}")
    if K > K_INT32_EXACT_MAX:
        raise ValueError(
            f"K={K} overflows exact int32 accumulation "
            f"(max {K_INT32_EXACT_MAX}); shard the contraction first")
    if backend == "jnp":
        return scaled_int8_mm_ref(a, b, sa, sb)
    if interpret is None:
        from repro.kernels import ops
        interpret = ops.INTERPRET
    bm_, bn_ = _pick_tile(M, bm), _pick_tile(N, bn)
    # zero padding is exact: padded rows/cols accumulate zeros and their
    # (zero) scales make the epilogue a no-op; K pads to the MXU lane width
    ap = _pad_axis(_pad_axis(a, 0, bm_), 1, 128)
    bp = _pad_axis(_pad_axis(b, 0, bn_), 1, 128)
    sap = _pad_axis(sa.astype(jnp.float32), 0, bm_)
    sbp = _pad_axis(sb.astype(jnp.float32), 0, bn_)
    Mp, Kp = ap.shape
    Np = bp.shape[0]
    out = pl.pallas_call(
        _int8_mm_kernel,
        grid=(Mp // bm_, Np // bn_),
        in_specs=[
            pl.BlockSpec((bm_, Kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn_, Kp), lambda i, j: (j, 0)),
            pl.BlockSpec((bm_,), lambda i, j: (i,)),
            pl.BlockSpec((bn_,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(ap, bp, sap, sbp)
    return out[:M, :N]


def int8_matmul(a: jnp.ndarray, b: jnp.ndarray, key_a=None, key_b=None,
                backend: str = "pallas") -> jnp.ndarray:
    """Quantize-then-multiply convenience: float ``a (M, K)`` x ``b (N, K)``
    -> f32 ``(M, N)`` through dynamic per-row int8.  ``key_a``/``key_b``
    switch the respective operand's quantize to stochastic rounding."""
    qa, sa = rowwise_quantize(a, key_a)
    qb, sb = rowwise_quantize(b, key_b)
    return scaled_int8_mm(qa, qb, sa, sb, backend=backend)
