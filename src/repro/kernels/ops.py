"""jit'd public wrappers around the Pallas kernels: padding, batching,
backend/interpret selection.

``quant_matmul_fused`` is the deployed hot path: ONE ``pallas_call`` for a
whole multi-precision weight (tile-aligned fused layout, see
kernels/quant_matmul.py).  ``quant_matmul`` is the per-group reference path
(one launch per precision group — ``backend="pallas-pergroup"``) and what
legacy non-tile-aligned QTensors use.  Both accept arbitrary leading batch
dims and unpadded shapes, pad to tile multiples, invoke the kernel, and
slice back.
"""
from __future__ import annotations

import functools

import jax
import jax.core
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import quantizers as qz
from repro.kernels import fake_quant as fq_kernel
from repro.kernels import quant_conv as qc_kernel
from repro.kernels import quant_matmul as qm_kernel

# interpret=True executes the kernel body in Python on CPU (validation);
# on a real TPU runtime set repro_kernels_interpret=False via this flag.
INTERPRET = True


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit,
                   static_argnames=("bits", "c_in", "out_dtype", "bm", "bn",
                                    "bk", "compute_dtype"))
def quant_matmul(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                 bits: int, c_in: int, out_dtype=jnp.bfloat16,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x (..., c_in) @ dequant(packed (n, ceil(c_in/f))) -> (..., n)."""
    f = qz.pack_factor(bits)
    Kp = packed.shape[1] * f                     # pack-padded c_in
    if x.shape[-1] != c_in:
        raise ValueError(
            f"x contraction dim {x.shape[-1]} != c_in {c_in} — for conv "
            "patches this means the im2col width does not match the packed "
            "kernel's C*kh*kw")
    if not 0 <= Kp - c_in < f:
        raise ValueError(
            f"packed K {Kp} (= {packed.shape[1]} bytes * {f}) does not "
            f"correspond to c_in {c_in} at {bits} bits")
    lead = x.shape[:-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, x.shape[-1]).astype(compute_dtype)
    N = packed.shape[0]
    x2 = _pad_to(x2, 1, Kp)                      # exactly Kp (single pad)
    # choose tile sizes that divide (pad where they don't)
    bm_ = _pick_bm(M, bm)
    x2 = _pad_to(x2, 0, bm_)
    packed_p = _pad_to(packed, 0, bn) if N % bn else packed
    scale_p = _pad_to(scale, 0, bn) if N % bn else scale
    bk_ = qm_kernel.pick_bk(Kp, f, bk)
    y = qm_kernel.quant_matmul_2d(x2, packed_p, scale_p, bits, bm=bm_,
                                  bn=min(bn, packed_p.shape[0]), bk=bk_,
                                  interpret=INTERPRET, out_dtype=out_dtype,
                                  compute_dtype=compute_dtype)
    return y[:M, :N].reshape(*lead, N)


def _pick_bm(M: int, bm: int) -> int:
    """M tile size — shared by the per-group and fused entry points so the
    two paths pad M identically (part of the bit-exactness contract)."""
    return min(bm, max(8, 1 << (M - 1).bit_length())) if M < bm else bm


@functools.partial(jax.jit,
                   static_argnames=("tile_bits", "tile_n", "c_in", "c_out",
                                    "out_dtype", "bm", "compute_dtype"))
def quant_matmul_fused(x: jnp.ndarray, fused_packed: jnp.ndarray,
                       fused_scales: jnp.ndarray, fused_perm, tile_bits: tuple,
                       tile_n: int, c_in: int, c_out: int,
                       out_dtype=jnp.float32, bm: int = 128,
                       compute_dtype=jnp.float32) -> jnp.ndarray:
    """Whole multi-precision GEMM ``x (..., c_in) -> (..., c_out)`` in ONE
    kernel launch over the tile-aligned fused layout (kernels/quant_matmul).

    ``tile_bits`` is the static per-output-tile bit-width schedule (walk
    order), ``fused_packed`` the ragged byte buffer, ``fused_scales`` the
    per-channel steps in walk order.  ``fused_perm`` is ``None`` when the
    deploy transform folded the channel-order restore into the schedule's
    walk order (the output needs only the tail-padding slice); otherwise it
    gathers the ``c_out`` real columns into target order — a single take,
    with no per-group concat either way.
    """
    if x.shape[-1] != c_in:
        raise ValueError(
            f"x contraction dim {x.shape[-1]} != c_in {c_in} — for conv "
            "patches this means the im2col width does not match the packed "
            "kernel's C*kh*kw")
    Kp = -(-c_in // qm_kernel.FUSED_K_ALIGN) * qm_kernel.FUSED_K_ALIGN
    lead = x.shape[:-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, c_in).astype(compute_dtype)
    x2 = _pad_to(x2, 1, Kp)
    bm_ = _pick_bm(M, bm)
    x2 = _pad_to(x2, 0, bm_)
    y = qm_kernel.quant_matmul_fused_2d(
        x2, fused_packed, fused_scales, tile_bits, Kp=Kp, tile_n=tile_n,
        bm=bm_, interpret=INTERPRET, out_dtype=out_dtype,
        compute_dtype=compute_dtype)
    y = y[:M]
    if fused_perm is not None:
        y = jnp.take(y, fused_perm, axis=-1)
    else:
        y = y[:, :c_out]
    return y.reshape(*lead, c_out)


@functools.partial(jax.jit,
                   static_argnames=("tile_bits", "tile_n", "c_in", "c_out",
                                    "out_dtype", "bm", "compute_dtype"))
def quant_matmul_fused_batched(x: jnp.ndarray, fused_packed: jnp.ndarray,
                               fused_scales: jnp.ndarray, fused_perm,
                               tile_bits: tuple, tile_n: int, c_in: int,
                               c_out: int, out_dtype=jnp.float32,
                               bm: int = 128,
                               compute_dtype=jnp.float32) -> jnp.ndarray:
    """Expert-stacked fused GEMM ``x (E, ..., c_in) -> (E, ..., c_out)`` in
    ONE kernel launch — the packed replacement for
    ``einsum("ecd,efd->ecf", x, dense_expert_stack)``.

    ``fused_packed (E, bytes)`` / ``fused_scales (E, T * tile_n)`` are the
    per-expert buffers of the shared static tile schedule
    (``models/serving.init_deployed_linear(expert_axis=E)``); the grid adds
    a leading E axis (kernels/quant_matmul.quant_matmul_fused_3d).  The
    kernel dequantizes each weight tile in VMEM **before** the MXU dot, so
    at f32 compute the output is bit-exact with the dense einsum reference
    over ``dequantize()`` — HBM weight traffic stays the packed sub-byte
    bytes.  ``fused_perm`` gathers the output channels exactly as in
    :func:`quant_matmul_fused` (None = restore folded into the walk order).
    """
    E = fused_packed.shape[0]
    if x.ndim < 2 or x.shape[0] != E:
        raise ValueError(
            f"expert-stacked fused matmul needs x of shape (E={E}, ..., "
            f"c_in); got {x.shape}")
    if x.shape[-1] != c_in:
        raise ValueError(
            f"x contraction dim {x.shape[-1]} != c_in {c_in}")
    Kp = -(-c_in // qm_kernel.FUSED_K_ALIGN) * qm_kernel.FUSED_K_ALIGN
    lead = x.shape[1:-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(E, M, c_in).astype(compute_dtype)
    x2 = _pad_to(x2, 2, Kp)
    bm_ = _pick_bm(M, bm)
    x2 = _pad_to(x2, 1, bm_)
    y = qm_kernel.quant_matmul_fused_3d(
        x2, fused_packed, fused_scales, tile_bits, Kp=Kp, tile_n=tile_n,
        bm=bm_, interpret=INTERPRET, out_dtype=out_dtype,
        compute_dtype=compute_dtype)
    y = y[:, :M]
    if fused_perm is not None:
        y = jnp.take(y, fused_perm, axis=-1)
    else:
        y = y[..., :c_out]
    return y.reshape(E, *lead, c_out)


@functools.partial(jax.jit,
                   static_argnames=("tile_bits", "chunk", "tile_n", "c_in",
                                    "c_out", "mesh", "axis", "out_dtype",
                                    "bm", "compute_dtype"))
def quant_matmul_fused_tp(x: jnp.ndarray, fused_packed: jnp.ndarray,
                          fused_scales: jnp.ndarray, fused_perm,
                          tile_bits: tuple, chunk: tuple, tile_n: int,
                          c_in: int, c_out: int, mesh, axis: str = "model",
                          out_dtype=jnp.float32, bm: int = 128,
                          compute_dtype=jnp.float32) -> jnp.ndarray:
    """Tensor-parallel :func:`quant_matmul_fused`: the fused ragged buffer
    and its scales are sharded along the N-tile schedule (``mesh[axis]``
    identical chunks, see ``quant_matmul.tp_chunk``), each device runs the
    SAME single-launch program over its own whole static-bit tiles, and the
    output concatenates along N.  Per-device compute is the unmodified int
    kernel, so the result is bitwise identical to the unsharded launch.
    """
    parts = mesh.shape[axis]
    if chunk * parts != tuple(tile_bits):
        raise ValueError(
            f"chunk {chunk} x {parts} does not tile schedule {tile_bits}")
    if x.shape[-1] != c_in:
        raise ValueError(
            f"x contraction dim {x.shape[-1]} != c_in {c_in}")
    Kp = -(-c_in // qm_kernel.FUSED_K_ALIGN) * qm_kernel.FUSED_K_ALIGN
    lead = x.shape[:-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, c_in).astype(compute_dtype)
    x2 = _pad_to(x2, 1, Kp)
    bm_ = _pick_bm(M, bm)
    x2 = _pad_to(x2, 0, bm_)

    def body(xs, fp, fs):
        return qm_kernel.quant_matmul_fused_2d(
            xs, fp, fs, chunk, Kp=Kp, tile_n=tile_n, bm=bm_,
            interpret=INTERPRET, out_dtype=out_dtype,
            compute_dtype=compute_dtype)

    y = shard_map(body, mesh=mesh,
                  in_specs=(P(), P(axis), P(axis)),
                  out_specs=P(None, axis), check_rep=False)(
        x2, fused_packed, fused_scales)
    y = y[:M]
    if fused_perm is not None:
        y = jnp.take(y, fused_perm, axis=-1)
    else:
        y = y[:, :c_out]
    return y.reshape(*lead, c_out)


@functools.partial(jax.jit,
                   static_argnames=("tile_bits", "tile_n", "c_in", "c_out",
                                    "mesh", "axis", "out_dtype", "bm",
                                    "compute_dtype"))
def quant_matmul_fused_batched_ep(x: jnp.ndarray, fused_packed: jnp.ndarray,
                                  fused_scales: jnp.ndarray, fused_perm,
                                  tile_bits: tuple, tile_n: int, c_in: int,
                                  c_out: int, mesh, axis: str = "model",
                                  out_dtype=jnp.float32, bm: int = 128,
                                  compute_dtype=jnp.float32) -> jnp.ndarray:
    """Expert-parallel :func:`quant_matmul_fused_batched`: the 3-D kernel's
    leading E axis is sharded over ``mesh[axis]`` (every expert keeps its
    full tile schedule), each device launches the batched kernel over its
    own E/parts experts — bitwise identical to the unsharded launch.
    """
    E = fused_packed.shape[0]
    parts = mesh.shape[axis]
    if E % parts:
        raise ValueError(f"E={E} not divisible by mesh[{axis}]={parts}")
    if x.ndim < 2 or x.shape[0] != E:
        raise ValueError(
            f"expert-stacked fused matmul needs x of shape (E={E}, ..., "
            f"c_in); got {x.shape}")
    if x.shape[-1] != c_in:
        raise ValueError(
            f"x contraction dim {x.shape[-1]} != c_in {c_in}")
    Kp = -(-c_in // qm_kernel.FUSED_K_ALIGN) * qm_kernel.FUSED_K_ALIGN
    lead = x.shape[1:-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(E, M, c_in).astype(compute_dtype)
    x2 = _pad_to(x2, 2, Kp)
    bm_ = _pick_bm(M, bm)
    x2 = _pad_to(x2, 1, bm_)

    def body(xs, fp, fs):
        return qm_kernel.quant_matmul_fused_3d(
            xs, fp, fs, tile_bits, Kp=Kp, tile_n=tile_n, bm=bm_,
            interpret=INTERPRET, out_dtype=out_dtype,
            compute_dtype=compute_dtype)

    y = shard_map(body, mesh=mesh,
                  in_specs=(P(axis), P(axis), P(axis)),
                  out_specs=P(axis), check_rep=False)(
        x2, fused_packed, fused_scales)
    y = y[:, :M]
    if fused_perm is not None:
        y = jnp.take(y, fused_perm, axis=-1)
    else:
        y = y[..., :c_out]
    return y.reshape(E, *lead, c_out)


def qtensor_matmul(x: jnp.ndarray, qt, out_dtype=jnp.float32) -> jnp.ndarray:
    """``x (..., c_in) @ QTensor -> (..., c_out)`` on the Pallas path.

    Typed entry point for :class:`repro.api.qtensor.QTensor`.  Routing
    (fused single launch vs per-group), concat and order-restore live in
    ``QTensor.matmul`` (single source of truth for all backends); this
    wrapper just pins the Pallas backend.  ``out_dtype`` defaults to f32,
    matching :func:`qtensor_conv2d` (the bit-parity compute path).
    """
    return qt.matmul(x, out_dtype, backend="pallas")


@functools.partial(jax.jit,
                   static_argnames=("bits", "c_in", "kernel_hw", "stride",
                                    "padding", "out_dtype", "compute_dtype"))
def quant_conv2d(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                 bits: int, c_in: int, kernel_hw: tuple, stride=1,
                 padding: str = "SAME", out_dtype=jnp.float32,
                 compute_dtype=jnp.float32) -> jnp.ndarray:
    """Packed conv of ONE precision group: im2col + fused patch-GEMM.

    ``x (N, H, W, C)`` NHWC against ``packed (n, ceil(c_in/f))`` where
    ``c_in = C * kh * kw`` is the flattened contraction axis (channel-major,
    matching ``(c_out, C, kh, kw).reshape(c_out, -1)``) -> ``(N, Ho, Wo, n)``.
    The dense float kernel is never materialized: packed bytes stream into
    the quant_matmul kernel and unpack in VMEM.  Group concat / channel-order
    restore for a multi-precision ``QTensor`` live in ``QTensor.conv2d``.
    """
    kh, kw = kernel_hw
    patches = qc_kernel.im2col(x, kh, kw, stride, padding)
    return quant_matmul(patches, packed, scale, bits, c_in,
                        out_dtype=out_dtype, compute_dtype=compute_dtype)


def qtensor_conv2d(x: jnp.ndarray, qt, stride=1, padding: str = "SAME",
                   groups: int = 1, out_dtype=jnp.float32) -> jnp.ndarray:
    """NHWC ``x`` * conv :class:`QTensor` -> ``(N, Ho, Wo, c_out)``, Pallas.

    Mirror of :func:`qtensor_matmul` for convolutions: the im2col, group
    loop, concat and order-restore live in ``QTensor.conv2d`` (single source
    of truth for both backends); this wrapper just pins the Pallas backend.
    """
    return qt.conv2d(x, stride=stride, padding=padding, groups=groups,
                     compute_dtype=out_dtype, backend="pallas")


def count_pallas_launches(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call``s one execution of ``fn(*args)`` issues.

    Counts ``pallas_call`` primitives in the traced jaxpr, recursing into
    nested call/scan/cond/``pjit``/``shard_map`` sub-jaxprs — robust against
    jit caching (a cached inner trace never re-enters the ``pl.pallas_call``
    Python wrapper, so monkeypatch counters undercount; the jaxpr is ground
    truth).  Sub-jaxprs are found by walking every eqn param value through
    arbitrary tuple/list/dict nesting, so higher-order primitives that stash
    their body under new param layouts keep counting.  Counts are launches
    per *program*, not per device: a kernel inside ``shard_map`` runs one
    program on every mesh device but counts once, matching the CI guards'
    "how many kernels does one step issue" meaning.  Used by the
    launch-count guard tests and the benchmark's launch column.
    """
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)

    def subjaxprs(v):
        stack = [v]
        while stack:
            u = stack.pop()
            if isinstance(u, (tuple, list)):
                stack.extend(u)
            elif isinstance(u, dict):
                stack.extend(u.values())
            elif isinstance(u, jax.core.ClosedJaxpr):
                yield u.jaxpr
            elif isinstance(u, jax.core.Jaxpr):
                yield u

    def walk(jpr) -> int:
        n = 0
        for eqn in jpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in subjaxprs(v):
                    n += walk(sub)
        return n

    return walk(jaxpr.jaxpr)


@functools.partial(jax.jit, static_argnames=("bitwidths",))
def fused_mix(w: jnp.ndarray, gamma_hat: jnp.ndarray, alpha: jnp.ndarray,
              bitwidths=(2, 4, 8)) -> jnp.ndarray:
    """Fused Eq. 5 weight mixture; arbitrary (N, K) via padding."""
    N, K = w.shape
    bn = 256 if N % 256 == 0 else (N if N <= 256 else 1 << 30)
    bk = 512 if K % 512 == 0 else (K if K <= 512 else 1 << 30)
    if bn == 1 << 30 or bk == 1 << 30:
        wp = _pad_to(_pad_to(w, 0, 256), 1, 512)
        gp = _pad_to(gamma_hat, 0, 256)
        ap = jnp.maximum(_pad_to(alpha, 0, 256), 1e-6)
        out = fq_kernel.fused_mix_2d(wp, gp, ap, bitwidths,
                                     interpret=INTERPRET)
        return out[:N, :K]
    return fq_kernel.fused_mix_2d(w, gamma_hat, alpha, bitwidths, bn=bn,
                                  bk=bk, interpret=INTERPRET)
