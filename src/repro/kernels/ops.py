"""jit'd public wrappers around the Pallas kernels: padding, batching,
backend/interpret selection.

``quant_matmul`` is the entry point serving.dq_linear uses with
backend="pallas": it accepts arbitrary leading batch dims and unpadded
shapes, pads to tile multiples, invokes the kernel, and slices back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quantizers as qz
from repro.kernels import fake_quant as fq_kernel
from repro.kernels import quant_conv as qc_kernel
from repro.kernels import quant_matmul as qm_kernel

# interpret=True executes the kernel body in Python on CPU (validation);
# on a real TPU runtime set repro_kernels_interpret=False via this flag.
INTERPRET = True


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit,
                   static_argnames=("bits", "c_in", "out_dtype", "bm", "bn",
                                    "bk", "compute_dtype"))
def quant_matmul(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                 bits: int, c_in: int, out_dtype=jnp.bfloat16,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x (..., c_in) @ dequant(packed (n, ceil(c_in/f))) -> (..., n)."""
    f = qz.pack_factor(bits)
    Kp = packed.shape[1] * f                     # pack-padded c_in
    if x.shape[-1] != c_in:
        raise ValueError(
            f"x contraction dim {x.shape[-1]} != c_in {c_in} — for conv "
            "patches this means the im2col width does not match the packed "
            "kernel's C*kh*kw")
    if not 0 <= Kp - c_in < f:
        raise ValueError(
            f"packed K {Kp} (= {packed.shape[1]} bytes * {f}) does not "
            f"correspond to c_in {c_in} at {bits} bits")
    lead = x.shape[:-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, x.shape[-1]).astype(compute_dtype)
    N = packed.shape[0]
    x2 = _pad_to(x2, 1, Kp)                      # exactly Kp (single pad)
    # choose tile sizes that divide (pad where they don't)
    bm_ = min(bm, max(8, 1 << (M - 1).bit_length())) if M < bm else bm
    x2 = _pad_to(x2, 0, bm_)
    packed_p = _pad_to(packed, 0, bn) if N % bn else packed
    scale_p = _pad_to(scale, 0, bn) if N % bn else scale
    bk_ = bk
    while Kp % bk_ or (bk_ % f):
        bk_ //= 2
        if bk_ < f:
            bk_ = Kp           # single K step
            break
    y = qm_kernel.quant_matmul_2d(x2, packed_p, scale_p, bits, bm=bm_,
                                  bn=min(bn, packed_p.shape[0]), bk=bk_,
                                  interpret=INTERPRET, out_dtype=out_dtype,
                                  compute_dtype=compute_dtype)
    return y[:M, :N].reshape(*lead, N)


def qtensor_matmul(x: jnp.ndarray, qt, out_dtype=jnp.bfloat16) -> jnp.ndarray:
    """``x (..., c_in) @ QTensor -> (..., c_out)`` on the Pallas path.

    Typed entry point for :class:`repro.api.qtensor.QTensor`.  The group
    loop, concat and order-restore live in ``QTensor.matmul`` (single source
    of truth for both backends); this wrapper just pins the Pallas backend.
    """
    return qt.matmul(x, out_dtype, backend="pallas")


@functools.partial(jax.jit,
                   static_argnames=("bits", "c_in", "kernel_hw", "stride",
                                    "padding", "out_dtype", "compute_dtype"))
def quant_conv2d(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                 bits: int, c_in: int, kernel_hw: tuple, stride=1,
                 padding: str = "SAME", out_dtype=jnp.float32,
                 compute_dtype=jnp.float32) -> jnp.ndarray:
    """Packed conv of ONE precision group: im2col + fused patch-GEMM.

    ``x (N, H, W, C)`` NHWC against ``packed (n, ceil(c_in/f))`` where
    ``c_in = C * kh * kw`` is the flattened contraction axis (channel-major,
    matching ``(c_out, C, kh, kw).reshape(c_out, -1)``) -> ``(N, Ho, Wo, n)``.
    The dense float kernel is never materialized: packed bytes stream into
    the quant_matmul kernel and unpack in VMEM.  Group concat / channel-order
    restore for a multi-precision ``QTensor`` live in ``QTensor.conv2d``.
    """
    kh, kw = kernel_hw
    patches = qc_kernel.im2col(x, kh, kw, stride, padding)
    return quant_matmul(patches, packed, scale, bits, c_in,
                        out_dtype=out_dtype, compute_dtype=compute_dtype)


def qtensor_conv2d(x: jnp.ndarray, qt, stride=1, padding: str = "SAME",
                   groups: int = 1, out_dtype=jnp.float32) -> jnp.ndarray:
    """NHWC ``x`` * conv :class:`QTensor` -> ``(N, Ho, Wo, c_out)``, Pallas.

    Mirror of :func:`qtensor_matmul` for convolutions: the im2col, group
    loop, concat and order-restore live in ``QTensor.conv2d`` (single source
    of truth for both backends); this wrapper just pins the Pallas backend.
    """
    return qt.conv2d(x, stride=stride, padding=padding, groups=groups,
                     compute_dtype=out_dtype, backend="pallas")


@functools.partial(jax.jit, static_argnames=("bitwidths",))
def fused_mix(w: jnp.ndarray, gamma_hat: jnp.ndarray, alpha: jnp.ndarray,
              bitwidths=(2, 4, 8)) -> jnp.ndarray:
    """Fused Eq. 5 weight mixture; arbitrary (N, K) via padding."""
    N, K = w.shape
    bn = 256 if N % 256 == 0 else (N if N <= 256 else 1 << 30)
    bk = 512 if K % 512 == 0 else (K if K <= 512 else 1 << 30)
    if bn == 1 << 30 or bk == 1 << 30:
        wp = _pad_to(_pad_to(w, 0, 256), 1, 512)
        gp = _pad_to(gamma_hat, 0, 256)
        ap = jnp.maximum(_pad_to(alpha, 0, 256), 1e-6)
        out = fq_kernel.fused_mix_2d(wp, gp, ap, bitwidths,
                                     interpret=INTERPRET)
        return out[:N, :K]
    return fq_kernel.fused_mix_2d(w, gamma_hat, alpha, bitwidths, bn=bn,
                                  bk=bk, interpret=INTERPRET)
