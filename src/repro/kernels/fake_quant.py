"""Pallas TPU kernel: fused DNAS weight mixture (Eq. 5) in one HBM pass.

The search-phase forward fake-quantizes every weight at |P_W| precisions and
mixes them (core/mixedprec.effective_weight).  Naively that reads W from HBM
once and writes |P_W| temporaries + the mixture — 4x the weight traffic of a
plain forward.  This kernel computes

    out[n, k] = sum_p gamma_hat[n, p] * FQ(w[n, k]; alpha[n], p)

in a single pass: one W read, one OUT write, everything else in VMEM.  This
is the "fused fake-quant" beyond-paper optimization logged in EXPERIMENTS.md
§Perf (it attacks the memory roofline term of the train_4k cells).

Grid (N/bn, K/bk); blocks: w (bn, bk), gamma_hat (bn, P), alpha (bn,).
The P loop is unrolled (|P_W| = 3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, g_ref, a_ref, o_ref, *, bitwidths: tuple[int, ...]):
    w = w_ref[...].astype(jnp.float32)                    # (bn, bk)
    a = jnp.maximum(a_ref[...].astype(jnp.float32), 1e-6)[:, None]
    acc = jnp.zeros_like(w)
    for i, bits in enumerate(bitwidths):
        half = (1 << (bits - 1)) - 1
        step = a / half
        q = jnp.clip(w, -a, a) / step
        q = jnp.round(q) * step
        acc = acc + g_ref[...][:, i:i + 1].astype(jnp.float32) * q
    o_ref[...] = acc


def fused_mix_2d(w: jnp.ndarray, gamma_hat: jnp.ndarray, alpha: jnp.ndarray,
                 bitwidths=(2, 4, 8), *, bn: int = 256, bk: int = 512,
                 interpret: bool = True) -> jnp.ndarray:
    """w (N, K), gamma_hat (N, |P|), alpha (N,) -> mixed weights (N, K) f32.

    Forward-only fused path (the VJP falls back to the reference expression —
    the mixture is linear in gamma_hat and piecewise-linear in w, so training
    uses mixedprec.effective_weight; serving/eval and the frozen fine-tune
    phase use this kernel).
    """
    N, K = w.shape
    bn, bk = min(bn, N), min(bk, K)
    assert N % bn == 0 and K % bk == 0, (N, K, bn, bk)
    kern = functools.partial(_kernel, bitwidths=tuple(bitwidths))
    return pl.pallas_call(
        kern,
        grid=(N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, len(bitwidths)), lambda i, j: (i, 0)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, K), jnp.float32),
        interpret=interpret,
    )(w, gamma_hat, alpha)
