"""Distribution utilities: sharding-rules engine and fault machinery."""
