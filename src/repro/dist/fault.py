"""Fault machinery: supervised checkpoint/restart training, heartbeats,
elastic mesh reshaping and straggler detection.

``run_supervised`` is the single-host stand-in for the production
supervisor: it drives ``run_steps`` in ``ckpt_every``-sized segments, saves
after each segment, and on a :class:`HostFailure` restores the latest
checkpoint and replays.  With a deterministic, step-keyed data pipeline the
restarted trajectory is bit-identical to an uninterrupted run
(tests/test_fault_recovery.py asserts exactly that).
"""
from __future__ import annotations

import dataclasses
import statistics
from collections import defaultdict, deque
from typing import Callable, Optional, Sequence


class HostFailure(RuntimeError):
    """A (possibly injected) host failure; carries the failed host id."""

    def __init__(self, host_id: int):
        super().__init__(f"host {host_id} failed")
        self.host_id = host_id


def run_supervised(total_steps: int,
                   make_state: Callable[[int], object],
                   run_steps: Callable[[object, int, int], tuple],
                   save: Callable[[int, object], None],
                   restore: Callable[[], tuple],
                   ckpt_every: int = 100,
                   max_restarts: int = 5):
    """Run ``total_steps`` under checkpoint/restart supervision.

    ``run_steps(state, start, stop)`` advances [start, stop) and returns
    ``(state, stop)``; ``restore()`` returns ``(step, state)`` or
    ``(None, None)`` when no checkpoint exists.  Returns
    ``(state, step, n_restarts)``; re-raises the failure once the same run
    has been restarted ``max_restarts`` times (permanently sick fleet).
    """
    state, step, restarts = make_state(0), 0, 0
    while step < total_steps:
        target = min(step + ckpt_every, total_steps)
        try:
            state, step = run_steps(state, step, target)
        except HostFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            r_step, r_state = restore()
            if r_state is None:
                state, step = make_state(0), 0
            else:
                state, step = r_state, r_step
            continue
        save(step, state)
    return state, step, restarts


def owned_slots(host: int, n_slots: int, n_hosts: int) -> list[int]:
    """Contiguous slot partition: the engine slots host ``host`` owns.

    The serving engine shards its slot axis over the ``data`` hosts; this is
    the single source of truth for that ownership (the drain path frees
    exactly these slots when a heartbeat dies).  Balanced to within one slot
    for any ``n_slots``/``n_hosts``.
    """
    if not 0 <= host < n_hosts:
        raise ValueError(f"host {host} outside fleet of {n_hosts}")
    lo = host * n_slots // n_hosts
    hi = (host + 1) * n_slots // n_hosts
    return list(range(lo, hi))


class Heartbeat:
    """Host liveness from periodic beats; ``check`` returns newly-dead hosts."""

    def __init__(self, hosts: Sequence[int], timeout_s: float):
        self.timeout_s = timeout_s
        self.last = {h: None for h in hosts}
        self.dead: set[int] = set()

    def beat(self, host: int, t: float):
        self.last[host] = t

    def check(self, now: float) -> list[int]:
        newly = []
        for h, t in self.last.items():
            if h in self.dead:
                continue
            if t is None or now - t > self.timeout_s:
                self.dead.add(h)
                newly.append(h)
        return sorted(newly)

    def alive(self) -> list[int]:
        return sorted(h for h in self.last if h not in self.dead)


@dataclasses.dataclass
class ElasticMesh:
    """Recompute the (data, model) mesh shape for a shrunken fleet.

    The model axis is pinned (weights are laid out for it); host loss only
    shrinks the data axis, dropping stragglers' chips from data parallelism.
    """
    model: int = 16
    chips_per_host: int = 4

    def shape_for(self, n_hosts: int) -> tuple[int, int]:
        chips = n_hosts * self.chips_per_host
        data = chips // self.model
        if data < 1:
            raise RuntimeError(
                f"{n_hosts} hosts x {self.chips_per_host} chips cannot fill "
                f"one model={self.model} slice")
        return (data, self.model)


class StragglerPolicy:
    """Flag hosts whose recent step time exceeds ``threshold`` x the fleet
    median (over a sliding ``window``, once ``min_samples`` recorded)."""

    def __init__(self, threshold: float = 1.3, window: int = 16,
                 min_samples: int = 8):
        self.threshold = threshold
        self.min_samples = min_samples
        self.times = defaultdict(lambda: deque(maxlen=window))

    def record(self, host: int, step_time_s: float):
        self.times[host].append(step_time_s)

    def stragglers(self) -> list[int]:
        means = {h: statistics.fmean(ts) for h, ts in self.times.items()
                 if len(ts) >= self.min_samples}
        if not means:
            return []
        med = statistics.median(means.values())
        return sorted(h for h, m in means.items() if m > self.threshold * med)
