"""Sharding-rules engine: parameter-path -> PartitionSpec mapping.

One declarative rule table maps every leaf of the train / serve state trees
onto the (data, model) mesh (launch/mesh.py):

* **column-parallel** weights ``(c_out, c_in)`` put c_out on ``model``; the
  c_in axis is FSDP-sharded on ``data`` only when the leaf is large enough
  (> ``fsdp_min_size`` elements) for the gather to amortize.
* **row-parallel** weights (``w_down``/``wo``/``out_proj`` — the projections
  whose *input* is already model-sharded) put c_in on ``model`` and FSDP
  c_out on ``data``.
* **MoE expert** stacks ``(L, E, c_out, c_in)`` put experts on ``model``
  (expert parallelism) and c_in on ``data``.
* **KV caches** ``(..., B, H, S, hd)`` put batch on ``data`` and heads on
  ``model`` (right-aligned so leading layer-stack axes replicate).
* everything that matches no rule (NAS gammas, norms, scales, biases,
  scalars) replicates.

Every assignment passes a **divisibility gate**: an axis whose extent the
mesh-axis size does not divide falls back to replicated on that axis (the
Megatron vocab-padding story makes the fallback rare in practice), and the
decision is recorded in ``self.decisions`` for ``explain()``.

``constrain`` is the in-model activation annotation: a no-op unless an
``activation_sharding(mesh)`` context is active, so pure-CPU tests and
single-device smoke runs never touch collectives.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Axis tokens used by the in-model ``constrain`` calls.
_AXIS_OF = {"D": "data", "M": "model", None: None}

_state = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh):
    """Enable ``constrain`` annotations for code run inside this context."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


@contextlib.contextmanager
def serving_mesh(ctx: "MeshContext"):
    """Enable serving-side mesh annotations (``replicate_serving`` and the
    QTensor TP/EP kernel routing) for code traced inside this context.

    Deliberately separate from ``activation_sharding``: the training-side
    ``constrain`` annotations in the shared attention core stay inert while
    the serving engine traces with a mesh.
    """
    prev = getattr(_state, "serving_ctx", None)
    _state.serving_ctx = ctx if (ctx is not None and ctx.is_active) else None
    try:
        yield
    finally:
        _state.serving_ctx = prev


def serving_ctx() -> Optional["MeshContext"]:
    """The active serving ``MeshContext``, or None outside ``serving_mesh``."""
    return getattr(_state, "serving_ctx", None)


def replicate_serving(x):
    """Pin ``x`` replicated across the active serving mesh.

    Identity when no ``serving_mesh`` context is active, so model code can
    annotate unconditionally — single-device serving traces are unchanged.
    Used on every f32-adjacent activation (attention views, router inputs)
    whose reduction order must not depend on the mesh.
    """
    ctx = serving_ctx()
    if ctx is None or x is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.replicated)


def constrain(x, *tokens):
    """Annotate intermediate ``x`` with a (data/model) layout.

    ``tokens`` are per-axis: "D" -> data, "M" -> model, None -> replicated.
    Outside an ``activation_sharding`` context this is the identity, so model
    code can annotate unconditionally.
    """
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return x
    assert len(tokens) == x.ndim, (tokens, x.shape)
    spec = []
    for tok, extent in zip(tokens, x.shape):
        ax = _AXIS_OF[tok]
        if ax is not None and extent % mesh.shape[ax] == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


@dataclasses.dataclass
class Decision:
    path: str
    shape: tuple
    spec: P
    note: str


# Names whose *input* axis is model-sharded (output of a column-parallel
# projection feeds them): shard c_in on model, c_out on data (FSDP).
_ROW_PARALLEL = ("w_down", "wo", "out_proj")
# Stacked MoE expert weights: (L, E, c_out, c_in).
_EXPERT = ("we_gate", "we_up", "we_down")
# KV-cache leaves: (stack..., B, H, S, hd).
_CACHE_LEAVES = ("k", "v", "ckv", "krope", "k_scale", "v_scale", "ckv_scale")


class ShardingRules:
    """Path-pattern -> PartitionSpec engine for one mesh."""

    def __init__(self, mesh: Mesh, fsdp: bool = True, moe_ep2d: bool = False,
                 kv_seq_shard: bool = False, fsdp_min_size: int = 1 << 20):
        self.mesh = mesh
        self.fsdp = fsdp
        self.moe_ep2d = moe_ep2d          # experts across model *and* data
        self.kv_seq_shard = kv_seq_shard  # shard cache seq axis on data
        self.fsdp_min_size = fsdp_min_size
        self.decisions: list[Decision] = []

    # Token-level axis size; tests monkeypatch this to simulate big meshes.
    def _axis_size(self, tok: str) -> int:
        return self.mesh.shape[_AXIS_OF[tok]]

    def _gate(self, tokens: Sequence[Optional[str]], shape, notes: list):
        """Divisibility gate: replicate any axis the mesh does not divide."""
        out = []
        for tok, extent in zip(tokens, shape):
            if tok is None:
                out.append(None)
                continue
            size = self._axis_size(tok)
            if extent % size:
                notes.append(f"dim {extent} % {_AXIS_OF[tok]}={size} != 0 "
                             f"-> replicate")
                out.append(None)
            else:
                out.append(_AXIS_OF[tok])
        return out

    def _leaf_tokens(self, path: str, shape) -> tuple[list, str]:
        """Raw (pre-gate) axis tokens for one leaf, plus the rule name."""
        parts = path.split("/")
        leaf = parts[-1]
        parent = parts[-2] if len(parts) > 1 else ""
        big = 1
        for d in shape:
            big *= d
        fsdp_on = self.fsdp and big >= self.fsdp_min_size

        in_cache = "caches" in parts or leaf in _CACHE_LEAVES
        if in_cache and len(shape) >= 4:
            # right-aligned (B, H, S, hd); leading stack axes replicate
            toks = [None] * (len(shape) - 4)
            toks += ["D", "M", "D" if self.kv_seq_shard else None, None]
            return toks, "kv-cache"

        is_weight = leaf in ("w", "packed", "scale", "embed") or \
            parent in _EXPERT or parent in _ROW_PARALLEL or \
            any(n in parts for n in ("lm_head", "embed"))
        if leaf in ("gamma", "delta", "aw", "ax") or len(shape) <= 1:
            return [None] * len(shape), "replicate (nas/small)"

        # MoE routers run their top-k in f32; sharding that GEMM changes
        # the CPU reduction order and breaks token-for-token parity, so the
        # (E, d) router weight always replicates.
        if leaf == "router":
            return [None] * len(shape), "replicate (f32 router determinism)"

        # QTensor (repro.api.qtensor) leaves: packed rows carry the deployed
        # output channels -> model axis; scales follow their rows.
        if "packed" in parts and len(shape) >= 2:
            return [None] * (len(shape) - 2) + ["M", None], "qtensor-packed"
        if ("scales" in parts or leaf == "scale") and len(shape) >= 1:
            # per-channel dequant steps: rows axis is LAST
            return [None] * (len(shape) - 1) + ["M"], "qtensor-scale"
        if "inv_perm" in parts:
            return [None] * len(shape), "replicate (perm)"

        # MoE expert stacks: (E, c_out, c_in) or (L, E, c_out, c_in)
        if any(n in parts for n in _EXPERT) and len(shape) >= 3:
            toks = [None] * (len(shape) - 3)
            toks += ["M", None, "D" if fsdp_on else None]
            if self.moe_ep2d:
                toks[-3] = "M"
            return toks, "moe-expert"

        if is_weight and len(shape) >= 2:
            row = any(n in parts for n in _ROW_PARALLEL)
            lead = [None] * (len(shape) - 2)
            if row:
                return lead + ["D" if fsdp_on else None, "M"], "row-parallel"
            return lead + ["M", "D" if fsdp_on else None], "column-parallel"

        return [None] * len(shape), "replicate (default)"

    def spec_for(self, path: str, shape) -> P:
        toks, rule = self._leaf_tokens(path, tuple(shape))
        notes: list[str] = []
        axes = self._gate(toks, shape, notes)
        spec = P(*axes)
        self.decisions.append(Decision(path, tuple(shape), spec,
                                       "; ".join([rule] + notes)))
        return spec

    def _fused_spec(self, path: str, name: str, shape, qt) -> P:
        """Sharding for a QTensor's fused ragged buffer / scale vector.

        The fused layout concatenates whole static-bit N-tiles, so the only
        legal shard boundary is a tile boundary:

        * tensor parallel (1-D / layer-stacked weights): shard the byte axis
          iff the tile schedule splits into ``model`` identical chunks
          (``quant_matmul.tp_chunk``) — each device then owns whole tiles
          and runs the same shard_map program;
        * expert parallel (expert-stacked weights): shard the leading E axis
          iff ``model`` divides E (the schedule is shared across experts);
        * otherwise replicate and record why.
        """
        from repro.kernels import quant_matmul as qm
        m = self._axis_size("M")
        axes = [None] * len(shape)
        note = "replicate (fused: model axis = 1)"
        if m > 1 and qt.tile_bits is not None:
            if qt.experts is not None:
                e_ax = len(shape) - 2
                if e_ax >= 0 and shape[e_ax] % m == 0:
                    axes[e_ax] = "model"
                    note = f"qtensor-fused-ep (E={shape[e_ax]} / model={m})"
                else:
                    note = f"replicate (fused: E !% model={m})"
            else:
                chunk = qm.tp_chunk(qt.tile_bits, m)
                if chunk is not None and shape[-1] % m == 0:
                    axes[-1] = "model"
                    note = f"qtensor-fused-tp (chunk={chunk})"
                else:
                    note = ("replicate (fused: tile schedule not periodic "
                            f"over model={m})")
        spec = P(*axes)
        self.decisions.append(Decision(f"{path}/{name}", tuple(shape), spec,
                                       note))
        return spec

    def qtensor_shardings(self, path: str, qt):
        """Per-leaf NamedShardings for one QTensor node (same pytree shape).

        Non-fused leaves route through the ordinary path rules; the fused
        ragged buffer and its scales get the tile-schedule-aware treatment
        of ``_fused_spec``.
        """
        flat, treedef = jax.tree_util.tree_flatten_with_path(qt)
        out = []
        for key_path, leaf in flat:
            sub = "/".join(_key_str(k) for k in key_path)
            name = _key_str(key_path[0]) if key_path else ""
            shape = tuple(getattr(leaf, "shape", ()))
            if name in ("fused_packed", "fused_scales"):
                spec = self._fused_spec(path, sub, shape, qt)
            else:
                # grouped buckets / permutations feed the jnp dequant GEMM,
                # whose f32 matmul is not shard-invariant on CPU — keep them
                # replicated so the mesh engine stays token-identical (the
                # fused leaves above are the sharded, shard_map-exact path)
                spec = P(*([None] * len(shape)))
                self.decisions.append(Decision(
                    f"{path}/{sub}", shape, spec,
                    "replicate (qtensor dequant path: f32 GEMM "
                    "determinism)"))
            out.append(NamedSharding(self.mesh, spec))
        return jax.tree_util.tree_unflatten(treedef, out)

    def tree_shardings(self, tree):
        """NamedSharding pytree matching ``tree`` (arrays or SDStructs).

        QTensor nodes are intercepted whole so their fused buffers can be
        sharded along the N-tile schedule (``qtensor_shardings``); plain
        array leaves map through ``spec_for`` as before.
        """
        try:
            from repro.api.qtensor import QTensor
        except Exception:                                  # pragma: no cover
            QTensor = ()

        def is_qt(node):
            return isinstance(node, QTensor) if QTensor else False

        def one(key_path, node):
            path = "/".join(_key_str(k) for k in key_path)
            if is_qt(node):
                return self.qtensor_shardings(path, node)
            shape = getattr(node, "shape", ())
            return NamedSharding(self.mesh, self.spec_for(path, shape))
        return jax.tree_util.tree_map_with_path(one, tree, is_leaf=is_qt)

    def serving_shardings(self, tree):
        """Deployment placement for the mesh serving engine.

        The serving contract is **token identity** with the single-device
        engine, so only operands whose sharded compute is provably
        bit-exact may shard: a QTensor's fused buffers (the shard_map
        integer kernels partition whole N-tiles / whole experts and are
        bitwise-identical to the unsharded launch).  Every other weight
        replicates — CPU f32/bf16 GEMMs are not shard-invariant, and a
        sharded norm scale or dequant bucket would silently re-shard the
        activations feeding them.
        """
        try:
            from repro.api.qtensor import QTensor
        except Exception:                                  # pragma: no cover
            QTensor = ()

        def is_qt(node):
            return isinstance(node, QTensor) if QTensor else False

        rep = NamedSharding(self.mesh, P())

        def one(key_path, node):
            path = "/".join(_key_str(k) for k in key_path)
            if is_qt(node):
                return self.qtensor_shardings(path, node)
            shape = tuple(getattr(node, "shape", ()))
            self.decisions.append(Decision(
                path, shape, P(), "replicate (serving token-identity)"))
            return rep
        return jax.tree_util.tree_map_with_path(one, tree, is_leaf=is_qt)

    def explain(self) -> str:
        lines = [f"{d.path}  {d.shape} -> {d.spec}   [{d.note}]"
                 for d in self.decisions]
        return "\n".join(lines)


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    if isinstance(k, jax.tree_util.FlattenedIndexKey):
        return str(k.key)
    return str(k)


class MeshContext:
    """One mesh handle threaded through the whole serving stack.

    ``mesh=None`` (the default everywhere) makes every method the identity:
    single-device serving runs exactly the pre-mesh code path, bit-for-bit,
    and nothing below ever touches a collective.

    With a live ``(data, model)`` mesh the context owns the placement
    contract:

    * ``put_params``      — weights via ``ShardingRules`` (QTensor-aware);
    * ``put_caches`` / ``constrain_caches`` — KV pools and page tables
      sharded along the slot/page axis (axis 1) on ``data``;
    * ``put_replicated`` / ``constrain_replicated`` — scheduler state,
      tokens, and sampling stay replicated;
    * ``data`` / ``model`` — axis sizes (1 when inactive), which double as
      the host count for the fault/drain story.
    """

    def __init__(self, mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None):
        if mesh is not None:
            names = tuple(mesh.axis_names)
            if "data" not in names or "model" not in names:
                raise ValueError(
                    f"serving mesh needs ('data', 'model') axes, got {names}")
        self.mesh = mesh
        self.rules = rules if rules is not None else (
            ShardingRules(mesh) if mesh is not None else None)

    @property
    def is_active(self) -> bool:
        return self.mesh is not None

    @property
    def data(self) -> int:
        return int(self.mesh.shape["data"]) if self.is_active else 1

    @property
    def model(self) -> int:
        return int(self.mesh.shape["model"]) if self.is_active else 1

    @property
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # -- placement (host-side device_put; identity when inactive) ----------
    def put_params(self, tree):
        if not self.is_active or tree is None:
            return tree
        return jax.device_put(tree, self.rules.serving_shardings(tree))

    def put_replicated(self, tree):
        if not self.is_active or tree is None:
            return tree
        rep = self.replicated
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, rep), tree)

    def cache_shardings(self, tree):
        """Axis 1 (the slot or physical-page axis of every cache leaf —
        dense rings, paged pools, page tables alike) on ``data`` when
        divisible; replicated otherwise."""
        d = self.data

        def one(leaf):
            shape = tuple(getattr(leaf, "shape", ()))
            if len(shape) >= 2 and d > 1 and shape[1] % d == 0:
                return NamedSharding(self.mesh, P(None, "data"))
            return self.replicated
        return jax.tree_util.tree_map(one, tree)

    def put_caches(self, tree):
        if not self.is_active or tree is None:
            return tree
        return jax.device_put(tree, self.cache_shardings(tree))

    # -- trace-time constraints (identity when inactive) --------------------
    def constrain_caches(self, tree):
        if not self.is_active or tree is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, self.cache_shardings(tree))

    def constrain_replicated(self, tree):
        if not self.is_active or tree is None:
            return tree
        rep = self.replicated
        return jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(x, rep), tree)


def batch_specs(mesh: Mesh, batch):
    """Data-parallel shardings for one host batch: leading axis on ``data``
    when divisible, else replicated."""
    data = mesh.shape["data"]

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) >= 1 and shape[0] % data == 0:
            return NamedSharding(mesh, P("data", *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(shape))))
    return jax.tree_util.tree_map(one, batch)
