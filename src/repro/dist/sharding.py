"""Sharding-rules engine: parameter-path -> PartitionSpec mapping.

One declarative rule table maps every leaf of the train / serve state trees
onto the (data, model) mesh (launch/mesh.py):

* **column-parallel** weights ``(c_out, c_in)`` put c_out on ``model``; the
  c_in axis is FSDP-sharded on ``data`` only when the leaf is large enough
  (> ``fsdp_min_size`` elements) for the gather to amortize.
* **row-parallel** weights (``w_down``/``wo``/``out_proj`` — the projections
  whose *input* is already model-sharded) put c_in on ``model`` and FSDP
  c_out on ``data``.
* **MoE expert** stacks ``(L, E, c_out, c_in)`` put experts on ``model``
  (expert parallelism) and c_in on ``data``.
* **KV caches** ``(..., B, H, S, hd)`` put batch on ``data`` and heads on
  ``model`` (right-aligned so leading layer-stack axes replicate).
* everything that matches no rule (NAS gammas, norms, scales, biases,
  scalars) replicates.

Every assignment passes a **divisibility gate**: an axis whose extent the
mesh-axis size does not divide falls back to replicated on that axis (the
Megatron vocab-padding story makes the fallback rare in practice), and the
decision is recorded in ``self.decisions`` for ``explain()``.

``constrain`` is the in-model activation annotation: a no-op unless an
``activation_sharding(mesh)`` context is active, so pure-CPU tests and
single-device smoke runs never touch collectives.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Axis tokens used by the in-model ``constrain`` calls.
_AXIS_OF = {"D": "data", "M": "model", None: None}

_state = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh):
    """Enable ``constrain`` annotations for code run inside this context."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def constrain(x, *tokens):
    """Annotate intermediate ``x`` with a (data/model) layout.

    ``tokens`` are per-axis: "D" -> data, "M" -> model, None -> replicated.
    Outside an ``activation_sharding`` context this is the identity, so model
    code can annotate unconditionally.
    """
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return x
    assert len(tokens) == x.ndim, (tokens, x.shape)
    spec = []
    for tok, extent in zip(tokens, x.shape):
        ax = _AXIS_OF[tok]
        if ax is not None and extent % mesh.shape[ax] == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


@dataclasses.dataclass
class Decision:
    path: str
    shape: tuple
    spec: P
    note: str


# Names whose *input* axis is model-sharded (output of a column-parallel
# projection feeds them): shard c_in on model, c_out on data (FSDP).
_ROW_PARALLEL = ("w_down", "wo", "out_proj")
# Stacked MoE expert weights: (L, E, c_out, c_in).
_EXPERT = ("we_gate", "we_up", "we_down")
# KV-cache leaves: (stack..., B, H, S, hd).
_CACHE_LEAVES = ("k", "v", "ckv", "krope", "k_scale", "v_scale", "ckv_scale")


class ShardingRules:
    """Path-pattern -> PartitionSpec engine for one mesh."""

    def __init__(self, mesh: Mesh, fsdp: bool = True, moe_ep2d: bool = False,
                 kv_seq_shard: bool = False, fsdp_min_size: int = 1 << 20):
        self.mesh = mesh
        self.fsdp = fsdp
        self.moe_ep2d = moe_ep2d          # experts across model *and* data
        self.kv_seq_shard = kv_seq_shard  # shard cache seq axis on data
        self.fsdp_min_size = fsdp_min_size
        self.decisions: list[Decision] = []

    # Token-level axis size; tests monkeypatch this to simulate big meshes.
    def _axis_size(self, tok: str) -> int:
        return self.mesh.shape[_AXIS_OF[tok]]

    def _gate(self, tokens: Sequence[Optional[str]], shape, notes: list):
        """Divisibility gate: replicate any axis the mesh does not divide."""
        out = []
        for tok, extent in zip(tokens, shape):
            if tok is None:
                out.append(None)
                continue
            size = self._axis_size(tok)
            if extent % size:
                notes.append(f"dim {extent} % {_AXIS_OF[tok]}={size} != 0 "
                             f"-> replicate")
                out.append(None)
            else:
                out.append(_AXIS_OF[tok])
        return out

    def _leaf_tokens(self, path: str, shape) -> tuple[list, str]:
        """Raw (pre-gate) axis tokens for one leaf, plus the rule name."""
        parts = path.split("/")
        leaf = parts[-1]
        parent = parts[-2] if len(parts) > 1 else ""
        big = 1
        for d in shape:
            big *= d
        fsdp_on = self.fsdp and big >= self.fsdp_min_size

        in_cache = "caches" in parts or leaf in _CACHE_LEAVES
        if in_cache and len(shape) >= 4:
            # right-aligned (B, H, S, hd); leading stack axes replicate
            toks = [None] * (len(shape) - 4)
            toks += ["D", "M", "D" if self.kv_seq_shard else None, None]
            return toks, "kv-cache"

        is_weight = leaf in ("w", "packed", "scale", "embed", "router") or \
            parent in _EXPERT or parent in _ROW_PARALLEL or \
            any(n in parts for n in ("lm_head", "embed"))
        if leaf in ("gamma", "delta", "aw", "ax") or len(shape) <= 1:
            return [None] * len(shape), "replicate (nas/small)"

        # QTensor (repro.api.qtensor) leaves: packed rows carry the deployed
        # output channels -> model axis; scales follow their rows.
        if "packed" in parts and len(shape) >= 2:
            return [None] * (len(shape) - 2) + ["M", None], "qtensor-packed"
        if ("scales" in parts or leaf == "scale") and len(shape) >= 1:
            # per-channel dequant steps: rows axis is LAST
            return [None] * (len(shape) - 1) + ["M"], "qtensor-scale"
        if "inv_perm" in parts:
            return [None] * len(shape), "replicate (perm)"

        # MoE expert stacks: (E, c_out, c_in) or (L, E, c_out, c_in)
        if any(n in parts for n in _EXPERT) and len(shape) >= 3:
            toks = [None] * (len(shape) - 3)
            toks += ["M", None, "D" if fsdp_on else None]
            if self.moe_ep2d:
                toks[-3] = "M"
            return toks, "moe-expert"

        if is_weight and len(shape) >= 2:
            row = any(n in parts for n in _ROW_PARALLEL)
            lead = [None] * (len(shape) - 2)
            if row:
                return lead + ["D" if fsdp_on else None, "M"], "row-parallel"
            return lead + ["M", "D" if fsdp_on else None], "column-parallel"

        return [None] * len(shape), "replicate (default)"

    def spec_for(self, path: str, shape) -> P:
        toks, rule = self._leaf_tokens(path, tuple(shape))
        notes: list[str] = []
        axes = self._gate(toks, shape, notes)
        spec = P(*axes)
        self.decisions.append(Decision(path, tuple(shape), spec,
                                       "; ".join([rule] + notes)))
        return spec

    def tree_shardings(self, tree):
        """NamedSharding pytree matching ``tree`` (arrays or SDStructs)."""
        def one(key_path, leaf):
            path = "/".join(_key_str(k) for k in key_path)
            shape = getattr(leaf, "shape", ())
            return NamedSharding(self.mesh, self.spec_for(path, shape))
        return jax.tree_util.tree_map_with_path(one, tree)

    def explain(self) -> str:
        lines = [f"{d.path}  {d.shape} -> {d.spec}   [{d.note}]"
                 for d in self.decisions]
        return "\n".join(lines)


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    if isinstance(k, jax.tree_util.FlattenedIndexKey):
        return str(k.key)
    return str(k)


def batch_specs(mesh: Mesh, batch):
    """Data-parallel shardings for one host batch: leading axis on ``data``
    when divisible, else replicated."""
    data = mesh.shape["data"]

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) >= 1 and shape[0] % data == 0:
            return NamedSharding(mesh, P("data", *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(shape))))
    return jax.tree_util.tree_map(one, batch)
