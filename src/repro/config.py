"""Architecture / run configuration system.

``ArchConfig`` describes one architecture from the assigned pool (exact
hyper-parameters from public literature — see src/repro/configs/*.py) plus the
mixed-precision search and deployment settings.  Every config is selectable by
``--arch <id>`` in the launchers.

``reduced()`` produces the CPU-smoke-test variant of the same family (few
layers, narrow width, tiny vocab, few experts) — the FULL configs are only
ever lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.core import mixedprec as mp


@dataclasses.dataclass(frozen=True)
class DeploySpec:
    """Static per-precision channel-group fractions for the deployed model.

    The true fractions come out of the Alg. 1 search; the dry-run and the
    serving benchmarks need *static* shapes, so configs pin a representative
    assignment (defaults follow the paper's Fig. 4: most channels at 4b, a
    small high-precision slice, the rest at 2b).  Group sizes are rounded to
    ``align`` (MXU lane width) with upward promotion (core/deploy.py).
    """
    fractions: tuple[float, ...] = (0.25, 0.55, 0.20)   # ordered as weight_bits
    align: int = 128
    act_bits: int = 8
    kv_cache_bits: int = 8   # layer-wise act quant applied to the KV cache

    def group_sizes(self, c_out: int, bitwidths: Sequence[int]) -> dict[int, int]:
        """Integer group sizes: aligned, upward-promoted, summing to c_out."""
        assert len(self.fractions) == len(bitwidths)
        align = min(self.align, c_out)
        sizes, used = {}, 0
        for frac, b in list(zip(self.fractions, bitwidths))[:-1]:
            n = int(round(frac * c_out / align) * align)
            n = max(0, min(n, c_out - used))
            sizes[b] = n
            used += n
        sizes[bitwidths[-1]] = c_out - used   # highest precision absorbs rest
        return sizes


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    mlp_type: str = "swiglu"         # swiglu | gelu
    qkv_bias: bool = False           # qwen1.5
    rope_partial: float = 1.0        # fraction of head_dim with RoPE (chatglm 2d-rope: 0.5)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0        # deepseek shared expert
    moe_d_ff: int = 0                # per-expert hidden dim
    dense_residual_ff: int = 0       # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    mtp: bool = False                # deepseek multi-token-prediction head

    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: one (shared) attn block every k layers

    # enc-dec (whisper)
    is_encdec: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper 30s @ 50Hz after conv frontend (stub)

    # modality frontend stub
    frontend: str = "none"           # none | vision | audio
    n_prefix_tokens: int = 0         # vlm: patch embeddings prepended

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # accumulation dtype of TP-sharded matmuls ("" = backend default f32).
    # "bfloat16" halves the partial-sum all-reduce bytes (the dominant
    # collective in dense train cells) at the cost of bf16 accumulation —
    # a §Perf knob, off by default.
    partial_dtype: str = ""

    # training-system hints (per-arch defaults consumed by launch/train.py)
    optimizer: str = "adamw"         # adamw | adafactor (factored 2nd moment,
                                     # no 1st moment — what lets 671B/480B
                                     # optimizer state fit 16 GB/chip)
    lr_schedule: str = "cosine"      # cosine | wsd (minicpm) | constant

    # Megatron-style vocab padding: the *physical* embedding/lm_head rows are
    # rounded up to a multiple of ``vocab_pad`` so the vocab axis shards
    # evenly over the model axis and stays MXU-lane aligned; padded logits
    # are masked to -inf before the loss.  0 disables padding.
    vocab_pad: int = 256

    # mixed-precision search + deployment
    quant: mp.MixedPrecConfig = dataclasses.field(default_factory=mp.MixedPrecConfig)
    deploy: DeploySpec = dataclasses.field(default_factory=DeploySpec)

    # which shapes this arch supports (see launch/shapes.py)
    supports_decode: bool = True
    supports_long: bool = False      # sub-quadratic only
    long_skip_reason: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        if not self.vocab_pad:
            return self.vocab_size
        p = self.vocab_pad
        return -(-self.vocab_size // p) * p

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        def shrink(v, lo, cap):
            return max(lo, min(v, cap))
        kw = dict(
            n_layers=shrink(self.n_layers, 2, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=shrink(self.n_experts, 0, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            moe_d_ff=32 if self.moe_d_ff else 0,
            dense_residual_ff=64 if self.dense_residual_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            q_lora_rank=24 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            qk_nope_dim=8 if self.qk_nope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            encoder_seq=16 if self.is_encdec else 1500,
            n_prefix_tokens=4 if self.n_prefix_tokens else 0,
            deploy=DeploySpec(fractions=self.deploy.fractions, align=8,
                              act_bits=self.deploy.act_bits,
                              kv_cache_bits=self.deploy.kv_cache_bits),
        )
        return dataclasses.replace(self, **kw)


# Registry -------------------------------------------------------------------

ARCH_IDS = (
    "phi-3-vision-4.2b",
    "stablelm-12b",
    "minicpm-2b",
    "chatglm3-6b",
    "qwen1.5-4b",
    "whisper-small",
    "zamba2-1.2b",
    "deepseek-v3-671b",
    "arctic-480b",
    "mamba2-780m",
)

TINYML_IDS = ("resnet8-cifar10", "dscnn-kws", "mobilenetv1-vww", "dae-ad")

_MODULE_FOR = {i: "repro.configs." + i.replace("-", "_").replace(".", "_")
               for i in ARCH_IDS + TINYML_IDS}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(_MODULE_FOR[arch_id])
    return mod.CONFIG


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS
