"""repro.qtrain — int8 quantized-compute training.

The search/finetune phases' answer to the packed serving kernels: the three
matmuls of every linear (forward ``x @ w^T``, grad-input ``dy @ w``,
grad-weight ``dy^T @ x``) run as dynamic int8 GEMMs
(kernels/int8_matmul.py) behind a ``custom_vjp``, switched per-leg by
:class:`QTrainConfig` and enabled model-wide through
``PrecisionPolicy.train_compute``.
"""
from repro.qtrain.linear import QTrainConfig, int8_linear

__all__ = ["QTrainConfig", "int8_linear"]
