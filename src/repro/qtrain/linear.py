"""``int8_linear`` — a ``custom_vjp`` linear whose three matmuls each run on
dynamic int8 compute (the gau-nernst/quant-train mixed-precision recipe).

For ``y = x @ w^T`` with ``x (..., K)`` and ``w (N, K)`` the backward pass
needs two more GEMMs:

    dx = dy @ w          (contract N)     — "grad_input"
    dw = dy^T @ x        (contract M)     — "grad_weight"

:class:`QTrainConfig` switches each of the three independently to int8
(both operands dynamically quantized per row of the contraction axis,
int8 x int8 -> int32, fused dequant — ``kernels/int8_matmul.py``); a leg
that is switched off runs the plain f32 einsum.

Rounding: the forward quantizes deterministically (round-to-nearest — the
forward wants the lowest per-step error, and determinism keeps serving-side
parity checks meaningful).  The **backward** quantizations use stochastic
rounding when a PRNG ``key`` is supplied: gradient noise must be unbiased
*across steps* for SGD-style averaging to converge, and round-to-nearest
of near-constant operands introduces a systematic bias SR removes.  Each of
the four backward quantizations (dy and w for grad-input; dy and x for
grad-weight) folds its own subkey, so their rounding noises are
independent.  ``key=None`` degrades every leg to deterministic rounding.

The config is a ``nondiff_argnums`` argument (hashable frozen dataclass);
the key rides through the vjp as a regular primal whose cotangent is the
mandatory float0 zero for integer-typed primals.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import int8_matmul as im


@dataclasses.dataclass(frozen=True)
class QTrainConfig:
    """Which of the linear's three matmuls run on int8 compute."""
    forward: bool = True
    grad_input: bool = True
    grad_weight: bool = True
    stochastic_rounding: bool = True
    backend: str = "pallas"          # pallas | jnp (bitwise-identical)


DEFAULT = QTrainConfig()


def _flat(x: jnp.ndarray):
    """(..., K) -> (M, K) f32 plus the leading shape."""
    lead = x.shape[:-1]
    M = 1
    for d in lead:
        M *= d
    return x.reshape(M, x.shape[-1]).astype(jnp.float32), lead


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def int8_linear(x: jnp.ndarray, w: jnp.ndarray, key=None,
                cfg: QTrainConfig = DEFAULT) -> jnp.ndarray:
    """``x (..., K) @ w (N, K)^T -> (..., N)`` on int8 training compute.

    Output is f32 (the dequant epilogue's dtype); callers cast to their
    compute dtype.  ``key`` seeds the backward stochastic rounding.
    """
    y, _ = _fwd(x, w, key, cfg)
    return y


def _fwd(x, w, key, cfg: QTrainConfig):
    x2, lead = _flat(x)
    if cfg.forward:
        qx, sx = im.rowwise_quantize(x2)
        qw, sw = im.rowwise_quantize(w)
        y = im.scaled_int8_mm(qx, qw, sx, sw, backend=cfg.backend)
    else:
        y = jnp.einsum("mk,nk->mn", x2, w.astype(jnp.float32))
    return y.reshape(*lead, w.shape[0]), (x, w, key)


def _subkeys(cfg: QTrainConfig, key):
    if key is None or not cfg.stochastic_rounding:
        return (None,) * 4
    return tuple(jax.random.fold_in(key, i) for i in range(4))


def _bwd(cfg: QTrainConfig, res, dy):
    x, w, key = res
    x2, _ = _flat(x)
    dy2, _ = _flat(dy)
    w32 = w.astype(jnp.float32)
    k_di_dy, k_di_w, k_dw_dy, k_dw_x = _subkeys(cfg, key)

    if cfg.grad_input:                      # dx = dy (M,N) @ w (N,K)
        qd, sd = im.rowwise_quantize(dy2, k_di_dy)
        qwt, swt = im.rowwise_quantize(w32.T, k_di_w)   # (K, N): rows over N
        dx2 = im.scaled_int8_mm(qd, qwt, sd, swt, backend=cfg.backend)
    else:
        dx2 = jnp.einsum("mn,nk->mk", dy2, w32)

    if cfg.grad_weight:                     # dw = dy^T (N,M) @ x (M,K)
        qdt, sdt = im.rowwise_quantize(dy2.T, k_dw_dy)  # (N, M): rows over M
        qxt, sxt = im.rowwise_quantize(x2.T, k_dw_x)    # (K, M): rows over M
        dw = im.scaled_int8_mm(qdt, qxt, sdt, sxt, backend=cfg.backend)
    else:
        dw = jnp.einsum("mn,mk->nk", dy2, x2)

    dx = dx2.reshape(x.shape).astype(x.dtype)
    dkey = None if key is None else np.zeros(key.shape, jax.dtypes.float0)
    return dx, dw.astype(w.dtype), dkey


int8_linear.defvjp(_fwd, _bwd)
