"""deepseek-v3-671b [moe] — 61L d_model=7168 128H MLA, MoE 256 routed
experts top-8 + 1 shared, expert d_ff=2048, vocab=129280, MTP
[arXiv:2412.19437].

MLA dims from the paper: q_lora_rank=1536, kv_lora_rank=512, qk_nope=128,
qk_rope=64, v_head=128.  The reference model keeps the first 3 layers dense;
we model the uniform-MoE stack (noted in DESIGN.md §Arch-applicability) so
the layer stack scans.

System hints: bf16 params + Adafactor (factored second moment, no first
moment) — with AdamW-fp32 the 671B training state cannot fit 256x16 GB; with
this setting params+grads+opt ≈ 2.8 TB, under the 4 TB single-pod HBM.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    # MoE
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    mtp=True,
    # MLA
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    head_dim=192,                    # qk_nope + qk_rope
    # numerics / system
    param_dtype="bfloat16",
    optimizer="adafactor",
    supports_long=False,
    long_skip_reason="full O(S^2) attention (MLA)",
)
