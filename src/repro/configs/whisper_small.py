"""whisper-small [audio] — enc-dec, 12L enc + 12L dec, d_model=768 12H
d_ff=3072 vocab=51865 [arXiv:2212.04356].

The conv frontend (2x Conv1d over 80-mel spectrograms -> 1500 frames @ 50Hz)
is a STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings (B, 1500, 768).  Whisper uses GELU MLPs, LayerNorm, and fixed
sinusoidal positions (no RoPE) — handled by the ``audio`` family path.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,                     # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    mlp_type="gelu",
    norm="layernorm",
    is_encdec=True,
    n_encoder_layers=12,
    encoder_seq=1500,                # 30 s audio @ 50 Hz after conv stub
    frontend="audio",
    supports_long=False,
    long_skip_reason="enc-dec with full attention; 524k decode out of scope",
)
