"""dae-ad — one of the paper's four MLPerf Tiny benchmark models (Sec. IV-A).

Config lives in models/tinyml.py (TinyConfig); re-exported here so
``--arch dae-ad`` resolves through the same registry as the LM archs.
"""
from repro.models.tinyml import TINY_CONFIGS

CONFIG = TINY_CONFIGS["dae-ad"]
