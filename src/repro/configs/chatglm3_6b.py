"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 [arXiv:2406.12793].

2D-RoPE: rotation applied to half of each head's dims (rope_partial=0.5);
QKV projections carry bias (add_qkv_bias=True in the reference impl).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_partial=0.5,
    rope_theta=10000.0,
    qkv_bias=True,
    supports_long=False,
    long_skip_reason="full O(S^2) attention",
)
