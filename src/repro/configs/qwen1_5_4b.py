"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20 = MHA) d_ff=6912
vocab=151936 [hf:Qwen/Qwen1.5-4B family].

Qwen signature: bias on the QKV projections only (qkv_bias=True).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    qkv_bias=True,
    supports_long=False,
    long_skip_reason="full O(S^2) attention",
)
