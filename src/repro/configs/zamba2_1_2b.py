"""zamba2-1.2b [hybrid] — 38L Mamba2 backbone + one SHARED attention block,
d_model=2048 32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242].

Zamba2 interleaves a single shared (weight-tied) attention+MLP block every
few Mamba2 layers; we apply it every ``attn_every=6`` layers.  Sub-quadratic
overall -> runs the long_500k cell.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    attn_every=6,
    supports_long=True,
)
