"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36 = MHA) d_ff=5760
vocab=122753 [arXiv:2404.06395].

Llama-like architecture; the paper's WSD (warmup-stable-decay) schedule is
implemented in optim/optimizers.py and selected via ``lr_schedule="wsd"``.
The odd vocab (122753) exercises the Megatron-style vocab padding path
(padded to 122880 so the vocab axis shards over model=16 and stays
MXU-aligned).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    lr_schedule="wsd",
    supports_long=False,
    long_skip_reason="full O(S^2) attention",
)
