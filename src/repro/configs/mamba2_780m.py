"""mamba2-780m [ssm] — 48L d_model=1536, attention-free SSD (state-space
duality), ssm_state=128, vocab=50280 [arXiv:2405.21060].

d_inner = 2*d_model = 3072, 48 SSD heads of dim 64.  Attention-free ->
runs the long_500k cell (state is O(1) in sequence length at decode).
The paper's channel-wise technique applies to in_proj/out_proj (the two
linears that dominate params); the SSD recurrence itself stays bf16
(DESIGN.md §Arch-applicability).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    supports_long=True,
)
