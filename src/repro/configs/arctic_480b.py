"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8), MoE 128 experts
top-2 with expert d_ff=4864, PLUS a dense residual MLP in parallel,
vocab=32000 [hf:Snowflake/snowflake-arctic-base].

Arctic's dense-MoE hybrid: every block computes dense_MLP(x) + MoE(x).
Same big-model system hints as deepseek (bf16 params + Adafactor).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    n_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual_ff=4864,
    param_dtype="bfloat16",
    optimizer="adafactor",
    supports_long=False,
    long_skip_reason="full O(S^2) attention",
)
