"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stub).

32L d_model=3072 32H (GQA kv=32 = MHA) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct].  The vision frontend (CLIP
ViT-L/14 @ 336px -> 576 patch embeddings) is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings that replace the
first ``n_prefix_tokens`` token embeddings.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    frontend="vision",
    n_prefix_tokens=576,            # CLIP ViT-L/14 @ 336px patch count
    supports_long=False,
    long_skip_reason="full O(S^2) attention; 524k decode KV fits but the "
                     "paper pool marks full-attention archs skip for long_500k",
)
