"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-12b family].

StableLM-2 uses LayerNorm (no bias on projections), gated SiLU MLP and
partial rotary embeddings (rotary_pct = 0.25).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    mlp_type="swiglu",
    norm="layernorm",
    rope_partial=0.25,
    rope_theta=10000.0,
    supports_long=False,
    long_skip_reason="full O(S^2) attention",
)
