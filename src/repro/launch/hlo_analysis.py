"""Roofline-term extraction from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds-per-step *per chip*
(the compiled module is the post-SPMD per-device program, so every quantity
below is already per-chip):

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = collective_bytes / ICI_bw         (~50 GB/s/link)

``cost_analysis()`` provides HLO_FLOPs and HLO_bytes.  Collective bytes are
NOT in cost_analysis, so we parse the compiled HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction we sum operand sizes (first pass builds an instr->shape table so
operand references resolve).  Ring-algorithm accounting: an all-reduce moves
~2x its operand bytes over the slowest link (reduce-scatter + all-gather
phases); the others move ~1x their max(operand, result).

The functions are backend-agnostic: on the CPU dry-run host they analyse the
partitioned module exactly as a TPU compile would produce it (same SPMD
pass), only the backend codegen differs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "%name = <shape(s)> op-name(" — shape may be a (tuple, of, shapes)
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
                       r"([\w\-]+)\(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of one HLO shape string (handles tuple shapes)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_moved: float = 0.0
    counts: dict = dataclasses.field(default_factory=dict)
    bytes_by_op: dict = dataclasses.field(default_factory=dict)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum data moved by collectives in a (per-device) HLO module text."""
    # pass 1: instruction name -> result shape string
    shapes: dict[str, str] = {}
    instrs: list[tuple[str, str, str, str]] = []  # (name, shape, op, line)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        shapes[name] = shape_str
        base_op = op.rstrip(".0123456789")
        if base_op.endswith("-start"):
            base_op = base_op[:-len("-start")]
        if base_op in _COLLECTIVES:
            instrs.append((name, shape_str, base_op, line))

    stats = CollectiveStats()
    for name, shape_str, op, line in instrs:
        # operand bytes: resolve %refs inside the parens
        args = re.findall(r"%([\w.\-]+)", line.split("(", 1)[1])
        operand_b = sum(shape_bytes(shapes.get(a, "")) for a in args
                        if a in shapes)
        result_b = shape_bytes(shape_str)
        moved = max(operand_b, result_b)
        if op == "all-reduce":
            moved = 2 * max(operand_b, result_b)   # RS + AG phases of a ring
        stats.bytes_moved += moved
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + moved
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    collective_counts: dict

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(compiled, hlo_text: Optional[str] = None) -> Roofline:
    """Three roofline terms from a compiled (per-device) executable.

    FLOPs / bytes / collective bytes come from the scan-aware HLO walk
    (launch/hlo_costs.py) — XLA's own cost_analysis counts while bodies
    once, under-counting scanned-layer models by ~L x (verified; see
    EXPERIMENTS.md §Roofline methodology).
    """
    from repro.launch import hlo_costs
    text = hlo_text if hlo_text is not None else compiled.as_text()
    costs = hlo_costs.analyze(text)
    terms = {
        "compute": costs.flops / PEAK_FLOPS_BF16,
        "memory": costs.mem_bytes / HBM_BW,
        "collective": costs.coll_bytes / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get)
    return Roofline(flops=costs.flops, hbm_bytes=costs.mem_bytes,
                    collective_bytes=costs.coll_bytes,
                    compute_s=terms["compute"], memory_s=terms["memory"],
                    collective_s=terms["collective"], bottleneck=bottleneck,
                    collective_counts=dict(costs.coll_by_op))


def model_flops_per_step(n_params_active: int, tokens: int,
                         kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D for training (fwd+bwd), 2·N·D for inference."""
    mult = 6 if kind == "train" else 2
    return float(mult) * n_params_active * tokens
