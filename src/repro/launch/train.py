"""Production training launcher: Alg. 1 over the LM-family archs with pjit
distribution, checkpoint/restart, and straggler-aware supervision.

On the CPU host this runs REDUCED configs end-to-end (same code path as
production, 1-device mesh); on real hardware the same entrypoint runs the
full configs on the (data, model) production mesh — only ``--mesh`` differs.

Phases per Alg. 1 (Sec. III-B): warmup (QAT@8b) -> search (theta on 20% /
W on 80% per epoch, tau annealed) -> fine-tune (argmax frozen).  The search
is the paper's workload; checkpointing captures the full state pytree
(params, NAS logits, both optimizer states, tau, step) plus the data
pipeline position so restart is bit-exact.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --reduced \
      --steps 30 --seq 128 --batch 8 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.policy import PrecisionPolicy
from repro.config import ARCH_IDS, get_config
from repro.core import mixedprec as mp
from repro.data import pipeline as pipe
from repro.dist import sharding as shd
from repro.launch.mesh import make_test_mesh, make_production_mesh
from repro.models import transformer as tfm
from repro.train import checkpoint as ckpt_mod
from repro.train import steps as steps_mod


def build_batch_iter(cfg, seq: int, global_batch: int, seed: int = 0):
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = (cfg.encoder_seq, cfg.d_model)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        extra["prefix_embeds"] = (cfg.n_prefix_tokens, cfg.d_model)
    return pipe.SyntheticLM(cfg.vocab_size, seq, global_batch, seed=seed,
                            extra=extra)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    p.add_argument("--reduced", action="store_true",
                   help="CPU-sized variant of the same family")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--warmup-steps", type=int, default=5)
    p.add_argument("--theta-every", type=int, default=5,
                   help="1 theta step per N W steps (the 20/80 split)")
    p.add_argument("--anneal-every", type=int, default=10,
                   help="steps per 'epoch' for tau annealing")
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--lam", type=float, default=1e-10)
    p.add_argument("--objective", default="size", choices=["size", "energy"])
    p.add_argument("--train-compute", default="f32",
                   choices=["f32", "bf16", "int8"],
                   help="matmul arithmetic of the training phases (int8 = "
                        "dynamic int8 GEMMs with stochastically rounded "
                        "backward, repro.qtrain)")
    p.add_argument("--sr-seed", type=int, default=0,
                   help="base seed of the int8 stochastic rounding")
    p.add_argument("--lut", default="tpu_bw", choices=["tpu_bw", "mpic"])
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--production-mesh", action="store_true",
                   help="use the 16x16 mesh (requires 256 devices)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    hp = steps_mod.TrainHParams.for_arch(
        cfg, lr=args.lr, lam=args.lam, objective=args.objective,
        lut_name=args.lut, warmup_steps=min(args.warmup_steps, 100),
        total_steps=args.steps, train_compute=args.train_compute,
        sr_seed=args.sr_seed)
    print("resolved policy:",
          steps_mod._train_policy(
              hp, PrecisionPolicy.search(cfg.quant.tau0),
              jnp.zeros((), jnp.int32)),
          f"(search phase; opt_state_dtype={hp.opt_state_dtype})")

    mesh = (make_production_mesh() if args.production_mesh
            else make_test_mesh())
    rules = shd.ShardingRules(mesh)

    state = steps_mod.init_train_state(cfg, hp, jax.random.PRNGKey(args.seed))
    state_sh = rules.tree_shardings(state)
    state = jax.device_put(state, state_sh)

    data = build_batch_iter(cfg, args.seq, args.batch, seed=args.seed)

    mgr = None
    if args.ckpt_dir:
        mgr = ckpt_mod.CheckpointManager(args.ckpt_dir)
        if args.resume:
            restored, step0, meta = mgr.restore_latest(state, state_sh)
            if restored is not None:
                state = restored
                data.state.step = int(meta.get("data_step", 0))
                print(f"resumed from step {step0}")

    warm = jax.jit(steps_mod.make_qat_warmup_step(cfg, hp),
                   in_shardings=(state_sh, shd.batch_specs(
                       mesh, next(iter([data._gen(0)])))),
                   donate_argnums=(0,))
    train = jax.jit(steps_mod.make_train_step(cfg, hp), donate_argnums=(0,))
    theta = jax.jit(steps_mod.make_theta_step(cfg, hp,
                                              args.seq * args.batch),
                    donate_argnums=(0,))

    t_start = time.time()
    it = iter(data)
    step = int(state["step"])
    while step < args.steps:
        batch = next(it)
        t0 = time.time()
        if step < hp.warmup_steps:
            state, metrics = warm(state, batch)
            phase = "warmup"
        elif step % args.theta_every == 0:
            state, metrics = theta(state, batch)
            phase = "theta"
        else:
            state, metrics = train(state, batch)
            phase = "W"
        step = int(state["step"])
        if step % args.anneal_every == 0:
            state = steps_mod.anneal_epoch(state, cfg)
        if step % 5 == 0 or step == args.steps:
            extras = {k: float(v) for k, v in metrics.items()}
            print(f"step {step:5d} [{phase:6s}] "
                  + " ".join(f"{k}={v:.4f}" for k, v in extras.items())
                  + f" tau={float(state['tau']):.3f}"
                  + f" dt={time.time() - t0:.2f}s", flush=True)
        if mgr and step % args.ckpt_every == 0:
            mgr.save(step, state, meta={"data_step": data.state.step,
                                        "arch": cfg.name})
    if mgr:
        mgr.save(args.steps, state, meta={"data_step": data.state.step,
                                          "arch": cfg.name}, block=True)
    print(f"done: {args.steps} steps in {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
