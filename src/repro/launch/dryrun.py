import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes, and record memory/cost/collective analysis.

The two lines above MUST stay the first statements in this file: jax locks
the device count on first init, and the production meshes need 512
placeholder devices on the CPU dry-run host.  Nothing else in the repo sets
this flag — smoke tests and benches see the 1 real device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""
import argparse
import json
import time
import traceback

import jax

from repro.config import ARCH_IDS, get_config
from repro.launch import hlo_analysis as ha
from repro.launch import workloads as wk
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cells
from repro.models import transformer as tfm
from repro.train import steps as steps_mod


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the model's shape tree."""
    params, _ = jax.eval_shape(
        lambda: tfm.init_model(cfg, jax.random.PRNGKey(0)))
    total = active = 0
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        pathstr = "/".join(str(getattr(p, "key", p)) for p in path)
        if pathstr.endswith("/aw") or pathstr.endswith("/ax"):
            continue
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.n_experts and "/we_" in pathstr:
            active += n * cfg.experts_per_token // cfg.n_experts
        else:
            active += n
    return total, active


def run_cell(arch: str, shape: str, multi_pod: bool,
             fsdp: bool = True) -> dict:
    """Lower+compile one cell; returns the EXPERIMENTS.md §Dry-run record."""
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    cfg = get_config(arch)
    spec = SHAPES[shape]
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        wl = wk.build(cfg, shape)
        lowered = wk.lower(wl, mesh, fsdp=fsdp)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
            }
            # per-device residency: args (params+opt+caches) + temps
            rec["bytes_per_device"] = int(mem.argument_size_in_bytes
                                          + mem.temp_size_in_bytes)
        except Exception as e:  # pragma: no cover - backend specific
            rec["memory_error"] = str(e)
        text = compiled.as_text()
        hlo_dir = os.environ.get("REPRO_HLO_DIR", "results/hlo")
        try:
            import gzip
            os.makedirs(hlo_dir, exist_ok=True)
            fn = f"{arch}_{shape}_{rec['mesh']}.txt.gz".replace("/", "_")
            with gzip.open(os.path.join(hlo_dir, fn), "wt") as f:
                f.write(text)
            rec["hlo_file"] = os.path.join(hlo_dir, fn)
        except OSError as e:
            rec["hlo_save_error"] = str(e)
        roof = ha.roofline_terms(compiled, text)
        rec["roofline"] = roof.as_dict()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "XLA counts while bodies once (scan-unaware)",
        }
        total, active = count_params(cfg)
        rec["params_total"] = total
        rec["params_active"] = active
        mf = ha.model_flops_per_step(
            active, wl.tokens_per_step,
            "train" if wl.kind == "train" else "serve")
        rec["model_flops"] = mf
        # cost_analysis flops are per-device (post-SPMD module)
        n_chips = 512 if multi_pod else 256
        rec["n_chips"] = n_chips
        rec["useful_flops_ratio"] = (
            mf / (roof.flops * n_chips)) if roof.flops else 0.0
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    p.add_argument("--shape", default=None, choices=list(SHAPES))
    p.add_argument("--mesh", default="both",
                   choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true",
                   help="run every runnable (arch x shape) cell")
    p.add_argument("--out", default=None, help="append JSON records here")
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--resume", action="store_true",
                   help="skip cells already recorded ok in --out")
    args = p.parse_args()

    done = set()
    if args.resume and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("ok") and not r.get("skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    todo = []
    for c in cells():
        if args.arch and c.arch != args.arch:
            continue
        if args.shape and c.shape != args.shape:
            continue
        if not args.all and not args.arch and not args.shape:
            continue
        todo.append(c)
    if not todo:
        p.error("nothing selected; pass --all or --arch/--shape")

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    records = []
    for c in todo:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            if (c.arch, c.shape, mesh_name) in done:
                print(f"[resume-skip] {c.arch}/{c.shape} {mesh_name}",
                      flush=True)
                continue
            if not c.runnable:
                rec = {"arch": c.arch, "shape": c.shape, "mesh": mesh_name,
                       "ok": True, "skipped": True, "reason": c.skip_reason}
                print(f"[skip] {c.arch}/{c.shape} ({c.skip_reason})",
                      flush=True)
            else:
                rec = run_cell(c.arch, c.shape, multi,
                               fsdp=not args.no_fsdp)
                status = "ok" if rec["ok"] else "FAIL: " + rec.get("error", "")
                roof = rec.get("roofline", {})
                print(f"[{mesh_name}] {c.arch}/{c.shape}: {status} "
                      f"compile={rec.get('compile_s', '-')}s "
                      f"bottleneck={roof.get('bottleneck', '-')}", flush=True)
            records.append(rec)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")

    n_fail = sum(1 for r in records if not r.get("ok"))
    print(f"\n{len(records)} records, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
