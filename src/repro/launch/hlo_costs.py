"""Scan-aware per-device cost extraction from compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, but our
models scan over layers (and attention scans over KV chunks), so its FLOPs /
bytes under-count by the trip count — verified experimentally: a scan of 10
matmuls reports the FLOPs of one (EXPERIMENTS.md §Roofline, methodology).

This module walks the compiled (post-SPMD, per-device) HLO call graph and
multiplies every ``while`` body/condition cost by the loop's trip count
(recovered from the integer constant in the condition computation — jax
scans lower to ``lt(iv, N)``).  Costs counted per instruction:

  flops            dot: 2 * prod(result dims) * contracted_extent
  mem bytes        dot: lhs+rhs+result (weights + activations at the
                   matmul boundary — the dominant, fusion-invariant HBM
                   traffic); gather/dynamic-slice: 2x result;
                   dynamic-update-slice: 2x update (in-place on hardware).
                   Fusion-boundary bytes are NOT charged: the CPU backend
                   makes far smaller fusions than TPU, so they are a
                   host-compiler artifact (documented in EXPERIMENTS.md).
  collective bytes all-gather / all-reduce (x2, ring) / reduce-scatter /
                   all-to-all / collective-permute: max(operand, result)

All shapes in the post-SPMD module are per-device, so totals are per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{")
# shape part is lazy `.*?` because tuple shapes embed /*index=N*/ comments
# (which contain '='); group 4 is the argument/attribute tail after the op's
# opening paren.
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s"
                    r"([\w\-]+)\((.*)$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    unknown_trip_counts: int = 0

    def __iadd__(self, other: "Costs"):
        self.flops += other.flops
        self.mem_bytes += other.mem_bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0) + v
        self.unknown_trip_counts += other.unknown_trip_counts
        return self

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.mem_bytes * k, self.coll_bytes * k,
                     {o: v * k for o, v in self.coll_by_op.items()},
                     self.unknown_trip_counts)


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    line: str
    tail: str = ""     # text after the op's opening paren (args + attrs)


def _split_computations(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, list[_Instr]] = {}
    entry = None
    current = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if "{" in line else None
        if hdr and "->" in line:
            current = hdr.group(2)
            comps[current] = []
            if hdr.group(1):
                entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR.match(line)
        if m:
            comps[current].append(_Instr(m.group(1), m.group(2), m.group(3),
                                         line, m.group(4)))
    return comps, entry


def _args_of(tail: str) -> list[str]:
    """%refs in the operand list (the tail up to the closing paren, before
    the attribute section which may reference computations)."""
    inner = tail
    for marker in ("), ", ") ,"):
        pos = inner.find(marker)
        if pos >= 0:
            inner = inner[:pos + 1]
            break
    return re.findall(r"%([\w.\-]+)", inner)


def _attr_comp(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _contracted_extent(ins: "_Instr", shapes: dict) -> int:
    """Product of lhs contracting dims of a dot instruction."""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    args = _args_of(ins.tail)
    if not m or not args:
        return 1
    lhs_shape = shape_dims(shapes.get(args[0], ""))
    ext = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs_shape):
            ext *= lhs_shape[int(d)]
    return max(ext, 1)


def _trip_count(cond_instrs: list[_Instr]) -> Optional[int]:
    best = None
    for ins in cond_instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
    return best


def analyze(text: str) -> Costs:
    comps, entry = _split_computations(text)
    if entry is None:
        return Costs()
    shapes: dict[str, str] = {}
    for instrs in comps.values():
        for ins in instrs:
            shapes[ins.name] = ins.shape

    memo: dict[str, Costs] = {}

    def cost_of(comp: str, stack=()) -> Costs:
        if comp in memo:
            return memo[comp]
        if comp in stack or comp not in comps:
            return Costs()
        total = Costs()
        for ins in comps[comp]:
            op = ins.op
            base = op.rstrip(".0123456789")
            if base.endswith("-start"):
                base = base[:-6]
            if base == "dot":
                k = _contracted_extent(ins, shapes)
                res = 1
                for d in shape_dims(ins.shape):
                    res *= d
                total.flops += 2.0 * res * k
                operand_b = sum(shape_bytes(shapes.get(a, ""))
                                for a in _args_of(ins.tail))
                total.mem_bytes += operand_b + shape_bytes(ins.shape)
            elif base == "fusion":
                # traverse for dots/collectives INSIDE the fusion, but do
                # NOT charge fusion-boundary bytes: CPU-backend fusions are
                # far smaller than TPU fusions, so boundary traffic here is
                # a host-compiler artifact.  Activation traffic that a TPU
                # would actually see is captured via dot operands/results.
                callee = _attr_comp(ins.line, "calls")
                if callee:
                    total += cost_of(callee, stack + (comp,))
            elif base in ("gather", "dynamic-slice"):
                total.mem_bytes += 2 * shape_bytes(ins.shape)
            elif base == "dynamic-update-slice":
                args = _args_of(ins.tail)
                upd = shape_bytes(shapes.get(args[1], "")) if len(args) > 1 \
                    else 0
                total.mem_bytes += 2 * upd
            elif base in _COLLECTIVES:
                operand_b = sum(shape_bytes(shapes.get(a, ""))
                                for a in _args_of(ins.tail))
                moved = max(operand_b, shape_bytes(ins.shape))
                if base == "all-reduce":
                    moved *= 2
                total.coll_bytes += moved
                total.coll_by_op[base] = total.coll_by_op.get(base, 0) + moved
            elif base == "while":
                body = _attr_comp(ins.line, "body")
                cond = _attr_comp(ins.line, "condition")
                trips = None
                if cond and cond in comps:
                    trips = _trip_count(comps[cond])
                inner = Costs()
                if body:
                    inner += cost_of(body, stack + (comp,))
                if cond:
                    inner += cost_of(cond, stack + (comp,))
                if trips is None:
                    trips = 1
                    inner.unknown_trip_counts += 1
                total += inner.scaled(trips)
            elif base in ("call", "custom-call", "reduce", "sort", "map",
                          "scatter", "reduce-window", "select-and-scatter",
                          "conditional"):
                for key in ("to_apply", "calls"):
                    callee = _attr_comp(ins.line, key)
                    if callee:
                        total += cost_of(callee, stack + (comp,))
                        break
                if base == "conditional":
                    for c in re.findall(r"branch_computations=\{([^}]*)\}",
                                        ins.line):
                        for cc in re.findall(r"%?([\w.\-]+)", c):
                            total += cost_of(cc, stack + (comp,))
        memo[comp] = total
        return total

    return cost_of(entry)
