"""Serving launcher: deployed mixed-precision model, request-level
continuous batching over a paged KV cache (repro.api.ServingEngine).

The deployed weights are the Sec. III-C output: channels reordered and
grouped by searched bit-width, packed sub-byte, consumed as per-precision
sub-GEMMs (kernels/quant_matmul.py on TPU; jnp fallback on CPU).  The
launcher synthesizes a staggered-arrival trace (requests arriving over
time with ragged prompt/output lengths) and serves it through the paged
slot pool: finished slots are reclaimed and refilled without re-jitting,
so prefill of new arrivals interleaves with decode of in-flight requests,
and KV pages of repeated prompt prefixes are shared copy-free (radix
index, ``--no-prefix-sharing`` to disable).  ``--page-size 0`` serves the
dense per-slot rings instead.  ``--lockstep`` runs the same trace
wave-at-a-time through the engine (submit a wave, drain it, repeat) — the
shortest-job-barrier baseline continuous batching removes.
``--speculate-k K`` serves speculatively: a draft proposes K tokens per
verify launch (``--draft-bits B`` re-quantizes the draft to a uniform
B-bit channel assignment — the aggressive end of the paper's channel-wise
Pareto front; default self-draft).

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --reduced \
      --requests 8 --slots 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api.scheduler import Request, ServingEngine
from repro.config import ARCH_IDS, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_test_mesh, make_production_mesh
from repro.models import serving


def build_trace(cfg, args, rng):
    """Staggered-arrival synthetic trace: ragged prompts, outputs, times."""
    reqs, arrivals = [], []
    min_len = max(1, args.prompt_len // 2)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        min_len = max(min_len, cfg.n_prefix_tokens + 1)  # past the prefix
    for i in range(args.requests):
        L = int(rng.integers(min_len, args.prompt_len + 1))
        gen = int(rng.integers(max(1, args.gen // 4), args.gen + 1))
        extras = {}
        if cfg.family == "audio":
            extras["frames"] = rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm" and cfg.n_prefix_tokens:
            extras["prefix_embeds"] = rng.standard_normal(
                (cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        reqs.append(Request(
            tokens=rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32),
            max_tokens=gen, extras=extras))
        arrivals.append(int(rng.integers(0, args.stagger + 1)))
    return reqs, arrivals


def _engine(cfg, dparams, args, mesh=None):
    page_size = {0: None, -1: "auto"}.get(args.page_size, args.page_size)
    draft = None
    if args.speculate_k and args.draft_bits:
        draft = serving.draft_model(dparams, cfg, args.draft_bits)
    return ServingEngine(cfg, dparams, backend=args.backend,
                         max_slots=args.slots,
                         max_len=args.prompt_len + args.gen,
                         prefill_len=args.prompt_len,
                         page_size=page_size,
                         num_pages=args.num_pages or None,
                         prefix_sharing=(False if args.no_prefix_sharing
                                         else "auto"),
                         speculate_k=args.speculate_k,
                         draft_dparams=draft,
                         mesh=mesh)


def _paged_line(eng):
    if eng.pool is None:
        return "paged:      off (dense slot rings)"
    st = eng.stats
    return (f"paged:      page_size {eng.page_size}, peak "
            f"{st['pages_peak']}/{eng.pool.capacity} pages, "
            f"{st['prefix_hits']} prefix hits "
            f"({st['zero_prefill_admits']} zero-prefill, "
            f"{st['cached_tokens']} cached tokens), "
            f"{st['evictions']} evictions, "
            f"{st['deferred_admissions']} deferred — resident KV "
            f"{eng.kv_bytes_resident()} B vs dense {eng.kv_bytes_dense()} B")


def run_continuous(cfg, dparams, reqs, arrivals, args, mesh=None):
    eng = _engine(cfg, dparams, args, mesh=mesh)
    t0 = time.time()
    if args.fail_host >= 0:
        # failure-injection drive loop: same schedule as eng.run, plus one
        # fail_host call partway through — the heartbeat then drains the
        # host's slots and the trace still completes
        order = sorted(range(len(reqs)), key=lambda i: (arrivals[i], i))
        fail_at = max(2, args.stagger // 2)
        outs, nxt, t = {}, 0, 0
        while nxt < len(order) or eng.has_work():
            while nxt < len(order) and arrivals[order[nxt]] <= t:
                i = order[nxt]
                eng.submit(reqs[i])
                nxt += 1
            if t == fail_at:
                eng.fail_host(args.fail_host)
                print(f"fail-host:  host {args.fail_host} stopped beating "
                      f"at tick {t}")
            eng.step()
            for o in eng.collect():
                outs[o.rid] = o
            t += 1
        print(f"fail-host:  {eng.stats['host_drains']} drains, "
              f"{eng.stats['drained_requests']} requests requeued, "
              f"{len(outs)}/{len(reqs)} completed")
    else:
        outs = eng.run(reqs, arrivals)
    dt = time.time() - t0
    st = eng.stats
    steps = st["prefill_launches"] + st["decode_launches"]
    occ = (st["occupancy_sum"] / st["decode_launches"]
           if st["decode_launches"] else 0.0)
    print(f"continuous: {len(outs)} requests, {st['useful_tokens']} tokens "
          f"in {dt:.2f}s ({st['useful_tokens'] / dt:.1f} tok/s) — "
          f"{st['prefill_launches']} prefills + {st['decode_launches']} "
          f"decode steps = {steps} launches, slot occupancy {occ:.2f}, "
          f"jit entries {eng.compile_counts()}")
    print(_paged_line(eng))
    if eng.speculate_k:
        vl = steps + st["verify_launches"]  # verifier-model launches
        acc = (st["accepted_tokens"] / st["verify_launches"]
               if st["verify_launches"] else 0.0)
        print(f"speculative: k={eng.speculate_k}, {st['spec_rounds']} "
              f"rounds, {acc:.2f} drafts accepted/verify, "
              f"{st['useful_tokens'] / vl:.2f} useful tokens per "
              f"verifier launch (+{st['draft_launches']} draft launches)")
    first = outs[0]
    print("sample token ids:", first.tokens[:16])
    return dt, st["useful_tokens"]


def run_lockstep(cfg, dparams, reqs, args):
    """Wave-at-a-time baseline: submit one wave, drain it to completion,
    repeat — every wave prefills together and idles behind its longest
    request (the shortest-job barrier continuous batching removes).  Same
    engine, same executables; only the schedule differs."""
    eng = _engine(cfg, dparams, args)
    B = args.slots
    t0, useful = time.time(), 0
    for w0 in range(0, len(reqs), B):
        wave = reqs[w0:w0 + B]
        for r in wave:
            eng.submit(r)
        while eng.has_work():
            eng.step()
        useful += sum(len(o.tokens) for o in eng.collect())
    dt = time.time() - t0
    st = eng.stats
    steps = st["prefill_launches"] + st["decode_launches"]
    print(f"lockstep:   {len(reqs)} requests, {useful} useful tokens in "
          f"{dt:.2f}s ({useful / dt:.1f} tok/s) over {steps} launches")
    return dt, useful


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--stagger", type=int, default=8,
                   help="arrival window in scheduler ticks")
    p.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    p.add_argument("--page-size", type=int, default=-1,
                   help="KV page size in tokens (-1 auto, 0 dense rings)")
    p.add_argument("--num-pages", type=int, default=0,
                   help="physical page pool size (0 = default sizing)")
    p.add_argument("--no-prefix-sharing", action="store_true",
                   help="disable the radix prompt-prefix index")
    p.add_argument("--speculate-k", type=int, default=0,
                   help="speculative decoding: draft k tokens per verify "
                        "launch (0 = off)")
    p.add_argument("--draft-bits", type=int, default=0,
                   help="re-quantize the draft to this uniform channel "
                        "bit-width (0 = self-draft at full precision)")
    p.add_argument("--lockstep", action="store_true",
                   help="also run the wave-at-a-time lockstep baseline")
    p.add_argument("--production-mesh", action="store_true")
    p.add_argument("--mesh", default="",
                   help="serve on a (data, model) device mesh, e.g. "
                        "'--mesh 2,4' (needs data*model visible devices; "
                        "on CPU set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=8). "
                        "Token-identical to the meshless engine.")
    p.add_argument("--fail-host", type=int, default=-1,
                   help="kill this data-axis host partway through the "
                        "trace (drain-on-death demo; requires --mesh)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(args.seed)
    dparams = serving.init_deployed_model(cfg, key)

    rng = np.random.default_rng(args.seed)
    reqs, arrivals = build_trace(cfg, args, rng)

    if args.mesh:
        # engine-owned mesh: the ServingEngine's MeshContext places the
        # weights (QTensor fused buffers sharded, the rest replicated) and
        # the caches (slot/page axis on data) itself
        dp, tp = (int(x) for x in args.mesh.split(","))
        serving_mesh = make_test_mesh(dp, tp)
        print(f"mesh:       (data={dp}, model={tp}) over "
              f"{dp * tp} of {len(jax.devices())} devices")
        run_continuous(cfg, dparams, reqs, arrivals, args,
                       mesh=serving_mesh)
        if args.lockstep:
            run_lockstep(cfg, dparams, reqs, args)
        return
    if args.fail_host >= 0:
        raise SystemExit("--fail-host requires --mesh (the data axis is "
                         "the host fleet)")

    mesh = (make_production_mesh() if args.production_mesh
            else make_test_mesh())
    rules = shd.ShardingRules(mesh)
    dparams = jax.device_put(dparams, rules.tree_shardings(dparams))

    with mesh:
        run_continuous(cfg, dparams, reqs, arrivals, args)
        if args.lockstep:
            run_lockstep(cfg, dparams, reqs, args)


if __name__ == "__main__":
    main()
