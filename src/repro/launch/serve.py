"""Serving launcher: deployed mixed-precision model, batched requests,
prefill + decode loop with int8 KV caches.

The deployed weights are the Sec. III-C output: channels reordered and
grouped by searched bit-width, packed sub-byte, consumed as per-precision
sub-GEMMs (kernels/quant_matmul.py on TPU; jnp fallback on CPU).

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engine import ServingSession
from repro.config import ARCH_IDS, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_test_mesh, make_production_mesh
from repro.models import serving


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    p.add_argument("--production-mesh", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_test_mesh())
    rules = shd.ShardingRules(mesh)

    key = jax.random.PRNGKey(args.seed)
    dparams = serving.init_deployed_model(cfg, key)
    dparams = jax.device_put(dparams, rules.tree_shardings(dparams))

    B, S = args.batch, args.prompt_len
    max_len = S + args.gen
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.float32)

    sess = ServingSession(cfg, dparams, backend=args.backend)

    with mesh:
        t0 = time.time()
        logits, pf_caches = sess.prefill(dparams, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        print(f"prefill: {B}x{S} tokens in {t_prefill:.2f}s "
              f"({B * S / t_prefill:.0f} tok/s)")

        # decode loop against fresh max_len caches (prefill caches are
        # S-deep; production pads them into the ring — here we re-init for
        # shape stability and measure steady-state decode)
        caches = sess.init_caches(B, max_len)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tokens]
        t0 = time.time()
        for i in range(args.gen):
            logits, caches = sess.decode(dparams, tokens, caches,
                                         jnp.asarray(S + i, jnp.int32))
            tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tokens)
        tokens.block_until_ready()
        dt = time.time() - t0
        print(f"decode: {args.gen} steps x batch {B} in {dt:.2f}s "
              f"({args.gen * B / dt:.1f} tok/s, "
              f"{1e3 * dt / args.gen:.1f} ms/step)")
        gen = jnp.concatenate(out, axis=1)
        print("sample token ids:", np.asarray(gen[0])[:16])


if __name__ == "__main__":
    main()
