"""Workload builders: (arch x shape) -> jitted step + ShapeDtypeStruct args
+ shardings for a given mesh.

This is the single source of truth consumed by the multi-pod dry-run
(launch/dryrun.py), the roofline benchmarks (benchmarks/roofline.py) and the
production launchers (launch/train.py / launch/serve.py):

  train_4k     -> ``train_step``  — the paper's search-phase W update (DNAS
                  mixture forward + CE + optimizer), the dominant workload.
  prefill_32k  -> ``prefill``     — deployed mixed-precision model, full
                  sequence, int8 KV-cache build.
  decode_32k / long_500k -> ``decode_step`` — one new token against a
                  seq_len-deep cache (the bandwidth-bound serving workload
                  where the paper's searched bit-widths directly scale
                  throughput).

Everything is ShapeDtypeStruct-based — no parameter or activation memory is
ever allocated on the dry-run host.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch.shapes import SHAPES, ShapeSpec
from repro.models import serving
from repro.models import transformer as tfm
from repro.train import steps as steps_mod


@dataclasses.dataclass
class Workload:
    name: str                      # "<arch>/<shape>"
    kind: str                      # train | prefill | decode
    fn: Callable                   # positional-args step function
    args: tuple                    # ShapeDtypeStruct pytrees
    donate: tuple = ()             # donated arg indices
    tokens_per_step: int = 0       # for MODEL_FLOPS accounting


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg, spec: ShapeSpec) -> dict:
    """ShapeDtypeStructs for one *global* training/prefill batch."""
    B, S = spec.global_batch, spec.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if spec.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        batch["prefix_embeds"] = _sds((B, cfg.n_prefix_tokens, cfg.d_model),
                                      jnp.float32)
    return batch


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def make_train_workload(cfg, spec: ShapeSpec,
                        hp: Optional[steps_mod.TrainHParams] = None
                        ) -> Workload:
    hp = hp or steps_mod.TrainHParams.for_arch(cfg)
    state = jax.eval_shape(
        lambda: steps_mod.init_train_state(cfg, hp, jax.random.PRNGKey(0)))
    batch = batch_struct(cfg, spec)
    step = steps_mod.make_train_step(cfg, hp)
    return Workload(name=f"{cfg.name}/{spec.name}", kind="train", fn=step,
                    args=(state, batch), donate=(0,),
                    tokens_per_step=spec.global_batch * spec.seq_len)


def make_prefill_workload(cfg, spec: ShapeSpec) -> Workload:
    dparams = jax.eval_shape(
        lambda: serving.init_deployed_model(cfg, jax.random.PRNGKey(0)))
    batch = batch_struct(cfg, spec)

    def prefill_fn(dp, b):
        return serving.prefill(dp, cfg, b)

    return Workload(name=f"{cfg.name}/{spec.name}", kind="prefill",
                    fn=prefill_fn, args=(dparams, batch),
                    tokens_per_step=spec.global_batch * spec.seq_len)


def make_decode_workload(cfg, spec: ShapeSpec) -> Workload:
    B, S = spec.global_batch, spec.seq_len
    dparams = jax.eval_shape(
        lambda: serving.init_deployed_model(cfg, jax.random.PRNGKey(0)))
    caches = jax.eval_shape(lambda: serving.init_caches(cfg, B, S))
    tokens = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)

    def decode_fn(dp, tok, c, p):
        return serving.decode_step(dp, cfg, tok, c, p)

    return Workload(name=f"{cfg.name}/{spec.name}", kind="decode",
                    fn=decode_fn, args=(dparams, tokens, caches, pos),
                    donate=(2,), tokens_per_step=B)


def build(cfg, shape_name: str,
          hp: Optional[steps_mod.TrainHParams] = None) -> Workload:
    spec = SHAPES[shape_name]
    if spec.kind == "train":
        return make_train_workload(cfg, spec, hp)
    if spec.kind == "prefill":
        return make_prefill_workload(cfg, spec)
    if spec.kind == "decode":
        return make_decode_workload(cfg, spec)
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def shardings_for(wl: Workload, mesh: Mesh,
                  fsdp: bool = True, moe_ep2d: bool = False,
                  kv_seq_shard: bool = False) -> tuple:
    """in_shardings pytree matching ``wl.args`` for ``mesh``."""
    rules = shd.ShardingRules(mesh, fsdp=fsdp, moe_ep2d=moe_ep2d,
                              kv_seq_shard=kv_seq_shard)
    rep = NamedSharding(mesh, P())
    if wl.kind == "train":
        state, batch = wl.args
        return (rules.tree_shardings(state), shd.batch_specs(mesh, batch))
    if wl.kind == "prefill":
        dparams, batch = wl.args
        return (rules.tree_shardings(dparams), shd.batch_specs(mesh, batch))
    if wl.kind == "decode":
        dparams, tokens, caches, pos = wl.args
        return (rules.tree_shardings(dparams),
                shd.batch_specs(mesh, tokens),
                rules.tree_shardings(caches),
                rep)
    raise ValueError(wl.kind)


def lower(wl: Workload, mesh: Mesh, fsdp: bool = True,
          moe_ep2d: bool = False, kv_seq_shard: bool = False):
    """jit(fn, in_shardings).lower(*args) under the mesh."""
    in_sh = shardings_for(wl, mesh, fsdp=fsdp, moe_ep2d=moe_ep2d,
                          kv_seq_shard=kv_seq_shard)
    jitted = jax.jit(wl.fn, in_shardings=in_sh,
                     donate_argnums=wl.donate or ())
    with mesh, shd.activation_sharding(mesh):
        return jitted.lower(*wl.args)
