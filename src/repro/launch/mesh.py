"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run host
forces 512 fake CPU devices via XLA_FLAGS *before* first jax init, while the
smoke tests and benchmarks see the single real device.

Mesh layout (DESIGN.md §5):
  single pod : (data=16, model=16)            = 256 chips (TPU v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips
``pod`` is a pure data-parallel axis: the only traffic crossing the
inter-pod DCN is the gradient all-reduce, which is the standard
hierarchical-DP posture for 1000+-node jobs (scaling to N pods is
changing one integer here).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

SINGLE_POD = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} are "
            f"visible — the dry-run entrypoint must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"any jax import (launch/dryrun.py does)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over the real host devices for smoke tests."""
    n = data * model
    devices = jax.devices()[:n]
    return Mesh(np.asarray(devices).reshape((data, model)), SINGLE_POD_AXES)
