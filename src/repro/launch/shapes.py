"""Assigned input-shape set and the 40-cell (arch x shape) enumeration.

  train_4k     seq=4096   global_batch=256   lowers train_step (search phase)
  prefill_32k  seq=32768  global_batch=32    lowers serve prefill
  decode_32k   seq=32768  global_batch=128   lowers serve_step (1 new token,
                                             KV cache of seq_len)
  long_500k    seq=524288 global_batch=1     decode; sub-quadratic archs only

``long_500k`` runs only for mamba2-780m (ssm) and zamba2-1.2b (hybrid); the
eight full-attention archs record an explicit skip (DESIGN.md §4).  Every
skip still appears as a row in the dry-run/roofline tables.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

from repro.config import ARCH_IDS, get_config


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    runnable: bool
    skip_reason: str = ""


def cells() -> list[Cell]:
    """All 40 (arch x shape) cells, with skip annotations."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPE_ORDER:
            spec = SHAPES[shape]
            if shape == "long_500k" and not cfg.supports_long:
                out.append(Cell(arch, shape, False,
                                cfg.long_skip_reason or "full attention"))
            elif spec.kind == "decode" and not cfg.supports_decode:
                out.append(Cell(arch, shape, False, "encoder-only"))
            else:
                out.append(Cell(arch, shape, True))
    return out
