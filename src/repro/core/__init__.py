"""Core: the paper's channel-wise mixed-precision DNAS, end to end.

quantizers    — PACT/affine fake-quant + STE, sub-byte packing
mixedprec     — gamma/delta NAS state, Eq. 3-6 effective tensors
regularizers  — Eq. 7 (size) / Eq. 8 (energy) differentiable costs
lut           — C(p_x, p_w) hardware cost tables (MPIC + TPU-bandwidth)
search        — Alg. 1 three-phase training loop
deploy        — Sec. III-C reorder/group/pack/split transform (TPU-aligned)
edmips        — layer-wise baseline configuration
"""
