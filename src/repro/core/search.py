"""Alg. 1 — the three-phase DNAS training procedure.

Phases (Sec. III-B):

1. **warmup**   — QAT at p_max (8b), NAS params frozen; only ``L_T``.
2. **search**   — per epoch: the first 20% of the samples update the NAS
   parameters theta on ``L_T + lambda * L_R``; the remaining 80% update the
   weights W on ``L_T``.  Temperature tau annealed by ``exp(-0.0045)`` per
   epoch from tau0=5.  Early-stopped on a converged cost/accuracy plateau.
3. **fine-tune** — theta frozen, softmax replaced by argmax, W trained on L_T.

The module is model-agnostic: models expose

    apply_fn(params, nas, policy, batch) -> predictions

with ``policy`` a :class:`repro.api.PrecisionPolicy` (QAT8 during warmup,
search(tau) during the search, FROZEN during fine-tuning) and a ``specs``
dict (LayerCostSpec per NAS layer).  The EdMIPS baseline (core/edmips.py)
reuses this exact loop with layer-wise gamma — the paper runs both under
*identical* training protocols for fairness (Sec. IV-B), and so do we.

:class:`SearchDriver` exposes the phases individually (warmup / search /
finetune share one pair of optimizer states), which is what the
``repro.api.Engine`` facade drives; :func:`run_search` composes all three
for one-shot callers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.api.policy import PrecisionPolicy
from repro.core import mixedprec as mp
from repro.core import regularizers as reg
from repro.optim import optimizers as opt_mod


@dataclasses.dataclass
class SearchSettings:
    cfg: mp.MixedPrecConfig
    objective: str = "size"          # "size" (Eq. 7) or "energy" (Eq. 8)
    lut_name: str = "mpic"
    lam: float = 1e-7                # lambda in Eq. (2)
    warmup_epochs: int = 2
    search_epochs: int = 4           # upper bound; early stop below
    finetune_epochs: int = 2
    theta_frac: float = 0.2          # 20% split for theta updates
    lr_w: float = 1e-3
    lr_theta: float = 1e-2
    early_stop_patience: int = 3     # epochs without cost improvement
    early_stop_rtol: float = 1e-3
    train_compute: str = "f32"       # matmul arithmetic: f32 | bf16 | int8
    sr_seed: int = 0                 # int8 stochastic-rounding base seed


@dataclasses.dataclass
class SearchResult:
    params: dict
    nas: dict
    tau: jnp.ndarray
    history: list
    settings: SearchSettings


class SearchDriver:
    """Stateful Alg. 1 executor: one optimizer pair across all phases.

    ``data_epochs()`` returns a fresh iterable of batches for one epoch (the
    caller controls batching/sharding/shuffling).  Phases may be driven
    individually (the Engine facade does) or via :func:`run_search`.
    """

    def __init__(self, apply_fn: Callable, loss_fn: Callable, specs: dict,
                 params: dict, nas: dict, settings: SearchSettings):
        s = settings
        self.apply_fn, self.loss_fn, self.specs = apply_fn, loss_fn, specs
        self.settings = s
        self.params, self.nas = params, nas
        self.tau = jnp.asarray(s.cfg.tau0, jnp.float32)
        self.history: list = []
        self.step = 0

        opt_w = opt_mod.AdamW(schedule=opt_mod.constant_schedule(s.lr_w),
                              clip_norm=1.0)
        opt_t = opt_mod.AdamW(schedule=opt_mod.constant_schedule(s.lr_theta),
                              clip_norm=None)
        self._opt_w, self._opt_t = opt_w, opt_t
        self._ow = opt_w.init(params)
        self._ot = opt_t.init(nas)

        def pol(base, step):
            """Per-step training policy: ``train_compute="f32"`` returns the
            phase singleton untouched (bit-identity with the pre-axis
            driver); int8 folds the step into the SR key."""
            if s.train_compute == "f32":
                return base
            sr_key = None
            if s.train_compute == "int8":
                sr_key = jax.random.fold_in(
                    jax.random.PRNGKey(s.sr_seed), step)
            return base.with_train_compute(s.train_compute, sr_key)

        @jax.jit
        def warmup_step(params, ow, step, batch):
            def lt(p):
                pred = apply_fn(p, None, pol(PrecisionPolicy.QAT8, step),
                                batch)
                return loss_fn(pred, batch)
            loss, grads = jax.value_and_grad(lt)(params)
            upd, ow = opt_w.update(grads, ow, params, step)
            return opt_mod.apply_updates(params, upd), ow, loss

        @jax.jit
        def theta_step(params, nas, tau, ot, step, batch):
            def lfull(n):
                pred = apply_fn(params, n,
                                pol(PrecisionPolicy.search(tau), step), batch)
                lt = loss_fn(pred, batch)
                lr = reg.total_cost(n, tau, specs, s.cfg, s.objective,
                                    s.lut_name)
                return lt + s.lam * lr, (lt, lr)
            (_, (lt, lr)), grads = jax.value_and_grad(
                lfull, has_aux=True)(nas)
            upd, ot = opt_t.update(grads, ot, nas, step)
            return opt_mod.apply_updates(nas, upd), ot, lt, lr

        @jax.jit
        def w_step(params, nas, tau, ow, step, batch):
            def lt(p):
                pred = apply_fn(p, nas,
                                pol(PrecisionPolicy.search(tau), step), batch)
                return loss_fn(pred, batch)
            loss, grads = jax.value_and_grad(lt)(params)
            upd, ow = opt_w.update(grads, ow, params, step)
            return opt_mod.apply_updates(params, upd), ow, loss

        @jax.jit
        def finetune_step(params, nas, ow, step, batch):
            def lt(p):
                pred = apply_fn(p, nas, pol(PrecisionPolicy.FROZEN, step),
                                batch)
                return loss_fn(pred, batch)
            loss, grads = jax.value_and_grad(lt)(params)
            upd, ow = opt_w.update(grads, ow, params, step)
            return opt_mod.apply_updates(params, upd), ow, loss

        self._warmup_step, self._theta_step = warmup_step, theta_step
        self._w_step, self._finetune_step = w_step, finetune_step

    # -- Phase 1: warmup (Alg. 1 l.1-2) -------------------------------------
    def warmup(self, data_epochs: Callable[[], Iterable],
               epochs: Optional[int] = None) -> "SearchDriver":
        for ep in range(self.settings.warmup_epochs if epochs is None
                        else epochs):
            loss = None
            for batch in data_epochs():
                self.params, self._ow, loss = self._warmup_step(
                    self.params, self._ow, jnp.asarray(self.step), batch)
                self.step += 1
            entry = {"phase": "warmup", "epoch": ep}
            if loss is not None:     # guard: epoch may yield zero batches
                entry["loss"] = float(loss)
            self.history.append(entry)
        return self

    # -- Phase 2: search (Alg. 1 l.3-8) --------------------------------------
    def search(self, data_epochs: Callable[[], Iterable],
               epochs: Optional[int] = None) -> "SearchDriver":
        s = self.settings
        best_cost, stall = None, 0
        for ep in range(s.search_epochs if epochs is None else epochs):
            batches = list(data_epochs())
            lt = lr = loss = None
            n_theta = min(len(batches),
                          max(1, int(len(batches) * s.theta_frac)))
            for batch in batches[:n_theta]:         # 20%: update theta
                self.nas, self._ot, lt, lr = self._theta_step(
                    self.params, self.nas, self.tau, self._ot,
                    jnp.asarray(self.step), batch)
                self.step += 1
            for batch in batches[n_theta:]:         # 80%: update W
                self.params, self._ow, loss = self._w_step(
                    self.params, self.nas, self.tau, self._ow,
                    jnp.asarray(self.step), batch)
                self.step += 1
            self.tau = mp.anneal_tau(self.tau, s.cfg)        # Alg. 1 l.8
            entry = {"phase": "search", "epoch": ep, "tau": float(self.tau)}
            if lt is not None:       # guard: short/empty epochs write no
                entry["task_loss"] = float(lt)       # stale loss values
            if lr is not None:
                entry["reg_cost"] = float(lr)
            self.history.append(entry)
            if lr is None:
                continue             # nothing to early-stop on
            cost = float(lr)
            if best_cost is not None and \
                    cost >= best_cost * (1 - s.early_stop_rtol):
                stall += 1
                if stall >= s.early_stop_patience:
                    break
            else:
                best_cost, stall = cost, 0
        return self

    # -- Phase 3: fine-tune (Alg. 1 l.9-11) ----------------------------------
    def finetune(self, data_epochs: Callable[[], Iterable],
                 epochs: Optional[int] = None,
                 eval_fn: Optional[Callable] = None) -> "SearchDriver":
        for ep in range(self.settings.finetune_epochs if epochs is None
                        else epochs):
            loss = None
            for batch in data_epochs():
                self.params, self._ow, loss = self._finetune_step(
                    self.params, self.nas, self._ow,
                    jnp.asarray(self.step), batch)
                self.step += 1
            entry = {"phase": "finetune", "epoch": ep}
            if loss is not None:
                entry["loss"] = float(loss)
            if eval_fn is not None:
                entry["metric"] = float(eval_fn(self.params, self.nas,
                                                PrecisionPolicy.FROZEN))
            self.history.append(entry)
        return self

    def result(self) -> SearchResult:
        return SearchResult(params=self.params, nas=self.nas, tau=self.tau,
                            history=self.history, settings=self.settings)


def run_search(apply_fn: Callable, loss_fn: Callable, specs: dict,
               params: dict, nas: dict, data_epochs: Callable[[], Iterable],
               settings: SearchSettings,
               eval_fn: Optional[Callable] = None) -> SearchResult:
    """Execute Alg. 1 end to end (warmup -> search -> fine-tune).

    ``eval_fn(params, nas, policy)`` optionally reports a validation metric
    into the fine-tune history entries.
    """
    driver = SearchDriver(apply_fn, loss_fn, specs, params, nas, settings)
    driver.warmup(data_epochs)
    driver.search(data_epochs)
    driver.finetune(data_epochs, eval_fn=eval_fn)
    return driver.result()
