"""Alg. 1 — the three-phase DNAS training procedure.

Phases (Sec. III-B):

1. **warmup**   — QAT at p_max (8b), NAS params frozen; only ``L_T``.
2. **search**   — per epoch: the first 20% of the samples update the NAS
   parameters theta on ``L_T + lambda * L_R``; the remaining 80% update the
   weights W on ``L_T``.  Temperature tau annealed by ``exp(-0.0045)`` per
   epoch from tau0=5.  Early-stopped on a converged cost/accuracy plateau.
3. **fine-tune** — theta frozen, softmax replaced by argmax, W trained on L_T.

The module is model-agnostic: models expose

    apply_fn(params, nas, tau, batch, mode) -> predictions

with ``mode`` in {"float", "qat8", "search", "frozen"} and a ``specs`` dict
(LayerCostSpec per NAS layer).  The EdMIPS baseline (core/edmips.py) reuses
this exact loop with layer-wise gamma — the paper runs both under *identical*
training protocols for fairness (Sec. IV-B), and so do we.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.core import mixedprec as mp
from repro.core import regularizers as reg
from repro.optim import optimizers as opt_mod


@dataclasses.dataclass
class SearchSettings:
    cfg: mp.MixedPrecConfig
    objective: str = "size"          # "size" (Eq. 7) or "energy" (Eq. 8)
    lut_name: str = "mpic"
    lam: float = 1e-7                # lambda in Eq. (2)
    warmup_epochs: int = 2
    search_epochs: int = 4           # upper bound; early stop below
    finetune_epochs: int = 2
    theta_frac: float = 0.2          # 20% split for theta updates
    lr_w: float = 1e-3
    lr_theta: float = 1e-2
    early_stop_patience: int = 3     # epochs without cost improvement
    early_stop_rtol: float = 1e-3


@dataclasses.dataclass
class SearchResult:
    params: dict
    nas: dict
    tau: jnp.ndarray
    history: list
    settings: SearchSettings


def _make_steps(apply_fn: Callable, loss_fn: Callable, specs: dict,
                s: SearchSettings):
    """Build the three jitted step functions once per search."""
    opt_w = opt_mod.AdamW(schedule=opt_mod.constant_schedule(s.lr_w),
                          clip_norm=1.0)
    opt_t = opt_mod.AdamW(schedule=opt_mod.constant_schedule(s.lr_theta),
                          clip_norm=None)

    @jax.jit
    def warmup_step(params, ow, step, batch):
        def lt(p):
            pred = apply_fn(p, None, jnp.asarray(s.cfg.tau0), batch, "qat8")
            return loss_fn(pred, batch)
        loss, grads = jax.value_and_grad(lt)(params)
        upd, ow = opt_w.update(grads, ow, params, step)
        return opt_mod.apply_updates(params, upd), ow, loss

    @jax.jit
    def theta_step(params, nas, tau, ot, step, batch):
        def lfull(n):
            pred = apply_fn(params, n, tau, batch, "search")
            lt = loss_fn(pred, batch)
            lr = reg.total_cost(n, tau, specs, s.cfg, s.objective, s.lut_name)
            return lt + s.lam * lr, (lt, lr)
        (loss, (lt, lr)), grads = jax.value_and_grad(lfull, has_aux=True)(nas)
        upd, ot = opt_t.update(grads, ot, nas, step)
        return opt_mod.apply_updates(nas, upd), ot, lt, lr

    @jax.jit
    def w_step(params, nas, tau, ow, step, batch):
        def lt(p):
            pred = apply_fn(p, nas, tau, batch, "search")
            return loss_fn(pred, batch)
        loss, grads = jax.value_and_grad(lt)(params)
        upd, ow = opt_w.update(grads, ow, params, step)
        return opt_mod.apply_updates(params, upd), ow, loss

    @jax.jit
    def finetune_step(params, nas, ow, step, batch):
        def lt(p):
            pred = apply_fn(p, nas, jnp.asarray(1.0), batch, "frozen")
            return loss_fn(pred, batch)
        loss, grads = jax.value_and_grad(lt)(params)
        upd, ow = opt_w.update(grads, ow, params, step)
        return opt_mod.apply_updates(params, upd), ow, loss

    return opt_w, opt_t, warmup_step, theta_step, w_step, finetune_step


def run_search(apply_fn: Callable, loss_fn: Callable, specs: dict,
               params: dict, nas: dict, data_epochs: Callable[[], Iterable],
               settings: SearchSettings,
               eval_fn: Optional[Callable] = None) -> SearchResult:
    """Execute Alg. 1 end to end.

    ``data_epochs()`` returns a fresh iterable of batches for one epoch (the
    caller controls batching/sharding/shuffling).  ``eval_fn(params, nas,
    tau, mode)`` optionally reports a validation metric into the history.
    """
    s = settings
    opt_w, opt_t, warmup_step, theta_step, w_step, finetune_step = _make_steps(
        apply_fn, loss_fn, specs, s)

    ow = opt_w.init(params)
    ot = opt_t.init(nas)
    tau = jnp.asarray(s.cfg.tau0, jnp.float32)
    history = []
    step = 0

    # -- Phase 1: warmup (Alg. 1 l.1-2) -------------------------------------
    for ep in range(s.warmup_epochs):
        for batch in data_epochs():
            params, ow, loss = warmup_step(params, ow, jnp.asarray(step), batch)
            step += 1
        history.append({"phase": "warmup", "epoch": ep, "loss": float(loss)})

    # -- Phase 2: search (Alg. 1 l.3-8) --------------------------------------
    best_cost, stall = None, 0
    for ep in range(s.search_epochs):
        batches = list(data_epochs())
        n_theta = max(1, int(len(batches) * s.theta_frac))
        for batch in batches[:n_theta]:         # 20%: update theta
            nas, ot, lt, lr = theta_step(params, nas, tau, ot,
                                         jnp.asarray(step), batch)
            step += 1
        for batch in batches[n_theta:]:         # 80%: update W
            params, ow, loss = w_step(params, nas, tau, ow,
                                      jnp.asarray(step), batch)
            step += 1
        tau = mp.anneal_tau(tau, s.cfg)          # Alg. 1 l.8
        cost = float(lr)
        history.append({"phase": "search", "epoch": ep, "task_loss": float(lt),
                        "reg_cost": cost, "tau": float(tau)})
        if best_cost is not None and cost >= best_cost * (1 - s.early_stop_rtol):
            stall += 1
            if stall >= s.early_stop_patience:
                break
        else:
            best_cost, stall = cost, 0

    # -- Phase 3: fine-tune (Alg. 1 l.9-11) ----------------------------------
    for ep in range(s.finetune_epochs):
        for batch in data_epochs():
            params, ow, loss = finetune_step(params, nas, ow,
                                             jnp.asarray(step), batch)
            step += 1
        entry = {"phase": "finetune", "epoch": ep, "loss": float(loss)}
        if eval_fn is not None:
            entry["metric"] = float(eval_fn(params, nas, tau, "frozen"))
        history.append(entry)

    return SearchResult(params=params, nas=nas, tau=tau, history=history,
                        settings=s)
