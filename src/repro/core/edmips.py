"""EdMIPS baseline (Cai & Vasconcelos, CVPR 2020) — layer-wise DNAS.

The paper's primary comparison point.  Per Sec. IV-B the baseline is run with
*identical* training protocol (20/80 alternating theta/W updates, tau
annealing) and the *same* PACT quantizer — the only difference is the
granularity of gamma: one row per **layer** instead of one per **channel**.

That makes the baseline a one-line configuration of the same machinery:
``MixedPrecConfig(per_channel=False)``.  ``init_nas_params`` then allocates a
(1, |P_W|) gamma which every channel of the layer shares, and the Eq. 7/8
regularizers fold the single row across c_out (see regularizers.size_cost).

This module exists so experiments name the baseline explicitly rather than
flipping a boolean inline.
"""
from __future__ import annotations

from repro.core import mixedprec as mp


def edmips_config(base: mp.MixedPrecConfig | None = None) -> mp.MixedPrecConfig:
    """Layer-wise variant of a (possibly channel-wise) search config."""
    base = base or mp.MixedPrecConfig()
    return mp.MixedPrecConfig(
        weight_bits=base.weight_bits,
        act_bits=base.act_bits,
        search_acts=base.search_acts,
        fixed_act_bits=base.fixed_act_bits,
        tau0=base.tau0,
        tau_decay=base.tau_decay,
        per_channel=False,
    )


def channelwise_config(base: mp.MixedPrecConfig | None = None) -> mp.MixedPrecConfig:
    """This paper's channel-wise search space (the default)."""
    base = base or mp.MixedPrecConfig()
    return mp.MixedPrecConfig(
        weight_bits=base.weight_bits,
        act_bits=base.act_bits,
        search_acts=base.search_acts,
        fixed_act_bits=base.fixed_act_bits,
        tau0=base.tau0,
        tau_decay=base.tau_decay,
        per_channel=True,
    )
