"""Deployment transform (Sec. III-C) — TPU-adapted, producing ``QTensor``.

The paper's offline pipeline for a searched layer:

1. **argmax** the NAS logits -> one bit-width per output channel;
2. **reorder** the filters, grouping channels by bit-width (this permutes the
   layer's output channels);
3. **propagate** the permutation to the *next* layer's C_in axis so every
   weight still multiplies the right activation (or carry ``inv_perm`` and
   restore canonical order after the matmul — structurally equivalent);
4. **split** the layer into |P_W| fixed-precision sub-layers whose outputs
   concatenate (activations are layer-wise quantized, so concat is free).

TPU adaptation (DESIGN.md §2): the MXU wants output-group sizes that are
multiples of the 128-wide lane dimension, so after grouping we *promote* up to
127 channels per boundary to the next-higher precision to round group sizes up
to 128 (promotion is upward only — it can only add representational power, so
accuracy is never hurt; memory cost of padding is <= (|P_W|-1)*127 channels).

The output of :func:`deploy_linear` is a :class:`repro.api.qtensor.QTensor` —
a registered pytree carrying the packed sub-byte groups, per-channel scales
and the channel permutation.  Unlike the numpy ``DeployedLinear`` it
replaces, a ``QTensor`` flows straight through ``jax.jit``/``jax.vmap`` into
the Pallas ``quant_matmul`` kernels, so the same object serves offline
analysis (``memory_bits``) and the production serving path
(models/serving.py).  Conv weights keep their kernel tail shape inside the
QTensor and serve through ``QTensor.conv2d`` — im2col patch-GEMMs over the
same packed groups (kernels/quant_conv.py), so the conv-dominated MLPerf
Tiny models never re-materialize a dense kernel either (see
docs/deployed_conv.md).  The grouping itself stays offline/one-time, exactly
as in the paper ("performed offline and does not have run-time overheads").
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.api.qtensor import QTensor
from repro.core import mixedprec as mp
from repro.core import quantizers as qz


def group_channels(bits_per_channel: np.ndarray,
                   bitwidths: Sequence[int] = qz.DEFAULT_BITWIDTHS,
                   align: int = 1) -> tuple[np.ndarray, dict]:
    """Reorder channels by bit-width; optionally pad groups to ``align``.

    Returns ``(perm, sizes)`` where ``perm`` lists original channel indices in
    deployed order (ascending precision groups) and ``sizes`` maps bit-width ->
    group size after alignment promotion.

    Alignment promotes the trailing ``size % align`` channels of a group to
    the next-higher precision (upward only).  The highest precision group
    absorbs all leftovers (its size needs no alignment: it is last, and a
    final ragged group costs only one sub-GEMM edge-tile).
    """
    bitwidths = sorted(bitwidths)
    bits_per_channel = np.asarray(bits_per_channel)
    buckets = {b: list(np.nonzero(bits_per_channel == b)[0]) for b in bitwidths}
    unknown = set(np.unique(bits_per_channel)) - set(bitwidths)
    if unknown:
        raise ValueError(f"channels assigned unsupported bit-widths {unknown}")
    # upward promotion for alignment
    for lo, hi in zip(bitwidths[:-1], bitwidths[1:]):
        rem = len(buckets[lo]) % align
        if rem:
            promoted = buckets[lo][-rem:]
            buckets[lo] = buckets[lo][:-rem]
            # keep deterministic ordering: promoted channels go first in the
            # higher bucket so original order inside each bucket is stable
            buckets[hi] = promoted + buckets[hi]
    perm = np.concatenate([np.asarray(buckets[b], dtype=np.int64)
                           for b in bitwidths if buckets[b]] or
                          [np.arange(0, dtype=np.int64)])
    sizes = {b: len(buckets[b]) for b in bitwidths}
    assert perm.shape[0] == bits_per_channel.shape[0]
    return perm, sizes


def deploy_linear(w: np.ndarray, gamma: np.ndarray, alpha_w: np.ndarray,
                  delta: Optional[np.ndarray], alpha_x: float,
                  cfg: mp.MixedPrecConfig, align: int = 1,
                  restore_order: bool = True, tile_n=None) -> QTensor:
    """Full Sec. III-C transform of one searched map ``w`` -> ``QTensor``.

    ``w`` is ``(c_out, ...)`` (trailing dims flatten into the contraction
    axis; conv kernels keep their tail shape inside the QTensor, and their
    channel-major flattening matches the im2col patch layout
    ``QTensor.conv2d`` contracts against).  With ``restore_order=False`` the
    QTensor keeps deployed channel order and the caller must permute the
    next layer's ``c_in`` with ``.perm`` (:func:`propagate_perm`).

    ``tile_n`` (int | ``"auto"`` | None) additionally builds the
    **tile-aligned fused layout** for the single-launch serving kernel:
    every precision group is padded up to the ``tile_n`` output tile (zero
    rows), so each output tile carries exactly one static bit-width and the
    whole weight serves as ONE ``pallas_call``.  ``align`` composes with it:
    ``align=128`` promotion already rounds the non-top groups to the MXU
    lane width, so with ``tile_n=128`` only the top group's tail pads (the
    promotion moves channels *up* in precision, the tile pad adds zero
    rows — both upward-only in representational power).
    """
    w = np.asarray(w, dtype=np.float32)
    c_out = w.shape[0]
    g = np.asarray(gamma).reshape(-1, np.asarray(gamma).shape[-1])
    bits = np.asarray(mp.argmax_weight_bits(jnp.asarray(g), cfg))
    if bits.shape[0] == 1:
        bits = np.broadcast_to(bits, (c_out,)).copy()

    if delta is None:
        act_bits = cfg.fixed_act_bits
    else:
        act_bits = int(np.asarray(mp.argmax_act_bits(jnp.asarray(delta), cfg)))
    levels = (1 << act_bits) - 1
    return QTensor.from_assignment(
        w, bits, np.asarray(alpha_w, np.float32),
        bitwidths=cfg.weight_bits, align=align, restore_order=restore_order,
        act_bits=act_bits, act_scale=float(max(alpha_x, 1e-6)) / levels,
        tile_n=tile_n)


def propagate_perm(next_w: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Permute the *next* layer's input axis (axis 1 of (c_out, c_in)) to
    match this layer's reordered outputs (paper Fig. 2, right)."""
    return np.asarray(next_w)[:, perm]


def dequantize_deployed(qt: QTensor) -> np.ndarray:
    """Reconstruct the float weight matrix (canonical channel order).

    Used by tests to assert the deploy transform is lossless w.r.t. the
    frozen (argmax) fake-quantized weights — canonical channel order even
    for ``restore_order=False`` tensors.
    """
    return np.asarray(qt.dequantize_canonical(jnp.float32))


def memory_bits(qt: QTensor) -> int:
    """Deployed model-size contribution in bits (the Pareto x-axis)."""
    return qt.memory_bits
