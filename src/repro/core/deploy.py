"""Deployment transform (Sec. III-C) — TPU-adapted.

The paper's offline pipeline for a searched layer:

1. **argmax** the NAS logits -> one bit-width per output channel;
2. **reorder** the filters, grouping channels by bit-width (this permutes the
   layer's output channels);
3. **propagate** the permutation to the *next* layer's C_in axis so every
   weight still multiplies the right activation;
4. **split** the layer into |P_W| fixed-precision sub-layers whose outputs
   concatenate (activations are layer-wise quantized, so concat is free).

TPU adaptation (DESIGN.md §2): the MXU wants output-group sizes that are
multiples of the 128-wide lane dimension, so after grouping we *promote* up to
127 channels per boundary to the next-higher precision to round group sizes up
to 128 (promotion is upward only — it can only add representational power, so
accuracy is never hurt; memory cost of padding is <= (|P_W|-1)*127 channels).
The resulting per-precision groups are packed sub-byte (int2 x4 / int4 x2 per
byte) for HBM storage and consumed by kernels/quant_matmul.py as up to three
dense sub-GEMMs — the direct analogue of the paper's three sub-convolutions.

Everything here is offline/one-time (numpy-style, outside jit), exactly as in
the paper ("performed offline and does not have run-time overheads").
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax.numpy as jnp

from repro.core import mixedprec as mp
from repro.core import quantizers as qz


@dataclasses.dataclass
class DeployedLinear:
    """One searched linear map after the deploy transform.

    ``groups`` maps bit-width -> dict with:
       packed   (c_group, c_in // pack_factor) uint8   packed weight rows
       scale    (c_group,) float32                     per-channel dequant step
    ``perm`` is the channel permutation applied to the output (original index
    of each deployed output channel) — the *next* layer's C_in must be
    permuted with it; ``inv_perm`` undoes it for the final layer.
    ``act_bits``/``act_scale`` give the layer-wise activation quantization.
    """
    groups: dict
    perm: np.ndarray
    inv_perm: np.ndarray
    act_bits: int
    act_scale: float
    c_out: int
    c_in: int


def group_channels(bits_per_channel: np.ndarray,
                   bitwidths: Sequence[int] = qz.DEFAULT_BITWIDTHS,
                   align: int = 1) -> tuple[np.ndarray, dict]:
    """Reorder channels by bit-width; optionally pad groups to ``align``.

    Returns ``(perm, sizes)`` where ``perm`` lists original channel indices in
    deployed order (ascending precision groups) and ``sizes`` maps bit-width ->
    group size after alignment promotion.

    Alignment promotes the trailing ``size % align`` channels of a group to
    the next-higher precision (upward only).  The highest precision group
    absorbs all leftovers (its size needs no alignment: it is last, and a
    final ragged group costs only one sub-GEMM edge-tile).
    """
    bitwidths = sorted(bitwidths)
    bits_per_channel = np.asarray(bits_per_channel)
    buckets = {b: list(np.nonzero(bits_per_channel == b)[0]) for b in bitwidths}
    unknown = set(np.unique(bits_per_channel)) - set(bitwidths)
    if unknown:
        raise ValueError(f"channels assigned unsupported bit-widths {unknown}")
    # upward promotion for alignment
    for lo, hi in zip(bitwidths[:-1], bitwidths[1:]):
        rem = len(buckets[lo]) % align
        if rem:
            promoted = buckets[lo][-rem:]
            buckets[lo] = buckets[lo][:-rem]
            # keep deterministic ordering: promoted channels go first in the
            # higher bucket so original order inside each bucket is stable
            buckets[hi] = promoted + buckets[hi]
    perm = np.concatenate([np.asarray(buckets[b], dtype=np.int64)
                           for b in bitwidths if buckets[b]] or
                          [np.arange(0, dtype=np.int64)])
    sizes = {b: len(buckets[b]) for b in bitwidths}
    assert perm.shape[0] == bits_per_channel.shape[0]
    return perm, sizes


def deploy_linear(w: np.ndarray, gamma: np.ndarray, alpha_w: np.ndarray,
                  delta: np.ndarray, alpha_x: float,
                  cfg: mp.MixedPrecConfig, align: int = 1) -> DeployedLinear:
    """Full Sec. III-C transform for one linear map ``w`` of shape (c_out, c_in)."""
    w = np.asarray(w, dtype=np.float32)
    c_out, c_in = w.shape
    g = np.asarray(gamma).reshape(-1, np.asarray(gamma).shape[-1])
    bits = np.asarray(mp.argmax_weight_bits(jnp.asarray(g), cfg))
    if bits.shape[0] == 1:
        bits = np.broadcast_to(bits, (c_out,)).copy()
    perm, sizes = group_channels(bits, cfg.weight_bits, align=align)
    alpha = np.asarray(alpha_w, dtype=np.float32)
    if alpha.ndim == 0:
        alpha = np.broadcast_to(alpha, (c_out,)).copy()

    groups = {}
    offset = 0
    for b in sorted(cfg.weight_bits):
        n = sizes[b]
        if n == 0:
            continue
        idx = perm[offset: offset + n]
        offset += n
        wq, scale = qz.quantize_weight_int(
            jnp.asarray(w[idx]), jnp.asarray(alpha[idx][:, None]), b)
        wq = np.asarray(wq)
        f = qz.pack_factor(b)
        if c_in % f:
            pad = f - c_in % f
            wq = np.pad(wq, ((0, 0), (0, pad)))
        packed = np.asarray(qz.pack_int(jnp.asarray(wq), b))
        groups[b] = {
            "packed": packed,
            "scale": np.asarray(scale).reshape(-1),
            "rows": idx,
        }

    if delta is None:
        act_bits = cfg.fixed_act_bits
    else:
        act_bits = int(np.asarray(mp.argmax_act_bits(jnp.asarray(delta), cfg)))
    levels = (1 << act_bits) - 1
    return DeployedLinear(
        groups=groups,
        perm=perm,
        inv_perm=np.argsort(perm),
        act_bits=act_bits,
        act_scale=float(max(alpha_x, 1e-6)) / levels,
        c_out=c_out,
        c_in=c_in,
    )


def propagate_perm(next_w: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Permute the *next* layer's input axis (axis 1 of (c_out, c_in)) to
    match this layer's reordered outputs (paper Fig. 2, right)."""
    return np.asarray(next_w)[:, perm]


def dequantize_deployed(d: DeployedLinear) -> np.ndarray:
    """Reconstruct the float weight matrix (deployed channel order undone).

    Used by tests to assert the deploy transform is lossless w.r.t. the
    frozen (argmax) fake-quantized weights.
    """
    out = np.zeros((d.c_out, d.c_in), dtype=np.float32)
    for b, grp in d.groups.items():
        unpacked = np.asarray(qz.unpack_int(jnp.asarray(grp["packed"]), b))
        unpacked = unpacked[:, : d.c_in]
        out[grp["rows"]] = unpacked.astype(np.float32) * grp["scale"][:, None]
    return out


def memory_bits(d: DeployedLinear) -> int:
    """Deployed model-size contribution in bits (the Pareto x-axis)."""
    return sum(grp["packed"].size * 8 for grp in d.groups.values())
