"""Channel-wise mixed-precision DNAS (the paper's core contribution).

Implements Sec. III-A: for every quantized linear map we carry

* ``gamma``  — NAS logits, shape ``(c_out, |P_W|)``   (per-channel weight bits)
* ``delta``  — NAS logits, shape ``(|P_X|,)``          (per-layer act bits)
* ``alpha_w``— PACT weight clip, shape ``(c_out,)``    (shared across precisions)
* ``alpha_x``— PACT activation clip, scalar

The softmax with temperature (Eq. 3) is annealed during the search
(``tau *= exp(-0.0045)`` per epoch, tau0 = 5 — Sec. III-B / [21]).

The *effective* tensors (Eq. 4, 5) are mixtures of fake-quantized copies of a
single shared float master tensor.  ``effective_weight``/``effective_act`` are
the differentiable search-time path; ``argmax_*`` provide the discretized
assignment used by the fine-tuning phase and the deploy transform.

Everything here is a pure function over explicit pytrees — no global state —
so the same code runs under jit, scan-over-layers (stacked leading layer dim)
and pjit with sharded ``gamma``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import quantizers as qz


@dataclasses.dataclass(frozen=True)
class MixedPrecConfig:
    """Static configuration of the search space."""
    weight_bits: tuple[int, ...] = qz.DEFAULT_BITWIDTHS   # P_W
    act_bits: tuple[int, ...] = qz.DEFAULT_BITWIDTHS      # P_X
    search_acts: bool = True    # False for the model-size objective (acts @ 8b)
    fixed_act_bits: int = 8     # used when search_acts=False
    tau0: float = 5.0
    tau_decay: float = 0.0045   # tau *= exp(-tau_decay) per epoch
    per_channel: bool = True    # False => layer-wise (EdMIPS baseline)

    @property
    def n_w(self) -> int:
        return len(self.weight_bits)

    @property
    def n_x(self) -> int:
        return len(self.act_bits)


def init_nas_params(key: jax.Array, c_out: int, cfg: MixedPrecConfig) -> dict:
    """Fresh NAS state for one linear map.

    Logits start uniform (zero) so the initial mixture is the plain average —
    matching EdMIPS' initialization; PACT clips are initialized by the caller
    from the warmed-up weights via ``qz.init_weight_alpha``.
    """
    del key  # deterministic init; kept for signature symmetry
    rows = c_out if cfg.per_channel else 1
    return {
        "gamma": jnp.zeros((rows, cfg.n_w), dtype=jnp.float32),
        "delta": jnp.zeros((cfg.n_x,), dtype=jnp.float32),
    }


def softmax_tau(logits: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Eq. (3): softmax with temperature, last axis."""
    return jax.nn.softmax(logits / tau, axis=-1)


def effective_weight(w: jnp.ndarray, gamma: jnp.ndarray, alpha_w: jnp.ndarray,
                     tau: jnp.ndarray, cfg: MixedPrecConfig) -> jnp.ndarray:
    """Eq. (5): per-channel mixture of fake-quantized weight slices.

    ``w``       — float master weights, shape ``(c_out, ...)`` (axis 0 = channel).
    ``gamma``   — ``(c_out, |P_W|)`` (or ``(1, |P_W|)`` for layer-wise).
    ``alpha_w`` — ``(c_out,)`` per-channel clip.
    """
    g = softmax_tau(gamma, tau)                      # (rows, |P_W|)
    bshape = (w.shape[0],) + (1,) * (w.ndim - 1)     # broadcast alpha per channel
    a = alpha_w.reshape(bshape)
    out = jnp.zeros_like(w)
    for i, bits in enumerate(cfg.weight_bits):
        coef = g[:, i] if g.shape[0] == w.shape[0] else g[0, i]
        coef = coef.reshape(bshape) if g.shape[0] == w.shape[0] else coef
        out = out + coef * qz.quantize_weight(w, a, bits)
    return out


def effective_act(x: jnp.ndarray, delta: jnp.ndarray, alpha_x: jnp.ndarray,
                  tau: jnp.ndarray, cfg: MixedPrecConfig,
                  signed: bool = False) -> jnp.ndarray:
    """Eq. (4): layer-wise mixture of fake-quantized activations."""
    if not cfg.search_acts:
        return qz.quantize_act_any(x, alpha_x, cfg.fixed_act_bits, signed)
    d = softmax_tau(delta, tau)                      # (|P_X|,)
    out = jnp.zeros_like(x)
    for i, bits in enumerate(cfg.act_bits):
        out = out + d[i] * qz.quantize_act_any(x, alpha_x, bits, signed)
    return out


def argmax_weight_bits(gamma: jnp.ndarray, cfg: MixedPrecConfig) -> jnp.ndarray:
    """Discrete per-channel assignment (end of search / deploy): (c_out,) ints."""
    idx = jnp.argmax(gamma, axis=-1)
    table = jnp.asarray(cfg.weight_bits, dtype=jnp.int32)
    return table[idx]


def argmax_act_bits(delta: jnp.ndarray, cfg: MixedPrecConfig) -> int | jnp.ndarray:
    if not cfg.search_acts:
        return jnp.asarray(cfg.fixed_act_bits, dtype=jnp.int32)
    table = jnp.asarray(cfg.act_bits, dtype=jnp.int32)
    return table[jnp.argmax(delta)]


def frozen_weight(w: jnp.ndarray, gamma: jnp.ndarray, alpha_w: jnp.ndarray,
                  cfg: MixedPrecConfig) -> jnp.ndarray:
    """Fine-tuning-phase weights: argmax replaces softmax (Alg. 1 line 10).

    Implemented with one-hot masks so it stays a single vectorized expression
    (scan/jit friendly) instead of a per-channel gather.
    """
    idx = jnp.argmax(gamma, axis=-1)                 # (rows,)
    if gamma.shape[0] == 1:
        idx = jnp.broadcast_to(idx, (w.shape[0],))
    bshape = (w.shape[0],) + (1,) * (w.ndim - 1)
    a = alpha_w.reshape(bshape)
    out = jnp.zeros_like(w)
    for i, bits in enumerate(cfg.weight_bits):
        mask = (idx == i).reshape(bshape)
        out = out + jnp.where(mask, qz.quantize_weight(w, a, bits), 0.0)
    return out


def frozen_act(x: jnp.ndarray, delta: jnp.ndarray, alpha_x: jnp.ndarray,
               cfg: MixedPrecConfig, signed: bool = False) -> jnp.ndarray:
    """Fine-tuning-phase activations: single argmax-selected precision."""
    if not cfg.search_acts:
        return qz.quantize_act_any(x, alpha_x, cfg.fixed_act_bits, signed)
    idx = jnp.argmax(delta)
    out = jnp.zeros_like(x)
    for i, bits in enumerate(cfg.act_bits):
        out = out + jnp.where(idx == i,
                              qz.quantize_act_any(x, alpha_x, bits, signed), 0.0)
    return out


def anneal_tau(tau: jnp.ndarray, cfg: MixedPrecConfig) -> jnp.ndarray:
    """One epoch of temperature annealing (Sec. III-B)."""
    return tau * jnp.exp(-cfg.tau_decay)


# ---------------------------------------------------------------------------
# Expected-bits statistics — consumed by the regularizers (Eq. 7/8) and by
# reporting.  Kept here so layer code and regularizer code cannot drift.
# ---------------------------------------------------------------------------

def expected_weight_bits(gamma: jnp.ndarray, tau: jnp.ndarray,
                         cfg: MixedPrecConfig) -> jnp.ndarray:
    """Per-channel expected bit-width  Σ_p γ̂_p · p  — shape (rows,)."""
    g = softmax_tau(gamma, tau)
    bits = jnp.asarray(cfg.weight_bits, dtype=g.dtype)
    return g @ bits


def act_bit_probs(delta: jnp.ndarray, tau: jnp.ndarray,
                  cfg: MixedPrecConfig) -> jnp.ndarray:
    """δ̂ — shape (|P_X|,); degenerate one-hot when acts are fixed."""
    if not cfg.search_acts:
        onehot = jnp.asarray(
            [1.0 if b == cfg.fixed_act_bits else 0.0 for b in cfg.act_bits],
            dtype=jnp.float32)
        return onehot
    return softmax_tau(delta, tau)
