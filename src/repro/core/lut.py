"""Energy/cost look-up tables C(p_x, p_w) for the Eq. (8) regularizer.

The paper populates the LUT by profiling the MPIC RISC-V core @ 250 MHz for
every (activation-bits, weight-bits) pair in {2,4,8}².  The exact per-OP
energies are not tabulated in the paper text, so we reconstruct a LUT with the
properties the paper states: (i) energy/OP is *not* linear in bit-width
(sub-byte ops share the datapath, so 2b is cheaper than 8b but far less than
4x cheaper), (ii) cost is roughly symmetric in p_x/p_w, (iii) 8x8 is the unit
of reference.  Values are in pJ/MAC, normalized so C(8,8) = 1.0 — the
regularizer only needs *relative* costs, and the Pareto sweep over lambda
absorbs any global scale.

For the TPU v5e deployment target the analogous cost model is HBM bytes moved
per weight (decode is bandwidth bound), which IS linear in weight bits and
independent of activation bits; both LUTs expose the same interface so either
backend plugs into the regularizer.
"""
from __future__ import annotations

import jax.numpy as jnp

# Rows: p_x in (2,4,8); cols: p_w in (2,4,8).  Normalized energy/OP.
# Reconstruction of the MPIC profile (Ottavi et al., ISVLSI 2020 report
# roughly 1.2-2x energy between successive precisions on the MAC datapath;
# sub-byte benefits saturate because fetch/decode is shared).
MPIC_LUT = jnp.asarray(
    [
        # p_w=2   p_w=4   p_w=8
        [0.40,   0.48,   0.62],   # p_x = 2
        [0.48,   0.55,   0.72],   # p_x = 4
        [0.62,   0.72,   1.00],   # p_x = 8
    ],
    dtype=jnp.float32,
)

# TPU v5e weight-bandwidth cost: decode-time energy/latency per op is
# dominated by weight HBM traffic => proportional to p_w, flat in p_x.
TPU_BW_LUT = jnp.asarray(
    [
        [2 / 8, 4 / 8, 1.0],
        [2 / 8, 4 / 8, 1.0],
        [2 / 8, 4 / 8, 1.0],
    ],
    dtype=jnp.float32,
)

LUTS = {"mpic": MPIC_LUT, "tpu_bw": TPU_BW_LUT}


def get_lut(name: str) -> jnp.ndarray:
    try:
        return LUTS[name]
    except KeyError:
        raise KeyError(f"unknown cost LUT {name!r}; available: {sorted(LUTS)}")
