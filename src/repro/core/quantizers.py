"""Quantizers: affine fake-quantization with straight-through estimators.

Implements the paper's Eq. (1) affine scheme and the PACT variant (learnable
clipping, Choi et al. 2018) used for both activations and weights, exactly as
the paper replaces EdMIPS' Gaussian quantizer with PACT (Sec. III-A).

All functions are pure and jit/vmap/scan friendly.  Gradients flow through the
round/clamp via the straight-through estimator (STE):

    fq(x) = x + stop_grad(q(x) - x)

For PACT the clip parameter ``alpha`` receives its analytic gradient (the
gradient of the clamp boundary), which falls out naturally from expressing the
clamp with ``jnp.clip`` *outside* the stop_gradient.

Conventions
-----------
* Activations are quantized **unsigned** on ``[0, alpha]`` (post-ReLU/GELU
  tensors; the affine zero-point is 0) — Eq. (1) with ``alpha_t = 0``.
* Weights are quantized **symmetric signed** on ``[-alpha, alpha]`` with
  ``2^n - 1`` levels (zero exactly representable).
* Per-channel weight quantization uses one ``alpha`` per output channel
  (axis 0 of the weight as stored ``(c_out, ...)`` — callers reshape).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

# Bit-widths supported by the search space (and by the MPIC deployment
# target of the paper).  Kept as a module constant so regularizers, the
# deploy transform and the Pallas kernels agree on ordering.
DEFAULT_BITWIDTHS: tuple[int, ...] = (2, 4, 8)


def _round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """round() with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def quantize_act(x: jnp.ndarray, alpha: jnp.ndarray, bits: int) -> jnp.ndarray:
    """PACT fake-quantization for activations (unsigned, [0, alpha]).

    Eq. (1) of the paper with alpha_t = 0, eps = alpha / (2^n - 1).
    ``alpha`` is a learnable scalar (or broadcastable) clip value.
    """
    alpha = jnp.maximum(alpha, 1e-6)  # keep the step strictly positive
    levels = (1 << bits) - 1
    # clip participates in the alpha gradient; round is STE.
    y = jnp.clip(x, 0.0, alpha)
    step = alpha / levels
    return _round_ste(y / step) * step


def quantize_act_signed(x: jnp.ndarray, alpha: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric signed PACT for activations.

    The paper quantizes post-ReLU CNN activations unsigned; transformer hidden
    states are signed, so the LM-family archs use this variant (recorded as a
    hardware/domain adaptation in DESIGN.md).  Same STE/clip-gradient
    structure as :func:`quantize_act`.
    """
    alpha = jnp.maximum(alpha, 1e-6)
    half_levels = (1 << (bits - 1)) - 1
    y = jnp.clip(x, -alpha, alpha)
    step = alpha / half_levels
    return _round_ste(y / step) * step


def quantize_act_any(x: jnp.ndarray, alpha: jnp.ndarray, bits: int,
                     signed: bool) -> jnp.ndarray:
    return (quantize_act_signed if signed else quantize_act)(x, alpha, bits)


def quantize_weight(w: jnp.ndarray, alpha: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric signed PACT-style fake-quantization for weights.

    ``alpha`` broadcasts against ``w`` — pass shape ``(c_out, 1, ...)`` for
    per-channel clipping (the paper shares one float master tensor across all
    precisions; only the number of levels changes per ``bits``).
    """
    alpha = jnp.maximum(alpha, 1e-6)
    half_levels = (1 << (bits - 1)) - 1  # e.g. 127 for 8b, 7 for 4b, 1 for 2b
    y = jnp.clip(w, -alpha, alpha)
    step = alpha / half_levels
    return _round_ste(y / step) * step


def quantize_weight_int(w: jnp.ndarray, alpha: jnp.ndarray, bits: int
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """True integer quantization (deployment path, no STE).

    Returns ``(q, scale)`` with ``q`` int8-typed integers in
    ``[-half_levels, half_levels]`` and ``w ≈ q * scale``.
    """
    alpha = jnp.maximum(alpha, 1e-6)
    half_levels = (1 << (bits - 1)) - 1
    step = alpha / half_levels
    q = jnp.clip(jnp.round(w / step), -half_levels, half_levels).astype(jnp.int8)
    return q, step


def quantize_act_int(x: jnp.ndarray, alpha: jnp.ndarray, bits: int
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Unsigned integer activation quantization (deployment path)."""
    alpha = jnp.maximum(alpha, 1e-6)
    levels = (1 << bits) - 1
    step = alpha / levels
    q = jnp.clip(jnp.round(x / step), 0, levels).astype(jnp.uint8)
    return q, step


# ---------------------------------------------------------------------------
# Sub-byte packing.  TPU HBM is byte addressed; int4/int2 weights are stored
# packed into uint8 (2 resp. 4 values per byte) and unpacked in VMEM by the
# Pallas kernel (kernels/quant_matmul.py) or by the jnp fallback below.
# Packing is along the LAST axis, which must be divisible by the pack factor.
# ---------------------------------------------------------------------------

def pack_factor(bits: int) -> int:
    assert bits in (2, 4, 8), bits
    return 8 // bits


def pack_int(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack signed integers (int8 storage, values fit in ``bits``) to uint8.

    Values are biased to unsigned (two's-complement within ``bits``) before
    packing so unpacking is branch-free.
    """
    if bits == 8:
        return q.astype(jnp.int8).view(jnp.uint8) if q.dtype != jnp.uint8 else q
    f = pack_factor(bits)
    assert q.shape[-1] % f == 0, (q.shape, bits)
    mask = (1 << bits) - 1
    u = (q.astype(jnp.int32) & mask).astype(jnp.uint8)
    u = u.reshape(*q.shape[:-1], q.shape[-1] // f, f)
    return _pack_fold(u, bits)


def _pack_fold(u: jnp.ndarray, bits: int) -> jnp.ndarray:
    """OR-fold the trailing pack axis of unsigned lanes into single bytes.

    Value ``j`` of byte ``b`` sits at bit ``j * bits`` — the layout
    ``unpack_int`` and the Pallas kernel's in-VMEM unpack both assume.
    """
    out = jnp.zeros(u.shape[:-1], dtype=jnp.uint8)
    for i in range(u.shape[-1]):
        out = out | (u[..., i] << jnp.uint8(i * bits)).astype(jnp.uint8)
    return out


def unpack_int(packed: jnp.ndarray, bits: int, signed: bool = True) -> jnp.ndarray:
    """Inverse of :func:`pack_int`; returns int8 values, last axis expanded."""
    if bits == 8:
        return packed.view(jnp.int8) if signed else packed
    f = pack_factor(bits)
    mask = (1 << bits) - 1
    shifts = jnp.arange(f, dtype=jnp.uint8) * bits
    u = (packed[..., None] >> shifts) & mask  # (..., f) uint8
    u = u.reshape(*packed.shape[:-1], packed.shape[-1] * f).astype(jnp.int8)
    if signed:
        # sign-extend from ``bits`` to 8
        sign_bit = 1 << (bits - 1)
        u = jnp.where(u >= sign_bit, u - (1 << bits), u).astype(jnp.int8)
    return u


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Fake-quant banks: the DNAS needs all |P| fake-quantized copies of a tensor
# at once (Eq. 4 / Eq. 5).  Generated on the fly from the single float master
# tensor (weight sharing — Sec. III-A).
# ---------------------------------------------------------------------------

def act_bank(x: jnp.ndarray, alpha: jnp.ndarray,
             bitwidths: Sequence[int] = DEFAULT_BITWIDTHS) -> jnp.ndarray:
    """Stack of fake-quantized activations, shape ``(|P_X|, *x.shape)``."""
    return jnp.stack([quantize_act(x, alpha, b) for b in bitwidths])


def weight_bank(w: jnp.ndarray, alpha: jnp.ndarray,
                bitwidths: Sequence[int] = DEFAULT_BITWIDTHS) -> jnp.ndarray:
    """Stack of fake-quantized weights, shape ``(|P_W|, *w.shape)``."""
    return jnp.stack([quantize_weight(w, alpha, b) for b in bitwidths])


def init_act_alpha() -> jnp.ndarray:
    """PACT initializes the activation clip around the expected dynamic range."""
    return jnp.asarray(6.0, dtype=jnp.float32)  # ReLU6-like prior


def init_weight_alpha(w: jnp.ndarray, per_channel: bool = True) -> jnp.ndarray:
    """Init weight clip to the per-channel max-abs (axis 0 = output channel)."""
    if per_channel:
        reduce_axes = tuple(range(1, w.ndim))
        a = jnp.max(jnp.abs(w), axis=reduce_axes)
        return jnp.maximum(a, 1e-3).astype(jnp.float32)
    return jnp.maximum(jnp.max(jnp.abs(w)), 1e-3).astype(jnp.float32)
