"""Differentiable cost regularizers — Eq. (7) (model size) and Eq. (8) (energy).

Each quantized linear map in a model registers a ``LayerCostSpec`` describing
its static geometry; the regularizer then consumes the *live* NAS state
(gamma/delta + tau) to compute the expected cost.  The total L_R is the sum
over layers (Sec. III-A, last paragraph); the training loss is Eq. (2):
``L = L_T + lambda * L_R``.

Shapes are written so the same code handles:
  * per-channel gamma   (c_out, |P_W|)   — this paper
  * layer-wise gamma    (1, |P_W|)       — the EdMIPS baseline
  * stacked-by-layer gamma (L, c_out, |P_W|) — scan-over-layers transformers
    (the leading axis is folded into the channel axis; cost sums anyway).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

from repro.core import lut as lut_mod
from repro.core import mixedprec as mp


@dataclasses.dataclass(frozen=True)
class LayerCostSpec:
    """Static per-layer geometry needed by Eq. (7)/(8).

    For a Conv layer: ``weights_per_channel = C_in * Kx * Ky`` and
    ``ops = C_out * C_in * Kx * Ky * H_out * W_out`` (MACs).
    For an FC/linear layer: ``weights_per_channel = C_in`` and
    ``ops = C_out * C_in * tokens``.
    """
    name: str
    c_out: int
    weights_per_channel: int   # C_in * Kx * Ky
    ops: int                   # Omega^(n): total MACs to produce the output


def size_cost(gamma: jnp.ndarray, tau: jnp.ndarray, spec: LayerCostSpec,
              cfg: mp.MixedPrecConfig) -> jnp.ndarray:
    """Eq. (7): expected weight bits of one layer.

    ``C_in*Kx*Ky * Σ_i Σ_p γ̂_{i,p} · p``.  When gamma is layer-wise (1 row)
    the row is implicitly shared by all c_out channels.
    """
    g = gamma.reshape(-1, gamma.shape[-1])            # fold any leading dims
    ebits = mp.softmax_tau(g, tau) @ jnp.asarray(cfg.weight_bits, jnp.float32)
    rows = g.shape[0]
    # Layer-wise gamma (rows=1) represents all c_out channels with one row;
    # per-channel gamma has rows == c_out and multiplier 1.  For stacked
    # scan-over-layers trees the caller sets spec.c_out = total rows.
    multiplier = spec.c_out / rows
    return spec.weights_per_channel * multiplier * jnp.sum(ebits)


def energy_cost(gamma: jnp.ndarray, delta: jnp.ndarray, tau: jnp.ndarray,
                spec: LayerCostSpec, cfg: mp.MixedPrecConfig,
                lut: jnp.ndarray) -> jnp.ndarray:
    """Eq. (8): Omega * Σ_{p_x} δ̂_{p_x} Σ_i Σ_{p_w} γ̂_{i,p_w} C(p_x,p_w).

    ``lut[xi, wi]`` must be indexed in the order of cfg.act_bits/weight_bits.
    The per-channel sum Σ_i γ̂ divides by c_out implicitly via ops-per-channel:
    Omega counts ops for ALL channels, each channel contributes ops/c_out.
    """
    g = gamma.reshape(-1, gamma.shape[-1])
    ghat = mp.softmax_tau(g, tau)                     # (rows, |P_W|)
    dhat = mp.act_bit_probs(delta, tau, cfg)          # (|P_X|,) or (L, |P_X|)
    rows = g.shape[0]
    # Each row accounts for ops/rows MACs: rows==c_out -> per-channel ops;
    # rows==1 (layer-wise) -> the whole layer's ops.
    ops_per_row = spec.ops / rows
    if dhat.ndim == 1:
        # expected energy/op for each row: (rows,) = γ̂ @ lutᵀ @ δ̂
        per_row = ghat @ (lut.T @ dhat)               # (rows,)
        return ops_per_row * jnp.sum(per_row)
    # stacked scan-over-layers site: delta is per layer; rows are layer-major
    Ld = dhat.shape[0]
    ghat = ghat.reshape(Ld, rows // Ld, ghat.shape[-1])   # (L, c_out, |P_W|)
    per = jnp.einsum("lrp,qp,lq->", ghat, lut, dhat)
    return ops_per_row * per


def total_cost(nas_tree: dict, tau: jnp.ndarray, specs: dict,
               cfg: mp.MixedPrecConfig, objective: str = "size",
               lut_name: str = "mpic") -> jnp.ndarray:
    """Sum L_R over all registered layers.

    ``nas_tree`` maps layer-name -> {"gamma": ..., "delta": ...};
    ``specs`` maps layer-name -> LayerCostSpec.  Layers present in the tree
    but lacking a spec are an error (silent cost omissions are how NAS
    regularizers rot).
    """
    total = jnp.zeros((), jnp.float32)
    lut = lut_mod.get_lut(lut_name)
    for name, nas in nas_tree.items():
        spec = specs.get(name)
        if spec is None:
            raise KeyError(f"NAS layer {name!r} has no LayerCostSpec")
        if objective == "size":
            total = total + size_cost(nas["gamma"], tau, spec, cfg)
        elif objective == "energy":
            total = total + energy_cost(nas["gamma"], nas["delta"], tau, spec,
                                        cfg, lut)
        else:
            raise ValueError(f"unknown objective {objective!r}")
    return total


def discrete_size_bits(nas_tree: dict, specs: dict,
                       cfg: mp.MixedPrecConfig) -> float:
    """Post-search *discrete* model size in bits (argmax assignment).

    This is the number reported on the Pareto plots' x-axis (model size),
    as opposed to the differentiable expectation used during training.
    """
    total = 0.0
    for name, nas in nas_tree.items():
        spec = specs[name]
        g = nas["gamma"].reshape(-1, nas["gamma"].shape[-1])
        bits = mp.argmax_weight_bits(g, cfg)             # (rows,)
        rows = int(bits.shape[0])
        total += float(spec.weights_per_channel * (spec.c_out / rows)
                       * jnp.sum(bits))
    return total


def discrete_energy(nas_tree: dict, specs: dict, cfg: mp.MixedPrecConfig,
                    lut_name: str = "mpic") -> float:
    """Post-search discrete energy estimate (argmax assignment)."""
    lut = lut_mod.get_lut(lut_name)
    total = 0.0
    for name, nas in nas_tree.items():
        spec = specs[name]
        g = nas["gamma"].reshape(-1, nas["gamma"].shape[-1])
        widx = jnp.argmax(g, axis=-1)                           # (rows,)
        rows = g.shape[0]
        d = nas["delta"]
        if not cfg.search_acts:
            xidx = jnp.full((rows,), cfg.act_bits.index(cfg.fixed_act_bits))
        elif d.ndim == 1:
            xidx = jnp.full((rows,), jnp.argmax(d))
        else:  # stacked per-layer delta; rows are layer-major
            Ld = d.shape[0]
            xidx = jnp.repeat(jnp.argmax(d, axis=-1), rows // Ld)
        total += float(spec.ops / rows * jnp.sum(lut[xidx, widx]))
    return total
