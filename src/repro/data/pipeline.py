"""Data pipelines: deterministic synthetic streams + host-sharded loading.

Production posture: each host produces only its shard of the global batch
(``host_slice``), batches are built ahead of time on a background thread
(double-buffered prefetch), and the pipeline state (epoch, step, rng) is
checkpointable so a restarted job resumes mid-epoch without replaying data —
required for fault-tolerant training (train/checkpoint.py stores it).

Synthetic generators exist for every modality the assigned archs need:
token streams (LM), frame embeddings (audio stub), patch embeddings (vlm
stub), CIFAR-like images, MFCC-like spectrograms and AD vectors for the
paper's MLPerf-Tiny tasks.  All are seeded and reproducible.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass
class PipelineState:
    """Checkpointable position of the stream."""
    seed: int
    step: int = 0

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticLM:
    """Deterministic token stream: batch i is a pure function of (seed, i).

    Labels are the next-token shift of the tokens; a simple Markov-ish
    structure (token_{t+1} depends on token_t) gives the models something
    learnable for convergence tests.
    """

    def __init__(self, vocab: int, seq: int, global_batch: int,
                 host_count: int = 1, host_id: int = 0, seed: int = 0,
                 extra: Optional[dict] = None):
        assert global_batch % host_count == 0
        self.vocab, self.seq = vocab, seq
        self.local_batch = global_batch // host_count
        self.host_id, self.host_count = host_id, host_count
        self.state = PipelineState(seed=seed)
        self.extra = extra or {}

    def _gen(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + step) * 131 + self.host_id)
        B, S, V = self.local_batch, self.seq, self.vocab
        base = rng.integers(0, V, size=(B, S + 1), dtype=np.int64)
        # inject learnable structure: even positions copy previous token
        base[:, 2::2] = (base[:, 1:-1:2] * 31 + 7) % V
        batch = {"tokens": base[:, :-1].astype(np.int32),
                 "labels": base[:, 1:].astype(np.int32)}
        for name, shape in self.extra.items():
            batch[name] = rng.standard_normal((B, *shape)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            batch = self._gen(self.state.step)
            # advance BEFORE yielding: a checkpoint taken after consuming
            # batch k must record position k+1, or restart replays a batch
            # (caught by test_pipeline_state_checkpointable)
            self.state.step += 1
            yield batch

    def epoch(self, n_batches: int):
        """Finite slice for Alg. 1's epoch-structured loops."""
        start = self.state.step
        for i in range(n_batches):
            yield self._gen(start + i)
        self.state.step = start + n_batches


class SyntheticTiny:
    """Synthetic datasets for the MLPerf-Tiny tasks (class-conditional
    Gaussian blobs — enough signal for the DNAS machinery to be exercised
    end-to-end and for accuracy-vs-cost Pareto sweeps to be meaningful)."""

    def __init__(self, cfg, n: int = 512, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.cfg = cfg
        if cfg.task == "ad":
            self.x = rng.standard_normal((n, 640)).astype(np.float32)
            # anomalies: shifted distribution, used only for AUC eval
            self.x_anom = (rng.standard_normal((n // 4, 640)) * 1.8 + 1.0
                           ).astype(np.float32)
            self.y = None
        else:
            C = cfg.n_classes
            self.y = rng.integers(0, C, size=n).astype(np.int32)
            protos = rng.standard_normal((C, *cfg.input_shape)) * 1.5
            self.x = (protos[self.y]
                      + rng.standard_normal((n, *cfg.input_shape))
                      ).astype(np.float32)

    def batches(self, batch_size: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(self.x))
        for i in range(0, len(idx) - batch_size + 1, batch_size):
            sel = idx[i:i + batch_size]
            b = {"x": self.x[sel]}
            if self.y is not None:
                b["y"] = self.y[sel]
            yield b


class Prefetcher:
    """Background-thread double buffering: overlaps host data generation
    with device compute (the standard input-pipeline optimization)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.it = it
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        try:
            for item in self.it:
                if self._stop.is_set():
                    return
                self.q.put(item)
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def input_batch_for(cfg, seq: int, global_batch: int, seed: int = 0) -> dict:
    """One concrete (host-local) batch matching input_specs(cfg) shapes —
    used by smoke tests; the dry-run itself uses ShapeDtypeStructs only."""
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = (cfg.encoder_seq, cfg.d_model)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        extra["prefix_embeds"] = (cfg.n_prefix_tokens, cfg.d_model)
    gen = SyntheticLM(cfg.vocab_size, seq, global_batch, seed=seed,
                      extra=extra)
    return gen._gen(0)
