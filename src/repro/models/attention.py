"""Attention variants: GQA (train/prefill/decode), MLA (DeepSeek-style with
latent KV compression + decode-time weight absorption), and cross-attention
(whisper).  All projections are quantization-aware (models/layers.qlinear).

Full-sequence attention uses a *blockwise online-softmax* formulation
(lax.scan over KV chunks) so the S×S score matrix never materializes — this
is what makes the 32k-prefill dry-run cells fit in HBM, and it is the compute
pattern a Pallas flash kernel would implement on real hardware (the jnp
version is the oracle; see kernels/).

KV caches default to int8 with per-token scales (layer-wise activation
quantization applied to the cache — the paper's activation scheme, DESIGN.md
§2).  A ``kv_spec`` (models/kv_quant.KVQuantSpec) switches a ring to the
**channel-wise packed** layout: contiguous feature-axis channel groups at
2/4/8 bits, one scale per token per group, stored packed in uint8; decode
then either dequantizes with the jnp reference or — ``backend="pallas"`` —
runs the fused decode-attention kernel that unpacks+scales ring tiles in
VMEM right before the dot (kernels/decode_attention.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.cache import paged
from repro.dist import sharding as shd
from repro.dist.sharding import constrain
from repro.api.policy import PrecisionPolicy
from repro.kernels import decode_attention as datt_kernel
from repro.models import kv_quant as kvq
from repro.models import layers as L


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, dtype) -> tuple[dict, dict]:
    """Returns (params, nas) for one GQA attention block."""
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    params = {
        "wq": L.linear_init(ks[0], d, H * hd, dtype, bias=cfg.qkv_bias),
        "wk": L.linear_init(ks[1], d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wv": L.linear_init(ks[2], d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wo": L.linear_init(ks[3], H * hd, d, dtype),
    }
    nas = {name: L.nas_init(ks[i], p["w"].shape[0], cfg.quant)
           for i, (name, p) in enumerate(params.items())}
    return params, nas


def init_mla(key, cfg, dtype) -> tuple[dict, dict]:
    """DeepSeek-V3 Multi-head Latent Attention parameters."""
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    params = {
        "wq_a": L.linear_init(ks[0], d, qr, dtype),
        "wq_b": L.linear_init(ks[1], qr, H * (nope + rope), dtype),
        "wkv_a": L.linear_init(ks[2], d, kvr + rope, dtype),
        "wkv_b": L.linear_init(ks[3], kvr, H * (nope + vd), dtype),
        "wo": L.linear_init(ks[4], H * vd, d, dtype),
        "q_norm": L.norm_init(qr, "rmsnorm", dtype),
        "kv_norm": L.norm_init(kvr, "rmsnorm", dtype),
    }
    nas = {name: L.nas_init(ks[min(i, 5)], params[name]["w"].shape[0], cfg.quant)
           for i, name in enumerate(("wq_a", "wq_b", "wkv_a", "wkv_b", "wo"))}
    return params, nas


# ---------------------------------------------------------------------------
# Blockwise (online-softmax) attention core
# ---------------------------------------------------------------------------

def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool, k_chunk: int = 1024,
                        q_offset: int = 0) -> jnp.ndarray:
    """softmax(q kᵀ / sqrt(d)) v without materializing the S_q×S_kv matrix.

    q: (B, H, Sq, D); k/v: (B, H, Skv, D) (GQA callers pre-broadcast KV heads
    by reshaping into (B, KV, rep, ...) groups — see gqa_core).
    Scans over KV chunks maintaining running (max, denom, numerator).
    """
    B, H, Sq, D = q.shape
    Dv = v.shape[-1]                 # MLA: value head dim may differ from qk
    Skv = k.shape[2]
    k_chunk = min(k_chunk, Skv)
    n_chunks = math.ceil(Skv / k_chunk)
    pad = n_chunks * k_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    q = constrain(q, "D", "M", None, None)
    kc = k.reshape(B, H, n_chunks, k_chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, n_chunks, k_chunk, Dv).transpose(2, 0, 1, 3, 4)
    kc = constrain(kc, None, "D", "M", None, None)
    vc = constrain(vc, None, "D", "M", None, None)
    scale = 1.0 / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m, d_sum, acc = carry
        kb, vb, ci = xs
        kb = constrain(kb, "D", "M", None, None)
        vb = constrain(vb, "D", "M", None, None)
        kv_pos = ci * k_chunk + jnp.arange(k_chunk)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kb).astype(jnp.float32) * scale
        mask = kv_pos[None, :] < Skv  # padding mask
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        else:
            mask = jnp.broadcast_to(mask, (Sq, k_chunk))
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard -inf rows (fully masked chunk): exp(-inf - -inf) -> use safe max
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(m), corr, 0.0)
        d_new = d_sum * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, d_new, acc_new), None

    m0 = constrain(jnp.full((B, H, Sq), -jnp.inf, jnp.float32),
                   "D", "M", None)
    d0 = constrain(jnp.zeros((B, H, Sq), jnp.float32), "D", "M", None)
    a0 = constrain(jnp.zeros((B, H, Sq, Dv), jnp.float32),
                   "D", "M", None, None)
    (m, d_sum, acc), _ = jax.lax.scan(
        body, (m0, d0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(d_sum, 1e-30)[..., None]
    return out.astype(q.dtype)


def gqa_core(q, k, v, n_heads: int, n_kv: int, causal: bool,
             q_offset: int = 0, k_chunk: int = 1024) -> jnp.ndarray:
    """Grouped-query attention: q (B,S,H,D), k/v (B,S,KV,D) -> (B,S,H,D)."""
    # serving under a mesh: attention math runs replicated (the f32 softmax
    # reduction order must not depend on the partitioning) — identity on a
    # single device and during training
    q, k, v = (shd.replicate_serving(t) for t in (q, k, v))
    B, Sq, H, D = q.shape
    Dv = v.shape[-1]                 # MLA: value head dim may differ from qk
    rep = n_heads // n_kv
    qh = q.transpose(0, 2, 1, 3)     # (B, H, Sq, D)
    kh = k.transpose(0, 2, 1, 3)     # (B, KV, Skv, D)
    vh = v.transpose(0, 2, 1, 3)
    kh = jnp.repeat(kh, rep, axis=1) if rep > 1 else kh
    vh = jnp.repeat(vh, rep, axis=1) if rep > 1 else vh
    out = blockwise_attention(qh, kh, vh, causal, k_chunk, q_offset)
    return out.reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# GQA block: train/prefill and cached-decode paths
# ---------------------------------------------------------------------------

def gqa_forward(p: dict, nas: Optional[dict], policy: PrecisionPolicy, cfg,
                x: jnp.ndarray, positions: jnp.ndarray, causal: bool = True,
                k_chunk: int = 1024) -> jnp.ndarray:
    """Full-sequence GQA with RoPE. x: (B, S, d)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.cdtype
    getn = (lambda n: nas[n]) if nas is not None else (lambda n: None)
    q = L.qlinear(x, p["wq"], getn("wq"), policy, cfg.quant, compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))
    k = L.qlinear(x, p["wk"], getn("wk"), policy, cfg.quant, compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))
    v = L.qlinear(x, p["wv"], getn("wv"), policy, cfg.quant, compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))
    q = constrain(q.reshape(B, S, H, hd), "D", None, "M", None)
    k = constrain(k.reshape(B, S, KV, hd), "D", None, "M", None)
    v = constrain(v.reshape(B, S, KV, hd), "D", None, "M", None)
    if cfg.rope_partial > 0:
        cos, sin, rot = L.rope_freqs(hd, cfg.rope_theta, positions,
                                     cfg.rope_partial)
        q = L.apply_rope(q, cos, sin, rot)
        k = L.apply_rope(k, cos, sin, rot)
    o = gqa_core(q, k, v, H, KV, causal, k_chunk=k_chunk)
    o = o.reshape(B, S, H * hd)
    return L.qlinear(o, p["wo"], getn("wo"), policy, cfg.quant,
                     compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))


def init_gqa_cache(cfg, batch: int, max_len: int,
                   spec: Optional[kvq.KVQuantSpec] = None) -> dict:
    """GQA ring cache.  ``spec=None``: legacy int8 values + per-token scales;
    with a spec the value leaves hold packed sub-byte rows (uint8, feature
    axis in bytes) and the scale leaves one f32 per channel group — same
    keys and tree structure either way, so the paging/merge machinery in
    models/serving.py is layout-agnostic."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    if spec is None:
        return {
            "k": jnp.zeros((batch, KV, max_len, hd), jnp.int8),
            "v": jnp.zeros((batch, KV, max_len, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, KV, max_len, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, KV, max_len, 1), jnp.float32),
        }
    assert spec.feat == hd, (spec, hd)
    nb, G = spec.packed_bytes, spec.n_groups
    return {
        "k": jnp.zeros((batch, KV, max_len, nb), jnp.uint8),
        "v": jnp.zeros((batch, KV, max_len, nb), jnp.uint8),
        "k_scale": jnp.zeros((batch, KV, max_len, G), jnp.float32),
        "v_scale": jnp.zeros((batch, KV, max_len, G), jnp.float32),
    }


def quant_per_token(t: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-token symmetric int8 quantization of KV-cache entries.

    ``t (..., D) -> (q int8 (..., D), scale f32 (..., 1))`` with
    ``t ≈ q * scale``; one amax over the feature axis per leading index —
    the paper's layer-wise activation scheme applied per cached token.
    The single quantizer behind every cache write (GQA K/V, the MLA
    latent, and the prefill cache builders in models/serving.py); public
    as of PR 4 so serving does not reach into a private helper.
    """
    amax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
    scale = jnp.maximum(amax.astype(jnp.float32), 1e-6) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def slot_write_pos(pos: jnp.ndarray, live: Optional[jnp.ndarray],
                   max_len: int) -> jnp.ndarray:
    """Per-slot ring-write index: dead slots write out of bounds.

    The serving cache writers scatter each row's new entry at its own
    position; with ``mode="drop"`` an out-of-bounds index silently skips
    the row, so a freed slot (``live=False``) leaves its pooled cache
    untouched while the live slots in the same fixed-width batch advance.
    """
    pos = pos.astype(jnp.int32)
    return pos if live is None else jnp.where(live, pos, max_len)


def gqa_decode(p: dict, cfg, x: jnp.ndarray, cache: dict,
               pos: jnp.ndarray, dq_linear,
               live: Optional[jnp.ndarray] = None,
               pages: Optional[jnp.ndarray] = None,
               page_size: Optional[int] = None,
               kv_spec: Optional[kvq.KVQuantSpec] = None,
               backend: str = "jnp") -> tuple[jnp.ndarray, dict]:
    """One-token decode with quantized KV cache, per-slot positions.

    ``x``: (B, 1, d); ``pos``: (B,) int32 **position vector** — row ``b``
    writes its new KV at ring index ``pos[b]`` and attends to history
    ``<= pos[b]``, so independently-progressed requests decode in one
    fixed-width batch (continuous batching); ``live``: optional (B,) bool —
    rows with ``live=False`` drop their ring write (freed slots stay
    untouched).  ``dq_linear`` is the linear application function for the
    deployed weight format (see models/serving.py) — this function is
    format-agnostic.

    ``pages``: optional (B, P) int32 page table for the **paged** cache
    (repro/cache): the cache leaves then hold physical pages ``(num_pages,
    KV, page_size, hd)`` instead of per-slot rings; row ``b``'s write
    scatters into page ``pages[b, pos[b] // page_size]`` and attention
    gathers its ring view through the table.  The gathered view is exactly
    the dense ``(B, KV, P*page_size, hd)`` ring, so the attention math —
    and its bits — are identical to the dense path.

    ``kv_spec``: optional channel-wise packed cache layout (the cache leaves
    must come from ``init_gqa_cache(..., spec=kv_spec)``).  New tokens
    quantize per channel group and the ring stays packed through the
    scatter/gather (packing is feature-axis only, so page boundaries never
    split a byte); ``backend="pallas"`` then attends through the fused
    decode-attention kernel (in-VMEM unpack+scale), anything else through
    the jnp dequant reference — token-identical paths, pinned by
    tests/test_kv_quant.py.
    """
    if x.shape[1] > 1:                # speculative verify: W tokens at once
        return _gqa_decode_multi(p, cfg, x, cache, pos, dq_linear, live,
                                 pages, page_size, kv_spec, backend)
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.cdtype
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:                 # legacy scalar: all slots synchronized
        pos = jnp.broadcast_to(pos[None], (B,))
    q = dq_linear(x, p["wq"]).reshape(B, 1, H, hd)
    k = dq_linear(x, p["wk"]).reshape(B, 1, KV, hd)
    v = dq_linear(x, p["wv"]).reshape(B, 1, KV, hd)
    if cfg.rope_partial > 0:
        cos, sin, rot = L.rope_freqs(hd, cfg.rope_theta,
                                     pos[:, None], cfg.rope_partial)
        q = L.apply_rope(q, cos, sin, rot)
        k = L.apply_rope(k, cos, sin, rot)
    # mesh serving: attention operands replicate (identity off-mesh)
    q, k, v = (shd.replicate_serving(t) for t in (q, k, v))
    # append new kv (int8 per-token or packed channel-wise), one ring
    # index per slot
    if kv_spec is None:
        kq, ks = quant_per_token(k.transpose(0, 2, 1, 3))  # (B, KV, 1, hd)
        vq, vs = quant_per_token(v.transpose(0, 2, 1, 3))
    else:
        kq, ks = kvq.quant_channelwise(k.transpose(0, 2, 1, 3), kv_spec)
        vq, vs = kvq.quant_channelwise(v.transpose(0, 2, 1, 3), kv_spec)
    if pages is None:
        S = cache["k"].shape[2]
        bidx = jnp.arange(B)
        wpos = slot_write_pos(pos, live, S)
        cache = {
            "k": cache["k"].at[bidx, :, wpos].set(kq[:, :, 0], mode="drop"),
            "v": cache["v"].at[bidx, :, wpos].set(vq[:, :, 0], mode="drop"),
            "k_scale": cache["k_scale"].at[bidx, :, wpos].set(ks[:, :, 0],
                                                              mode="drop"),
            "v_scale": cache["v_scale"].at[bidx, :, wpos].set(vs[:, :, 0],
                                                              mode="drop"),
        }
        ki, vi, ksc, vsc = (cache["k"], cache["v"],
                            cache["k_scale"], cache["v_scale"])
    else:
        NP = cache["k"].shape[0]
        S = pages.shape[1] * page_size
        phys, off = paged.write_coords(pos, live, pages, page_size, NP)
        cache = {
            "k": cache["k"].at[phys, :, off].set(kq[:, :, 0], mode="drop"),
            "v": cache["v"].at[phys, :, off].set(vq[:, :, 0], mode="drop"),
            "k_scale": cache["k_scale"].at[phys, :, off].set(ks[:, :, 0],
                                                             mode="drop"),
            "v_scale": cache["v_scale"].at[phys, :, off].set(vs[:, :, 0],
                                                             mode="drop"),
        }
        ki = paged.gather_pages(cache["k"], pages)       # (B, KV, S, hd)
        vi = paged.gather_pages(cache["v"], pages)
        ksc = paged.gather_pages(cache["k_scale"], pages)
        vsc = paged.gather_pages(cache["v_scale"], pages)
    ki, vi, ksc, vsc = (shd.replicate_serving(t)
                        for t in (ki, vi, ksc, vsc))
    rep = H // KV
    if kv_spec is not None and backend == "pallas":
        # fused path: the ring stays packed into VMEM; unpack+scale happens
        # per (slot, kv-head) tile right before the dot
        # q keeps its native dtype (f32 after RoPE): the kernel's score dot
        # then promotes exactly like the reference einsum, so fused and jnp
        # paths stay token-identical
        qg = q.transpose(0, 2, 1, 3).reshape(B, KV, rep, hd)
        o = datt_kernel.decode_attention(qg, ki, ksc, vi, vsc,
                                         pos, kv_spec.bits, kv_spec.sizes,
                                         out_dtype=cd,
                                         interpret=datt_kernel.INTERPRET)
        return dq_linear(o.reshape(B, 1, H * hd), p["wo"]), cache
    if kv_spec is None:
        kf = (ki.astype(jnp.float32) * ksc).astype(cd)
        vf = (vi.astype(jnp.float32) * vsc).astype(cd)
    else:
        kf = kvq.dequant_channelwise(ki, ksc, kv_spec, cd)
        vf = kvq.dequant_channelwise(vi, vsc, kv_spec, cd)
    qh = q.transpose(0, 2, 1, 3)                          # (B, H, 1, hd)
    # grouped score: expand kv heads to full head count
    kfe = jnp.repeat(kf, rep, axis=1) if rep > 1 else kf  # (B, H, S, hd)
    vfe = jnp.repeat(vf, rep, axis=1) if rep > 1 else vf
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kfe).astype(jnp.float32)
    s = s / math.sqrt(hd)
    valid = jnp.arange(S)[None, None, None, :] <= pos[:, None, None, None]
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(cd)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, vfe)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
    return dq_linear(o, p["wo"]), cache


def _gqa_decode_multi(p: dict, cfg, x: jnp.ndarray, cache: dict,
                      pos: jnp.ndarray, dq_linear,
                      live: Optional[jnp.ndarray] = None,
                      pages: Optional[jnp.ndarray] = None,
                      page_size: Optional[int] = None,
                      kv_spec: Optional[kvq.KVQuantSpec] = None,
                      backend: str = "jnp") -> tuple[jnp.ndarray, dict]:
    """W-token verify decode: one batched KV scatter, then W attention steps.

    ``x (B, W, d)`` are the speculative verify inputs ``[t0, d1..d_{W-1}]``;
    row ``b``'s token ``j`` lives at ring position ``pos[b] + j``, so ALL W
    entries are written in one scatter up front.  That is the cache-rewind
    contract: entries past the eventually-accepted length are never
    unwound — the ``<= pos`` attention mask keeps them invisible until a
    later write overwrites them (exactly like stale reused pages, pinned by
    tests/test_paged_cache.py).  Attention then runs as W successive
    single-query steps whose operands match the baseline :func:`gqa_decode`
    step for step — same masks, same per-step fused-kernel calls — so the
    verify logits are bit-identical to W sequential decode launches (the
    greedy parity anchor of tests/test_speculative.py).
    """
    B, W, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.cdtype
    pos = jnp.asarray(pos, jnp.int32)
    posk = pos[:, None] + jnp.arange(W, dtype=jnp.int32)[None]   # (B, W)
    q = dq_linear(x, p["wq"]).reshape(B, W, H, hd)
    k = dq_linear(x, p["wk"]).reshape(B, W, KV, hd)
    v = dq_linear(x, p["wv"]).reshape(B, W, KV, hd)
    if cfg.rope_partial > 0:
        cos, sin, rot = L.rope_freqs(hd, cfg.rope_theta, posk,
                                     cfg.rope_partial)
        q = L.apply_rope(q, cos, sin, rot)
        k = L.apply_rope(k, cos, sin, rot)
    # mesh serving: attention operands replicate (identity off-mesh)
    q, k, v = (shd.replicate_serving(t) for t in (q, k, v))
    if kv_spec is None:
        kq, ks = quant_per_token(k.transpose(0, 2, 1, 3))  # (B, KV, W, ...)
        vq, vs = quant_per_token(v.transpose(0, 2, 1, 3))
    else:
        kq, ks = kvq.quant_channelwise(k.transpose(0, 2, 1, 3), kv_spec)
        vq, vs = kvq.quant_channelwise(v.transpose(0, 2, 1, 3), kv_spec)
    kq, ks = kq.transpose(0, 2, 1, 3), ks.transpose(0, 2, 1, 3)  # (B, W, KV, .)
    vq, vs = vq.transpose(0, 2, 1, 3), vs.transpose(0, 2, 1, 3)
    if pages is None:
        S = cache["k"].shape[2]
        bidx = jnp.arange(B)[:, None]                            # (B, 1)
        wposk = posk if live is None else jnp.where(live[:, None], posk, S)
        # advanced indices (bidx, wposk) separated by the KV-head slice ->
        # their broadcast (B, W) dims lead, so values are (B, W, KV, feat)
        cache = {
            "k": cache["k"].at[bidx, :, wposk].set(kq, mode="drop"),
            "v": cache["v"].at[bidx, :, wposk].set(vq, mode="drop"),
            "k_scale": cache["k_scale"].at[bidx, :, wposk].set(ks,
                                                               mode="drop"),
            "v_scale": cache["v_scale"].at[bidx, :, wposk].set(vs,
                                                               mode="drop"),
        }
        ki, vi, ksc, vsc = (cache["k"], cache["v"],
                            cache["k_scale"], cache["v_scale"])
    else:
        NP = cache["k"].shape[0]
        S = pages.shape[1] * page_size
        phys, off = paged.write_coords(posk, live, pages, page_size, NP)
        cache = {
            "k": cache["k"].at[phys, :, off].set(kq, mode="drop"),
            "v": cache["v"].at[phys, :, off].set(vq, mode="drop"),
            "k_scale": cache["k_scale"].at[phys, :, off].set(ks,
                                                             mode="drop"),
            "v_scale": cache["v_scale"].at[phys, :, off].set(vs,
                                                             mode="drop"),
        }
        ki = paged.gather_pages(cache["k"], pages)       # (B, KV, S, hd)
        vi = paged.gather_pages(cache["v"], pages)
        ksc = paged.gather_pages(cache["k_scale"], pages)
        vsc = paged.gather_pages(cache["v_scale"], pages)
    ki, vi, ksc, vsc = (shd.replicate_serving(t)
                        for t in (ki, vi, ksc, vsc))
    rep = H // KV
    outs = []
    if kv_spec is not None and backend == "pallas":
        for j in range(W):
            qg = q[:, j:j + 1].transpose(0, 2, 1, 3).reshape(B, KV, rep, hd)
            o = datt_kernel.decode_attention(qg, ki, ksc, vi, vsc,
                                             posk[:, j], kv_spec.bits,
                                             kv_spec.sizes, out_dtype=cd,
                                             interpret=datt_kernel.INTERPRET)
            outs.append(o.reshape(B, 1, H * hd))
        return dq_linear(jnp.concatenate(outs, axis=1), p["wo"]), cache
    if kv_spec is None:
        kf = (ki.astype(jnp.float32) * ksc).astype(cd)
        vf = (vi.astype(jnp.float32) * vsc).astype(cd)
    else:
        kf = kvq.dequant_channelwise(ki, ksc, kv_spec, cd)
        vf = kvq.dequant_channelwise(vi, vsc, kv_spec, cd)
    kfe = jnp.repeat(kf, rep, axis=1) if rep > 1 else kf  # (B, H, S, hd)
    vfe = jnp.repeat(vf, rep, axis=1) if rep > 1 else vf
    for j in range(W):
        qh = q[:, j:j + 1].transpose(0, 2, 1, 3)          # (B, H, 1, hd)
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, kfe).astype(jnp.float32)
        s = s / math.sqrt(hd)
        valid = (jnp.arange(S)[None, None, None, :]
                 <= posk[:, j][:, None, None, None])
        s = jnp.where(valid, s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1).astype(cd)
        o = jnp.einsum("bhqk,bhkd->bhqd", w, vfe)
        outs.append(o.transpose(0, 2, 1, 3).reshape(B, 1, H * hd))
    return dq_linear(jnp.concatenate(outs, axis=1), p["wo"]), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): latent KV compression; decode uses weight absorption
# ---------------------------------------------------------------------------

def mla_forward(p: dict, nas: Optional[dict], policy: PrecisionPolicy, cfg,
                x: jnp.ndarray, positions: jnp.ndarray,
                k_chunk: int = 1024) -> jnp.ndarray:
    """Full-sequence MLA (train/prefill): expand latents to per-head k/v."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    cd = cfg.cdtype
    getn = (lambda n: nas[n]) if nas is not None else (lambda n: None)

    cq = L.qlinear(x, p["wq_a"], getn("wq_a"), policy, cfg.quant,
                   compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))
    cq = L.rmsnorm(cq, p["q_norm"])
    q = L.qlinear(cq, p["wq_b"], getn("wq_b"), policy, cfg.quant,
                  compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg)).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    ckv = L.qlinear(x, p["wkv_a"], getn("wkv_a"), policy, cfg.quant,
                    compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))
    c_kv, k_rope = ckv[..., :kvr], ckv[..., kvr:]
    c_kv = L.rmsnorm(c_kv, p["kv_norm"])
    kv = L.qlinear(c_kv, p["wkv_b"], getn("wkv_b"), policy, cfg.quant,
                   compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg)).reshape(B, S, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    cos, sin, rot = L.rope_freqs(rope, cfg.rope_theta, positions, 1.0)
    q_rope = L.apply_rope(q_rope, cos, sin, rot)
    k_rope = L.apply_rope(k_rope[:, :, None, :], cos, sin, rot)  # shared head
    k_rope = jnp.broadcast_to(k_rope, (B, S, H, rope))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    o = gqa_core(q_full, k_full, v, H, H, causal=True, k_chunk=k_chunk)
    o = o.reshape(B, S, H * vd)
    return L.qlinear(o, p["wo"], getn("wo"), policy, cfg.quant,
                     compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))


def init_mla_cache(cfg, batch: int, max_len: int,
                   spec: Optional[kvq.KVQuantSpec] = None) -> dict:
    """MLA cache stores the *latent* c_kv + shared k_rope — (kvr + rope) per
    token instead of 2*H*hd: the paper-aligned memory win for decode.

    With a ``spec`` the latent leaf holds packed channel-wise sub-byte rows
    (``kv_lora_rank`` is the feature axis) and the scale leaf one f32 per
    channel group; ``krope`` stays bf16 — it is the shared rotary phase
    (``qk_rope_dim`` small), not a searched activation.
    """
    if spec is None:
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), jnp.int8),
            "ckv_scale": jnp.zeros((batch, max_len, 1), jnp.float32),
            "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim),
                               jnp.bfloat16),
        }
    assert spec.feat == cfg.kv_lora_rank, (spec, cfg.kv_lora_rank)
    return {
        "ckv": jnp.zeros((batch, max_len, spec.packed_bytes), jnp.uint8),
        "ckv_scale": jnp.zeros((batch, max_len, spec.n_groups), jnp.float32),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), jnp.bfloat16),
    }


def mla_decode(p: dict, cfg, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
               dq_linear, live: Optional[jnp.ndarray] = None,
               pages: Optional[jnp.ndarray] = None,
               page_size: Optional[int] = None,
               kv_spec: Optional[kvq.KVQuantSpec] = None
               ) -> tuple[jnp.ndarray, dict]:
    """One-token MLA decode, fully packed, per-slot positions.

    ``pos`` is a (B,) int32 position vector (see :func:`gqa_decode`): each
    row writes its latent at its own ring index and attends to its own
    history; ``live=False`` rows drop their write.  ``pages (B, P)`` turns
    the cache leaves into page pools (``(num_pages, page_size, feat)``) and
    routes writes/reads through the table exactly as in :func:`gqa_decode`.

    The pre-PR4 path "absorbed" ``wkv_b`` per head (W_uk / W_uv) from a
    dense ``(c_out, c_in)`` view — re-materializing the full bf16 weight on
    every step, exactly the HBM traffic the searched sub-byte assignment is
    supposed to save.  Decode now expands the cached latents through the
    **packed** ``wkv_b`` matmul instead (``dq_linear`` — the same
    mixed-precision group/fused kernels as prefill) and attends in per-head
    K/V space: mathematically the same attention (absorption is an exact
    linear-algebra rewrite), with every weight read staying sub-byte.  The
    cache layout is unchanged (int8 latent + shared bf16 k_rope), so
    prefill-built caches embed as before.

    Trade-off: expansion re-runs the ``wkv_b`` matmul over all ``S``
    cached latents each step (O(S) activation compute) where absorption
    paid a dense O(1) weight read — the packed win holds while
    ``S * act_bytes`` stays under the dense ``H*(nope+vd)*kvr`` weight
    bytes, i.e. the edge/short-context decode this repo serves.  Packed
    absorption proper needs a transpose (contract-over-``c_out``) packed
    matmul, which the channel-grouped layout does not support — revisit if
    long-context MLA decode becomes a target workload.

    ``kv_spec``: optional channel-wise packed *latent* storage (cache from
    ``init_mla_cache(..., spec=kv_spec)``).  The win is the packed ring
    bytes; the dequantized latent still materializes once per step because
    it immediately expands through the packed ``wkv_b`` matmul — there is
    no attention dot to fuse the latent unpack into (unlike GQA's
    decode-attention kernel), so the channel-wise jnp dequant IS the packed
    path here, on every backend.
    """
    if x.shape[1] > 1:                # speculative verify: W tokens at once
        return _mla_decode_multi(p, cfg, x, cache, pos, dq_linear, live,
                                 pages, page_size, kv_spec)
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    cd = cfg.cdtype
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:                 # legacy scalar: all slots synchronized
        pos = jnp.broadcast_to(pos[None], (B,))

    cq = L.rmsnorm(dq_linear(x, p["wq_a"]), p["q_norm"])
    q = dq_linear(cq, p["wq_b"]).reshape(B, 1, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    ckv_new = dq_linear(x, p["wkv_a"])
    c_kv, k_rope_new = ckv_new[..., :kvr], ckv_new[..., kvr:]
    c_kv = L.rmsnorm(c_kv, p["kv_norm"])

    cos, sin, rot = L.rope_freqs(rope, cfg.rope_theta, pos[:, None], 1.0)
    q_rope = L.apply_rope(q_rope, cos, sin, rot)
    k_rope_new = L.apply_rope(k_rope_new[:, :, None, :], cos, sin, rot)[:, :, 0]

    if kv_spec is None:
        qc, qs = quant_per_token(c_kv)
    else:
        qc, qs = kvq.quant_channelwise(c_kv, kv_spec)
    if pages is None:
        S = cache["ckv"].shape[1]
        bidx = jnp.arange(B)
        wpos = slot_write_pos(pos, live, S)
        cache = {
            "ckv": cache["ckv"].at[bidx, wpos].set(qc[:, 0], mode="drop"),
            "ckv_scale": cache["ckv_scale"].at[bidx, wpos].set(qs[:, 0],
                                                               mode="drop"),
            "krope": cache["krope"].at[bidx, wpos].set(
                k_rope_new[:, 0].astype(jnp.bfloat16), mode="drop"),
        }
        ckv_i, ckv_s, krope_i = (cache["ckv"], cache["ckv_scale"],
                                 cache["krope"])
    else:
        NP = cache["ckv"].shape[0]
        S = pages.shape[1] * page_size
        phys, off = paged.write_coords(pos, live, pages, page_size, NP)
        cache = {
            "ckv": cache["ckv"].at[phys, off].set(qc[:, 0], mode="drop"),
            "ckv_scale": cache["ckv_scale"].at[phys, off].set(qs[:, 0],
                                                              mode="drop"),
            "krope": cache["krope"].at[phys, off].set(
                k_rope_new[:, 0].astype(jnp.bfloat16), mode="drop"),
        }
        ckv_i = paged.gather_pages(cache["ckv"], pages)      # (B, S, kvr)
        ckv_s = paged.gather_pages(cache["ckv_scale"], pages)
        krope_i = paged.gather_pages(cache["krope"], pages)

    # mesh serving: latent views and queries replicate (identity off-mesh)
    q_nope, q_rope, ckv_i, ckv_s, krope_i = (
        shd.replicate_serving(t)
        for t in (q_nope, q_rope, ckv_i, ckv_s, krope_i))

    # expand latents to per-head K/V through the packed low-rank factor:
    # ckv (B, S, kvr) -> (B, S, H, nope + vd), weights streaming sub-byte
    if kv_spec is None:
        ckv_f = (ckv_i.astype(jnp.float32) * ckv_s).astype(cd)
    else:
        ckv_f = kvq.dequant_channelwise(ckv_i, ckv_s, kv_spec, cd)
    kv = dq_linear(ckv_f, p["wkv_b"]).reshape(B, S, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    s = jnp.einsum("bqhn,bkhn->bhqk", q_nope.astype(cd),
                   k_nope.astype(cd)).astype(jnp.float32)
    s = s + jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(cd),
                       krope_i.astype(cd)).astype(jnp.float32)
    s = s / math.sqrt(nope + rope)
    valid = jnp.arange(S)[None, None, None, :] <= pos[:, None, None, None]
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(cd)
    o = jnp.einsum("bhqk,bkhv->bqhv", w, v.astype(cd))   # (B, 1, H, vd)
    o = o.reshape(B, 1, H * vd)
    return dq_linear(o, p["wo"]), cache


def _mla_decode_multi(p: dict, cfg, x: jnp.ndarray, cache: dict,
                      pos: jnp.ndarray, dq_linear,
                      live: Optional[jnp.ndarray] = None,
                      pages: Optional[jnp.ndarray] = None,
                      page_size: Optional[int] = None,
                      kv_spec: Optional[kvq.KVQuantSpec] = None
                      ) -> tuple[jnp.ndarray, dict]:
    """W-token MLA verify decode — see :func:`_gqa_decode_multi` for the
    write-then-mask contract.  The W latents land in one batched scatter;
    the packed ``wkv_b`` expansion then runs once over the full ring
    (identical to the baseline step, which also expands all ``S`` cached
    latents), and attention runs W single-query steps under the per-step
    ``<= pos + j`` mask."""
    B, W, _ = x.shape
    H = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    cd = cfg.cdtype
    pos = jnp.asarray(pos, jnp.int32)
    posk = pos[:, None] + jnp.arange(W, dtype=jnp.int32)[None]   # (B, W)

    cq = L.rmsnorm(dq_linear(x, p["wq_a"]), p["q_norm"])
    q = dq_linear(cq, p["wq_b"]).reshape(B, W, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    ckv_new = dq_linear(x, p["wkv_a"])
    c_kv, k_rope_new = ckv_new[..., :kvr], ckv_new[..., kvr:]
    c_kv = L.rmsnorm(c_kv, p["kv_norm"])

    cos, sin, rot = L.rope_freqs(rope, cfg.rope_theta, posk, 1.0)
    q_rope = L.apply_rope(q_rope, cos, sin, rot)
    k_rope_new = L.apply_rope(k_rope_new[:, :, None, :], cos, sin, rot)[:, :, 0]

    if kv_spec is None:
        qc, qs = quant_per_token(c_kv)             # (B, W, kvr) / (B, W, 1)
    else:
        qc, qs = kvq.quant_channelwise(c_kv, kv_spec)
    if pages is None:
        S = cache["ckv"].shape[1]
        bidx = jnp.arange(B)[:, None]                            # (B, 1)
        wposk = posk if live is None else jnp.where(live[:, None], posk, S)
        # adjacent advanced indices (bidx, wposk) broadcast in place ->
        # values are (B, W, feat)
        cache = {
            "ckv": cache["ckv"].at[bidx, wposk].set(qc, mode="drop"),
            "ckv_scale": cache["ckv_scale"].at[bidx, wposk].set(qs,
                                                                mode="drop"),
            "krope": cache["krope"].at[bidx, wposk].set(
                k_rope_new.astype(jnp.bfloat16), mode="drop"),
        }
        ckv_i, ckv_s, krope_i = (cache["ckv"], cache["ckv_scale"],
                                 cache["krope"])
    else:
        NP = cache["ckv"].shape[0]
        S = pages.shape[1] * page_size
        phys, off = paged.write_coords(posk, live, pages, page_size, NP)
        cache = {
            "ckv": cache["ckv"].at[phys, off].set(qc, mode="drop"),
            "ckv_scale": cache["ckv_scale"].at[phys, off].set(qs,
                                                              mode="drop"),
            "krope": cache["krope"].at[phys, off].set(
                k_rope_new.astype(jnp.bfloat16), mode="drop"),
        }
        ckv_i = paged.gather_pages(cache["ckv"], pages)      # (B, S, kvr)
        ckv_s = paged.gather_pages(cache["ckv_scale"], pages)
        krope_i = paged.gather_pages(cache["krope"], pages)

    # mesh serving: latent views and queries replicate (identity off-mesh)
    q_nope, q_rope, ckv_i, ckv_s, krope_i = (
        shd.replicate_serving(t)
        for t in (q_nope, q_rope, ckv_i, ckv_s, krope_i))

    if kv_spec is None:
        ckv_f = (ckv_i.astype(jnp.float32) * ckv_s).astype(cd)
    else:
        ckv_f = kvq.dequant_channelwise(ckv_i, ckv_s, kv_spec, cd)
    kv = dq_linear(ckv_f, p["wkv_b"]).reshape(B, S, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    outs = []
    for j in range(W):
        s = jnp.einsum("bqhn,bkhn->bhqk", q_nope[:, j:j + 1].astype(cd),
                       k_nope.astype(cd)).astype(jnp.float32)
        s = s + jnp.einsum("bqhr,bkr->bhqk", q_rope[:, j:j + 1].astype(cd),
                           krope_i.astype(cd)).astype(jnp.float32)
        s = s / math.sqrt(nope + rope)
        valid = (jnp.arange(S)[None, None, None, :]
                 <= posk[:, j][:, None, None, None])
        s = jnp.where(valid, s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1).astype(cd)
        o = jnp.einsum("bhqk,bkhv->bqhv", w, v.astype(cd))   # (B, 1, H, vd)
        outs.append(o.reshape(B, 1, H * vd))
    return dq_linear(jnp.concatenate(outs, axis=1), p["wo"]), cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder): KV from encoder output, not causal.
# ---------------------------------------------------------------------------

def cross_forward(p: dict, nas: Optional[dict], policy: PrecisionPolicy, cfg,
                  x: jnp.ndarray, enc: jnp.ndarray,
                  k_chunk: int = 1024) -> jnp.ndarray:
    B, S, _ = x.shape
    Se = enc.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.cdtype
    getn = (lambda n: nas[n]) if nas is not None else (lambda n: None)
    q = L.qlinear(x, p["wq"], getn("wq"), policy, cfg.quant,
                  compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg)).reshape(B, S, H, hd)
    k = L.qlinear(enc, p["wk"], getn("wk"), policy, cfg.quant,
                  compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg)).reshape(B, Se, KV, hd)
    v = L.qlinear(enc, p["wv"], getn("wv"), policy, cfg.quant,
                  compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg)).reshape(B, Se, KV, hd)
    o = gqa_core(q, k, v, H, KV, causal=False, k_chunk=k_chunk)
    o = o.reshape(B, S, H * hd)
    return L.qlinear(o, p["wo"], getn("wo"), policy, cfg.quant,
                     compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))
