"""The paper's four MLPerf Tiny benchmark models (Sec. IV-A).

  IC  — ResNet-8 on CIFAR-10 (8 conv backbone + FC)
  KWS — DS-CNN on Google Speech Commands v2 (conv + 4x depthwise-separable)
  VWW — MobileNetV1 width 0.25 on MSCOCO-VWW (96x96x3)
  AD  — Dense Autoencoder on DCASE2020 Toy-car (640-d input)

Models are described as op lists consumed by a tiny interpreter, which gives
init / quant-aware apply / LayerCostSpec generation from one description.
``apply_fn(params, nas, policy, batch)`` takes a
:class:`repro.api.PrecisionPolicy`; with QTensor weight leaves
(engine.deploy output) and ``PrecisionPolicy.deployed(backend)`` the same
interpreter serves the packed model — convs as im2col patch-GEMMs through
the Pallas quant_matmul kernel (``backend="pallas"``), depthwise convs
through the grouped per-channel path (``QTensor.conv2d``).
BatchNorm is represented as a per-channel scale+bias (the folded form used at
deployment — QAT pipelines fold BN into the preceding conv).

Every conv/FC weight goes through the channel-wise DNAS (models/layers.py),
exactly as in the paper: per-filter gamma for convs, per-output-neuron gamma
for FCs; activations layer-wise, unsigned (post-ReLU).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.api.policy import PrecisionPolicy
from repro.core import mixedprec as mp
from repro.core.regularizers import LayerCostSpec
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class TinyConfig:
    name: str
    task: str                    # ic | kws | vww | ad
    input_shape: tuple           # (H, W, C) or (D,) for AD
    n_classes: int
    quant: mp.MixedPrecConfig = dataclasses.field(
        default_factory=lambda: mp.MixedPrecConfig())
    width_mult: float = 1.0

    def reduced(self) -> "TinyConfig":
        return self  # already tiny


# ---------------------------------------------------------------------------
# Op-list model descriptions
# ---------------------------------------------------------------------------

def resnet8_ops():
    return [
        ("conv", dict(cout=16, k=3, s=1)), ("bn",), ("relu",),
        ("resblock", dict(cout=16, s=1)),
        ("resblock", dict(cout=32, s=2)),
        ("resblock", dict(cout=64, s=2)),
        ("gap",),
        ("fc", dict(cout=10)),
    ]


def dscnn_ops():
    seq = [("conv", dict(cout=64, k=(10, 4), s=2)), ("bn",), ("relu",)]
    for _ in range(4):
        seq += [("dwconv", dict(k=3, s=1)), ("bn",), ("relu",),
                ("conv", dict(cout=64, k=1, s=1)), ("bn",), ("relu",)]
    seq += [("gap",), ("fc", dict(cout=12))]
    return seq


def mobilenetv1_ops(width=0.25):
    def c(ch):
        return max(8, int(ch * width))
    seq = [("conv", dict(cout=c(32), k=3, s=2)), ("bn",), ("relu",)]
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
            (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
            (1024, 1)]
    for ch, s in plan:
        seq += [("dwconv", dict(k=3, s=s)), ("bn",), ("relu",),
                ("conv", dict(cout=c(ch), k=1, s=1)), ("bn",), ("relu",)]
    seq += [("gap",), ("fc", dict(cout=2))]
    return seq


def dae_ops():
    seq = []
    for _ in range(4):
        seq += [("fc", dict(cout=128)), ("bn",), ("relu",)]
    seq += [("fc", dict(cout=8)), ("bn",), ("relu",)]
    for _ in range(4):
        seq += [("fc", dict(cout=128)), ("bn",), ("relu",)]
    seq += [("fc", dict(cout=640))]
    return seq


OPS_FOR = {"ic": resnet8_ops, "kws": dscnn_ops,
           "vww": lambda: mobilenetv1_ops(0.25), "ad": dae_ops}


# ---------------------------------------------------------------------------
# Interpreter: init + apply + specs from one op list
# ---------------------------------------------------------------------------

def _norm_k(k):
    return (k, k) if isinstance(k, int) else k


def build(cfg: TinyConfig):
    """Returns (init_fn(key) -> (params, nas), apply_fn, specs)."""
    ops = OPS_FOR[cfg.task]()
    # --- trace shapes & geometry -------------------------------------------
    specs: dict[str, LayerCostSpec] = {}
    geom = []        # per-op records used by init/apply
    if len(cfg.input_shape) == 3:
        h, w, c = cfg.input_shape
    else:
        h, w, c = 1, 1, cfg.input_shape[0]
    idx = 0

    def reg_conv(name, cin, cout, kh, kw, ho, wo):
        specs[name] = LayerCostSpec(name=name, c_out=cout,
                                    weights_per_channel=cin * kh * kw,
                                    ops=cout * cin * kh * kw * ho * wo)

    for op, *rest in [(o[0], *o[1:]) for o in ops]:
        arg = rest[0] if rest else {}
        if op == "conv":
            kh, kw = _norm_k(arg["k"])
            s = arg["s"]
            ho, wo = math.ceil(h / s), math.ceil(w / s)
            name = f"conv{idx}"
            reg_conv(name, c, arg["cout"], kh, kw, ho, wo)
            geom.append((op, dict(name=name, cin=c, cout=arg["cout"],
                                  k=(kh, kw), s=s)))
            h, w, c = ho, wo, arg["cout"]
            idx += 1
        elif op == "dwconv":
            kh, kw = _norm_k(arg["k"])
            s = arg["s"]
            ho, wo = math.ceil(h / s), math.ceil(w / s)
            name = f"dwconv{idx}"
            specs[name] = LayerCostSpec(name=name, c_out=c,
                                        weights_per_channel=kh * kw,
                                        ops=c * kh * kw * ho * wo)
            geom.append((op, dict(name=name, cin=c, cout=c, k=(kh, kw), s=s)))
            h, w = ho, wo
            idx += 1
        elif op == "resblock":
            cout, s = arg["cout"], arg["s"]
            ho, wo = math.ceil(h / s), math.ceil(w / s)
            n1, n2 = f"conv{idx}", f"conv{idx + 1}"
            reg_conv(n1, c, cout, 3, 3, ho, wo)
            reg_conv(n2, cout, cout, 3, 3, ho, wo)
            rec = dict(n1=n1, n2=n2, cin=c, cout=cout, s=s)
            idx += 2
            if s != 1 or c != cout:
                ns = f"conv{idx}"
                reg_conv(ns, c, cout, 1, 1, ho, wo)
                rec["nshort"] = ns
                idx += 1
            geom.append((op, rec))
            h, w, c = ho, wo, cout
        elif op == "fc":
            name = f"fc{idx}"
            cin = c * h * w if (h > 1 or w > 1) else c
            specs[name] = LayerCostSpec(name=name, c_out=arg["cout"],
                                        weights_per_channel=cin,
                                        ops=arg["cout"] * cin)
            geom.append((op, dict(name=name, cin=cin, cout=arg["cout"])))
            h, w, c = 1, 1, arg["cout"]
            idx += 1
        elif op in ("bn", "relu", "gap"):
            if op == "gap":
                h, w = 1, 1
            geom.append((op, dict(c=c)))
        else:
            raise ValueError(op)

    # --- init ---------------------------------------------------------------
    def init_fn(key):
        params, nas = {}, {}
        bn_i = 0
        for op, g in geom:
            key, sub = jax.random.split(key)
            if op == "conv":
                params[g["name"]] = L.conv2d_init(sub, g["cin"], g["cout"],
                                                  *g["k"], bias=False)
                nas[g["name"]] = L.nas_init(sub, g["cout"], cfg.quant)
            elif op == "dwconv":
                params[g["name"]] = L.conv2d_init(sub, g["cin"], g["cout"],
                                                  *g["k"], bias=False,
                                                  groups=g["cin"])
                nas[g["name"]] = L.nas_init(sub, g["cout"], cfg.quant)
            elif op == "resblock":
                k1, k2, k3 = jax.random.split(sub, 3)
                params[g["n1"]] = L.conv2d_init(k1, g["cin"], g["cout"], 3, 3,
                                                bias=False)
                nas[g["n1"]] = L.nas_init(k1, g["cout"], cfg.quant)
                params[g["n2"]] = L.conv2d_init(k2, g["cout"], g["cout"], 3, 3,
                                                bias=False)
                nas[g["n2"]] = L.nas_init(k2, g["cout"], cfg.quant)
                params[g["n1"] + "_bn"] = _bn_init(g["cout"])
                params[g["n2"] + "_bn"] = _bn_init(g["cout"])
                if "nshort" in g:
                    params[g["nshort"]] = L.conv2d_init(k3, g["cin"],
                                                        g["cout"], 1, 1,
                                                        bias=False)
                    nas[g["nshort"]] = L.nas_init(k3, g["cout"], cfg.quant)
                    params[g["nshort"] + "_bn"] = _bn_init(g["cout"])
            elif op == "fc":
                params[g["name"]] = L.linear_init(sub, g["cin"], g["cout"],
                                                  bias=True)
                nas[g["name"]] = L.nas_init(sub, g["cout"], cfg.quant)
            elif op == "bn":
                params[f"bn{bn_i}"] = _bn_init(g["c"])
                bn_i += 1
        return params, nas

    # --- apply ---------------------------------------------------------------
    def apply_fn(params, nas, policy, batch):
        x = batch["x"]
        if len(cfg.input_shape) == 1 and x.ndim == 2:
            x = x[:, None, None, :]          # AD vectors as 1x1 images
        getn = (lambda n: nas[n]) if nas is not None else (lambda n: None)
        bn_i = 0
        for op, g in geom:
            if op == "conv":
                x = L.qconv2d(x, params[g["name"]], getn(g["name"]),
                              policy, cfg.quant, stride=g["s"])
            elif op == "dwconv":
                x = L.qconv2d(x, params[g["name"]], getn(g["name"]),
                              policy, cfg.quant, stride=g["s"],
                              groups=g["cin"])
            elif op == "resblock":
                sc = x
                h1 = L.qconv2d(x, params[g["n1"]], getn(g["n1"]), policy,
                               cfg.quant, stride=g["s"])
                h1 = jax.nn.relu(_bn(h1, params[g["n1"] + "_bn"]))
                h2 = L.qconv2d(h1, params[g["n2"]], getn(g["n2"]), policy,
                               cfg.quant)
                h2 = _bn(h2, params[g["n2"] + "_bn"])
                if "nshort" in g:
                    sc = L.qconv2d(sc, params[g["nshort"]], getn(g["nshort"]),
                                   policy, cfg.quant, stride=g["s"])
                    sc = _bn(sc, params[g["nshort"] + "_bn"])
                x = jax.nn.relu(h2 + sc)
            elif op == "fc":
                if x.ndim == 4:
                    x = x.reshape(x.shape[0], -1)
                x = L.qlinear(x, params[g["name"]], getn(g["name"]),
                              policy, cfg.quant, signed_act=False)
            elif op == "bn":
                x = _bn(x, params[f"bn{bn_i}"])
                bn_i += 1
            elif op == "relu":
                x = jax.nn.relu(x)
            elif op == "gap":
                x = jnp.mean(x, axis=(1, 2), keepdims=True)
        return x

    return init_fn, apply_fn, specs


def _bn_init(c: int) -> dict:
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn(x, p):
    return x * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# Losses / metrics per task
# ---------------------------------------------------------------------------

def task_loss(cfg: TinyConfig, pred: jnp.ndarray, batch: dict) -> jnp.ndarray:
    if cfg.task == "ad":                      # reconstruction MSE
        return jnp.mean(jnp.square(pred - batch["x"].reshape(pred.shape)))
    logits = pred.reshape(pred.shape[0], -1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], 1))


def task_metric(cfg: TinyConfig, pred: jnp.ndarray, batch: dict) -> jnp.ndarray:
    if cfg.task == "ad":                      # higher = better (neg. error)
        return -jnp.mean(jnp.square(pred - batch["x"].reshape(pred.shape)))
    logits = pred.reshape(pred.shape[0], -1)
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))


TINY_CONFIGS = {
    "resnet8-cifar10": TinyConfig("resnet8-cifar10", "ic", (32, 32, 3), 10),
    "dscnn-kws": TinyConfig("dscnn-kws", "kws", (49, 10, 1), 12),
    "mobilenetv1-vww": TinyConfig("mobilenetv1-vww", "vww", (96, 96, 3), 2,
                                  width_mult=0.25),
    "dae-ad": TinyConfig("dae-ad", "ad", (640,), 0),
}
