"""Channel-wise sub-byte KV-cache quantization.

The paper's thesis — per-channel bit-width assignment beats per-layer — has
so far only been applied to weights; the KV cache was uniform int8 per token
(``attention.quant_per_token``, the layer-wise activation scheme).  This
module applies the same channel-grouping machinery to the cache itself: the
feature axis of a cache leaf (``head_dim`` for GQA K/V, ``kv_lora_rank`` for
the MLA latent) splits into a few static contiguous channel groups, each
quantized symmetric at its own bit-width with ONE scale per (token, group),
and stored packed in uint8 (``core.quantizers.pack_int`` — 4x int2 / 2x int4
per byte).  Decode bandwidth then scales with the assigned bits exactly as
weight bandwidth does for the deployed linears.

Contracts
---------
* Packing is along the FEATURE axis only.  Every token row is a whole number
  of bytes, so the token axis slices freely — page pools (repro/cache) carry
  packed rows through ``gather_pages`` / ``scatter_prefill`` unchanged, and a
  page boundary can never split a packed byte.
* At ``bits=8`` with a single group this is **bit-identical** to
  ``quant_per_token`` + the legacy int8 dequant: same amax/127 scale with
  the same 1e-6 floor, same clip, and 8-bit "packing" is a pure int8<->uint8
  bitcast.  That equivalence is what pins the packed engines token-for-token
  against the legacy int8 engine (tests/test_kv_quant.py).
* All-zero rows quantize to zero codes with the floored scale, and zero
  codes dequantize to exact 0.0 under ANY scale — including the audio
  zero-scale cross-cache stand-in (all-zero packed bytes AND all-zero
  scales), which must keep dequantizing to exact zeros.

:class:`KVQuantSpec` is a frozen hashable dataclass, so it rides in jit
cache keys next to :class:`~repro.api.sampling.SamplingParams` — the serving
engine specializes per cache-bits policy with zero recompiles afterwards.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax.numpy as jnp

from repro.core import quantizers as qz

# Channel-count granularity every group size must honor regardless of its
# bit-width: the largest pack factor (int2 -> 4 values/byte), so group byte
# boundaries exist for any member of the bit alphabet.
GROUP_ALIGN = 4


@dataclasses.dataclass(frozen=True)
class KVQuantSpec:
    """Static channel-group bit assignment for one cache feature axis.

    ``bits[g]`` and ``sizes[g]`` describe contiguous channel groups covering
    the feature axis in storage order: channels ``[sum(sizes[:g]),
    sum(sizes[:g+1]))`` are quantized at ``bits[g]`` with one shared scale
    per token.  Hashable (usable as a jit-cache key); all shape math is
    static Python.
    """
    bits: tuple
    sizes: tuple

    def __post_init__(self):
        if not self.bits or len(self.bits) != len(self.sizes):
            raise ValueError(f"bits {self.bits} / sizes {self.sizes} must be "
                             "non-empty and the same length")
        for b, n in zip(self.bits, self.sizes):
            if b not in (2, 4, 8):
                raise ValueError(f"unsupported cache bit-width {b} "
                                 "(alphabet: 2, 4, 8)")
            if n < 1 or n % qz.pack_factor(b):
                raise ValueError(
                    f"group size {n} not a positive multiple of the {b}-bit "
                    f"pack factor {qz.pack_factor(b)}")

    @property
    def feat(self) -> int:
        """Channels covered (the unpacked feature-axis width)."""
        return sum(self.sizes)

    @property
    def n_groups(self) -> int:
        return len(self.bits)

    @property
    def packed_bytes(self) -> int:
        """Bytes per token row — what the cache leaf actually stores."""
        return sum(n // qz.pack_factor(b)
                   for b, n in zip(self.bits, self.sizes))


def spec_for(kv_bits: Union[int, Sequence[int], None],
             feat: int) -> Optional[KVQuantSpec]:
    """Resolve the engine-facing ``kv_bits`` policy knob for one feature axis.

    * ``None`` — no spec (the caller keeps the legacy int8-per-token path);
    * ``int`` — uniform: ONE group spanning all ``feat`` channels (at 8 this
      reproduces ``quant_per_token`` bit-for-bit);
    * sequence of ints — channel-wise: ``len(kv_bits)`` contiguous groups
      splitting ``feat`` as evenly as :data:`GROUP_ALIGN` allows, the last
      group absorbing the remainder (mirroring
      ``config.DeploySpec.group_sizes``'s upward promotion).
    """
    if kv_bits is None:
        return None
    for b in ((kv_bits,) if isinstance(kv_bits, int) else kv_bits):
        if b not in (2, 4, 8):
            raise ValueError(f"kv_bits widths must be in (2, 4, 8), "
                             f"got {b} (kv_bits={kv_bits})")
    if isinstance(kv_bits, int):
        if feat % qz.pack_factor(kv_bits):
            raise ValueError(
                f"feature axis {feat} not divisible by the {kv_bits}-bit "
                f"pack factor {qz.pack_factor(kv_bits)}")
        return KVQuantSpec((kv_bits,), (feat,))
    bits = tuple(int(b) for b in kv_bits)
    n = len(bits)
    base = max((feat // n) // GROUP_ALIGN * GROUP_ALIGN, GROUP_ALIGN)
    if base * (n - 1) >= feat:
        raise ValueError(
            f"feature axis {feat} too narrow to split into {n} groups of "
            f">= {GROUP_ALIGN} channels (kv_bits={bits})")
    sizes = (base,) * (n - 1) + (feat - base * (n - 1),)
    return KVQuantSpec(bits, sizes)


def quant_channelwise(t: jnp.ndarray, spec: KVQuantSpec
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize + pack a cache write along its feature axis.

    ``t (..., feat) -> (packed uint8 (..., spec.packed_bytes),
    scales f32 (..., spec.n_groups))`` with
    ``t[..., group g] ≈ unpack(packed)[..., g] * scales[..., g]``.
    Per group: symmetric signed with the amax-over-group scale —
    ``quant_per_token`` generalized from one full-width 8-bit group.
    """
    assert t.shape[-1] == spec.feat, (t.shape, spec)
    packs, scales = [], []
    lo = 0
    for b, n in zip(spec.bits, spec.sizes):
        g = t[..., lo:lo + n]
        lo += n
        half = float((1 << (b - 1)) - 1)
        amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
        scale = jnp.maximum(amax.astype(jnp.float32), 1e-6) / half
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -half, half
                     ).astype(jnp.int8)
        packs.append(qz.pack_int(q, b))
        scales.append(scale)
    packed = packs[0] if len(packs) == 1 else jnp.concatenate(packs, axis=-1)
    sc = scales[0] if len(scales) == 1 else jnp.concatenate(scales, axis=-1)
    return packed, sc


def dequant_channelwise(packed: jnp.ndarray, scales: jnp.ndarray,
                        spec: KVQuantSpec, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Inverse of :func:`quant_channelwise`: ``(..., packed_bytes)`` uint8 +
    ``(..., n_groups)`` f32 -> ``(..., feat)`` in ``dtype``.

    The jnp reference for the fused Pallas decode-attention kernel
    (kernels/decode_attention.py), which performs the identical unpack +
    scale per tile in VMEM; zero codes dequantize to exact 0.0 under any
    scale (the audio zero-scale cross-cache contract).
    """
    assert packed.shape[-1] == spec.packed_bytes, (packed.shape, spec)
    outs, lo = [], 0
    for g, (b, n) in enumerate(zip(spec.bits, spec.sizes)):
        nb = n // qz.pack_factor(b)
        q = qz.unpack_int(packed[..., lo:lo + nb], b)
        lo += nb
        outs.append((q.astype(jnp.float32)
                     * scales[..., g:g + 1]).astype(dtype))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
