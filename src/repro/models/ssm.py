"""Mamba2 (state-space duality, arXiv:2405.21060) — chunked SSD in pure JAX.

The block:  x -> in_proj -> [z | xBC | dt] ; causal conv1d over xBC ; split
x/B/C ; SSD recurrence over heads with scalar-per-head decay A ; gated (silu z)
output ; out_proj.

SSD is computed with the **chunked** algorithm: the sequence splits into
chunks of length Q; within a chunk the recurrence is a (masked, decay-
weighted) attention-like quadratic form; across chunks a lax.scan carries the
(H, P, N) state.  Cost is O(S·Q) instead of O(S²) — this is the sub-quadratic
path that makes the long_500k (524288-token) dry-run cell feasible, and the
O(1)-state decode step.

Quantization: in_proj/out_proj are channel-wise searchable (qlinear); the
recurrence itself runs bf16/f32 (state recurrences are precision-sensitive —
the same reason the paper keeps norms float; DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import layers as L

CONV_K = 4  # mamba2 depthwise conv kernel size


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def init_mamba2(key, cfg, dtype) -> tuple[dict, dict]:
    d = cfg.d_model
    d_inner, H, N, P = dims(cfg)
    conv_dim = d_inner + 2 * N          # x + B + C  (n_groups=1)
    ks = jax.random.split(key, 4)
    params = {
        "in_proj": L.linear_init(ks[0], d, 2 * d_inner + 2 * N + H, dtype),
        "out_proj": L.linear_init(ks[1], d_inner, d, dtype),
        "conv_w": (jax.random.normal(ks[2], (CONV_K, conv_dim)) /
                   math.sqrt(CONV_K)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": L.norm_init(d_inner, "rmsnorm", dtype),
    }
    nas = {
        "in_proj": L.nas_init(ks[0], 2 * d_inner + 2 * N + H, cfg.quant),
        "out_proj": L.nas_init(ks[1], d, cfg.quant),
    }
    return params, nas


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv1d: xbc (B, S, C), w (K, C)."""
    B, S, C = xbc.shape
    pad = jnp.pad(xbc, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(CONV_K):
        out = out + pad[:, i:i + S, :] * w[i]
    return jax.nn.silu(out + b)


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                h0: Optional[jnp.ndarray] = None
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    xh (B,S,H,P) inputs per head; dt (B,S,H) softplus'd steps; A (H,) decay
    rates (positive); Bm/Cm (B,S,N) shared across heads (n_groups=1).
    Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    # reshape to (nc, B, Q, ...) for scan over chunks
    def to_chunks(t):
        return t.reshape(Bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    xc, dtc, Bc, Cc = map(to_chunks, (xh, dt, Bm, Cm))
    # scan xs lose their sharding without constraints (dist/sharding.py):
    # keep batch on data and the head dim on model through the chunk scan
    xc = constrain(xc, None, "D", None, "M", None)
    dtc = constrain(dtc, None, "D", None, "M")
    Bc = constrain(Bc, None, "D", None, None)
    Cc = constrain(Cc, None, "D", None, None)

    A = -A  # decay: dA = -A*dt <= 0

    def body(h, xs):
        xq, dtq, Bq, Cq = xs          # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        xq = constrain(xq, "D", None, "M", None)
        h = constrain(h, "D", "M", None, None)
        dA = dtq * A                  # (B,Q,H)  (<=0)
        cum = jnp.cumsum(dA, axis=1)  # inclusive cumsum over chunk
        # intra-chunk: Lmat[t,s] = exp(cum[t]-cum[s]) for s<=t  (B,H,Q,Q)
        diff = cum[:, :, None, :] - cum[:, None, :, :]       # (B,Qt,Qs,H)
        mask = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
        Lmat = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("btn,bsn->bts", Cq, Bq)              # (B,Qt,Qs)
        W = CB[:, :, :, None] * Lmat                         # (B,Qt,Qs,H)
        xdt = xq * dtq[..., None]                            # (B,Q,H,P)
        y_intra = jnp.einsum("btsh,bshp->bthp", W, xdt)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cum)                              # (B,Q,H)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", Cq, h, decay_in)
        # state update: h' = exp(cum[-1]) h + sum_s exp(cum[-1]-cum[s]) B_s xdt_s
        tail = jnp.exp(cum[:, -1:, :] - cum)                 # (B,Q,H)
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None]  # (B,H,P,N)
        h_new = h_new + jnp.einsum("bsn,bshp,bsh->bhpn", Bq, xdt, tail)
        return h_new, y_intra + y_inter

    if h0 is None:
        h0 = constrain(jnp.zeros((Bsz, H, P, N), xh.dtype),
                       "D", "M", None, None)
    hT, yc = jax.lax.scan(body, h0, (xc, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(Bsz, nc * chunk, H, P)[:, :S]
    return y, hT


def mamba2_forward(p: dict, nas: Optional[dict], policy, cfg,
                   x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence Mamba2 block. x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    d_inner, H, N, P = dims(cfg)
    cd = cfg.cdtype
    getn = (lambda n: nas[n]) if nas is not None else (lambda n: None)
    zxbcdt = L.qlinear(x, p["in_proj"], getn("in_proj"), policy, cfg.quant,
                       compute_dtype=cd)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * N]
    dt_raw = zxbcdt[..., -H:]
    xbc = _causal_conv(xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
    xs = xbc[..., :d_inner].reshape(B, S, H, P)
    Bm = xbc[..., d_inner:d_inner + N]
    Cm = xbc[..., d_inner + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs.astype(jnp.float32), dt, A,
                       Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                       cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(cd)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(cd)), p["norm"])
    return L.qlinear(y, p["out_proj"], getn("out_proj"), policy, cfg.quant,
                     compute_dtype=cd)


# ---------------------------------------------------------------------------
# Decode path: O(1) recurrent step with (state, conv ring buffer) cache
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int) -> dict:
    d_inner, H, N, P = dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), jnp.bfloat16),
    }


def mamba2_decode(p: dict, cfg, x: jnp.ndarray, cache: dict, dq_linear,
                  live: Optional[jnp.ndarray] = None
                  ) -> tuple[jnp.ndarray, dict]:
    """Single-token recurrent step. x: (B, 1, d).

    ``live``: optional (B,) bool slot mask — rows with ``live=False`` keep
    their cached recurrent state and conv ring untouched (the SSM analogue
    of the attention caches' dropped ring write), so freed slots in a
    fixed-width serving batch cannot drift while they wait for admission.
    """
    B = x.shape[0]
    d_inner, H, N, P = dims(cfg)
    cd = cfg.cdtype
    zxbcdt = dq_linear(x, p["in_proj"])[:, 0]            # (B, 2di+2N+H)
    z = zxbcdt[..., :d_inner]
    xbc_new = zxbcdt[..., d_inner:d_inner + d_inner + 2 * N]
    dt_raw = zxbcdt[..., -H:]
    # conv ring buffer
    window = jnp.concatenate([cache["conv"].astype(cd),
                              xbc_new[:, None].astype(cd)], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(cd))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(cd))
    new_conv = window[:, 1:].astype(jnp.bfloat16)

    xs = xbc[..., :d_inner].reshape(B, H, P).astype(jnp.float32)
    Bm = xbc[..., d_inner:d_inner + N].astype(jnp.float32)
    Cm = xbc[..., d_inner + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = jnp.exp(p["A_log"])
    decay = jnp.exp(-A * dt)                              # (B,H)
    h = cache["h"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", Bm, xs, dt)
    y = jnp.einsum("bn,bhpn->bhp", Cm, h)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(cd)
    y = L.rmsnorm(y * jax.nn.silu(z[:, None].astype(cd)), p["norm"])
    out = dq_linear(y, p["out_proj"])
    if live is not None:
        h = jnp.where(live[:, None, None, None], h, cache["h"])
        new_conv = jnp.where(live[:, None, None], new_conv, cache["conv"])
    return out, {"h": h, "conv": new_conv}
