"""Generic LM-family model builder: dense / MoE / MLA / SSM / hybrid / enc-dec.

One config-driven implementation covers all ten assigned architectures.
Uniform layer stacks are **scanned** (params stacked on a leading L axis) so
the lowered HLO stays small enough to compile 61-layer/671B configs on the
CPU dry-run host; non-uniform stacks (zamba2's shared attention block) use a
python loop over groups with static slices.

Interface (all pure functions):

  init_model(cfg, key)          -> (params, nas)
  forward(params, nas, cfg, batch, policy) -> logits  (full sequence)
  lm_loss(logits, batch)        -> scalar CE
  cost_specs(cfg, tokens)       -> {site: LayerCostSpec}  for Eq. 7/8

``policy`` is a :class:`repro.api.PrecisionPolicy` (FLOAT / QAT8 /
search(tau) / FROZEN — see models/layers.py).  ``batch`` is a dict with
"tokens"/"labels" (+ "prefix_embeds" for vlm, "frames" for audio).  The
deployed / serving path lives in models/serving.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.policy import PrecisionPolicy
from repro.core.regularizers import LayerCostSpec
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


# ---------------------------------------------------------------------------
# Per-layer blocks (single layer, unstacked params)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_in: int, d_ff: int, dtype) -> tuple[dict, dict]:
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        p = {"w_gate": L.linear_init(ks[0], d_in, d_ff, dtype),
             "w_up": L.linear_init(ks[1], d_in, d_ff, dtype),
             "w_down": L.linear_init(ks[2], d_ff, d_in, dtype)}
    else:
        p = {"w_in": L.linear_init(ks[0], d_in, d_ff, dtype),
             "w_down": L.linear_init(ks[1], d_ff, d_in, dtype)}
    n = {k: L.nas_init(ks[0], v["w"].shape[0], cfg.quant) for k, v in p.items()}
    return p, n


def mlp_forward(p, nas, policy, cfg, x):
    cd = cfg.cdtype
    getn = (lambda n: nas[n]) if nas is not None else (lambda n: None)
    if cfg.mlp_type == "swiglu":
        h = L.swiglu(
            L.qlinear(x, p["w_gate"], getn("w_gate"), policy, cfg.quant,
                      compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg)),
            L.qlinear(x, p["w_up"], getn("w_up"), policy, cfg.quant,
                      compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg)))
    else:
        h = jax.nn.gelu(L.qlinear(x, p["w_in"], getn("w_in"), policy,
                                  cfg.quant, compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg)))
    return L.qlinear(h, p["w_down"], getn("w_down"), policy, cfg.quant,
                     compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))


def init_block(key, cfg, dtype) -> tuple[dict, dict]:
    """One decoder block for dense/vlm/moe families."""
    ks = jax.random.split(key, 2)
    p, n = {}, {}
    if cfg.use_mla:
        p["attn"], n_attn = attn.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"], n_attn = attn.init_gqa(ks[0], cfg, dtype)
    n.update({f"attn.{k}": v for k, v in n_attn.items()})
    if cfg.n_experts:
        p["ffn"], n_ffn = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"], n_ffn = init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff, dtype)
    n.update({f"ffn.{k}": v for k, v in n_ffn.items()})
    p["ln1"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    p["ln2"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    return p, n


def block_forward(p, nas, policy, cfg, x, positions):
    sub = (lambda pre: {k[len(pre):]: v for k, v in nas.items()
                        if k.startswith(pre)}) if nas is not None else (lambda pre: None)
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    if cfg.use_mla:
        a = attn.mla_forward(p["attn"], sub("attn."), policy, cfg, h,
                             positions)
    else:
        a = attn.gqa_forward(p["attn"], sub("attn."), policy, cfg, h,
                             positions)
    x = x + a.astype(x.dtype)
    h = L.apply_norm(x, p["ln2"], cfg.norm)
    if cfg.n_experts:
        f = moe_mod.moe_forward(p["ffn"], sub("ffn."), policy, cfg, h)
    else:
        f = mlp_forward(p["ffn"], sub("ffn."), policy, cfg, h)
    return x + f.astype(x.dtype)


def init_mamba_block(key, cfg, dtype) -> tuple[dict, dict]:
    p, n_in = ssm_mod.init_mamba2(key, cfg, dtype)
    p["ln"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    return p, n_in


def mamba_block_forward(p, nas, policy, cfg, x):
    h = L.apply_norm(x, p["ln"], cfg.norm)
    return x + ssm_mod.mamba2_forward(p, nas, policy, cfg, h).astype(x.dtype)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def _stacked_init(init_fn, key, n: int):
    """vmap an init over n fresh keys -> params stacked on a leading axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_model(cfg, key) -> tuple[dict, dict]:
    dtype = cfg.pdtype
    k_emb, k_blocks, k_head, k_extra = jax.random.split(key, 4)
    params: dict = {"embed": L.embedding_init(k_emb, cfg.padded_vocab,
                                              cfg.d_model, dtype)}
    nas: dict = {}

    if cfg.family in ("dense", "vlm", "moe"):
        p, n = _stacked_init(lambda k: init_block(k, cfg, dtype), k_blocks,
                             cfg.n_layers)
        params["blocks"], nas["blocks"] = p, n
    elif cfg.family == "ssm":
        p, n = _stacked_init(lambda k: init_mamba_block(k, cfg, dtype),
                             k_blocks, cfg.n_layers)
        params["blocks"], nas["blocks"] = p, n
    elif cfg.family == "hybrid":
        p, n = _stacked_init(lambda k: init_mamba_block(k, cfg, dtype),
                             k_blocks, cfg.n_layers)
        params["blocks"], nas["blocks"] = p, n
        params["shared_attn"], n_sa = init_block(k_extra, cfg, dtype)
        nas["shared_attn"] = n_sa
    elif cfg.family == "audio":  # whisper enc-dec
        pe, ne = _stacked_init(lambda k: _init_enc_block(k, cfg, dtype),
                               k_blocks, cfg.n_encoder_layers)
        pd, nd = _stacked_init(lambda k: _init_dec_block(k, cfg, dtype),
                               k_extra, cfg.n_layers)
        params["enc_blocks"], nas["enc_blocks"] = pe, ne
        params["dec_blocks"], nas["dec_blocks"] = pd, nd
        params["enc_ln_f"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    else:
        raise ValueError(f"unknown family {cfg.family}")

    params["ln_f"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    params["lm_head"] = L.linear_init(k_head, cfg.d_model, cfg.padded_vocab,
                                      dtype)
    nas["lm_head"] = L.nas_init(k_head, cfg.padded_vocab, cfg.quant)

    if cfg.mtp:  # deepseek multi-token-prediction: one extra block + head
        p_mtp, n_mtp = init_block(jax.random.fold_in(k_extra, 1), cfg, dtype)
        params["mtp_block"], nas["mtp_block"] = p_mtp, n_mtp
        params["mtp_ln"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    return params, nas


def _init_enc_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    p, n = {}, {}
    p["attn"], n_a = attn.init_gqa(ks[0], cfg, dtype)
    n.update({f"attn.{k}": v for k, v in n_a.items()})
    p["mlp"], n_m = init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff, dtype)
    n.update({f"mlp.{k}": v for k, v in n_m.items()})
    p["ln1"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    p["ln2"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    return p, n


def _init_dec_block(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    p, n = {}, {}
    p["attn"], n_a = attn.init_gqa(ks[0], cfg, dtype)
    n.update({f"attn.{k}": v for k, v in n_a.items()})
    p["xattn"], n_x = attn.init_gqa(ks[1], cfg, dtype)
    n.update({f"xattn.{k}": v for k, v in n_x.items()})
    p["mlp"], n_m = init_mlp(ks[2], cfg, cfg.d_model, cfg.d_ff, dtype)
    n.update({f"mlp.{k}": v for k, v in n_m.items()})
    for i in (1, 2, 3):
        p[f"ln{i}"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    return p, n


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg, batch):
    x = params["embed"][batch["tokens"]].astype(cfg.cdtype)
    if cfg.n_prefix_tokens and "prefix_embeds" in batch:
        n = cfg.n_prefix_tokens
        x = jnp.concatenate(
            [batch["prefix_embeds"].astype(cfg.cdtype), x[:, n:]], axis=1)
    return x


def _layer_keys(policy, n_layers: int, tag: int):
    """Per-layer stochastic-rounding keys for a scanned block stack, or
    None when the policy carries no SR key (every non-int8 run).  ``tag``
    decorrelates distinct stacks of one forward (enc vs dec vs groups)."""
    if policy.sr_key is None:
        return None
    return jax.random.split(jax.random.fold_in(policy.sr_key, tag),
                            n_layers)


def _scan_blocks(block_fn, params_blocks, nas_blocks, x, remat: bool = True,
                 keys=None):
    """lax.scan over a stacked layer pytree; nas may be None.

    ``keys (n_layers, 2)`` optionally threads a per-layer PRNG key (int8
    training's stochastic rounding) as a fourth ``block_fn`` argument; the
    no-keys paths keep their pre-existing scan structure exactly (the
    ``train_compute="f32"`` bit-identity contract).
    """
    fn = jax.checkpoint(block_fn) if remat else block_fn

    if keys is not None:
        def body(h, pnk):
            p, n, k = pnk
            return fn(h, p, n, k), None
        x, _ = jax.lax.scan(body, x, (params_blocks, nas_blocks, keys))
    elif nas_blocks is None:
        def body(h, p):
            return fn(h, p, None), None
        x, _ = jax.lax.scan(body, x, params_blocks)
    else:
        def body(h, pn):
            p, n = pn
            return fn(h, p, n), None
        x, _ = jax.lax.scan(body, x, (params_blocks, nas_blocks))
    return x


def forward(params, nas, cfg, batch, policy: PrecisionPolicy,
            remat: bool = True) -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S, vocab)."""
    if cfg.family == "audio":
        return _forward_encdec(params, nas, cfg, batch, policy, remat)

    x = _embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)

    keys = _layer_keys(policy, cfg.n_layers, 0)
    if cfg.family in ("dense", "vlm", "moe"):
        def bf(h, p, n, k=None):
            pol = policy if k is None else policy.with_sr_key(k)
            return block_forward(p, n, pol, cfg, h, positions)
        x = _scan_blocks(bf, params["blocks"], None if nas is None
                         else nas["blocks"], x, remat, keys=keys)
    elif cfg.family == "ssm":
        def bf(h, p, n, k=None):
            pol = policy if k is None else policy.with_sr_key(k)
            return mamba_block_forward(p, n, pol, cfg, h)
        x = _scan_blocks(bf, params["blocks"], None if nas is None
                         else nas["blocks"], x, remat, keys=keys)
    elif cfg.family == "hybrid":
        x = _forward_hybrid(params, nas, cfg, x, positions, policy, remat)

    x = L.apply_norm(x, params["ln_f"], cfg.norm)
    head_nas = nas["lm_head"] if nas is not None else None
    logits = L.qlinear(x, params["lm_head"], head_nas, policy, cfg.quant,
                       compute_dtype=cfg.cdtype)
    return _mask_pad(logits.astype(jnp.float32), cfg)


def _mask_pad(logits: jnp.ndarray, cfg) -> jnp.ndarray:
    """Mask Megatron-style vocab-padding logits to -inf (never predicted)."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    keep = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(keep, logits, -1e9)


def _forward_hybrid(params, nas, cfg, x, positions, policy, remat):
    """zamba2: mamba backbone + shared attention block every ``attn_every``."""
    Ltot, k = cfg.n_layers, cfg.attn_every
    p_sa = params["shared_attn"]
    n_sa = nas["shared_attn"] if nas is not None else None
    keys = _layer_keys(policy, Ltot, 0)

    def bf(h, p, n, kk=None):
        pol = policy if kk is None else policy.with_sr_key(kk)
        return mamba_block_forward(p, n, pol, cfg, h)

    start = 0
    while start < Ltot:
        # shared attention block at every group boundary (layers 0, k, 2k, ..)
        x = block_forward(p_sa, n_sa, policy, cfg, x, positions)
        stop = min(start + k, Ltot)
        pg = jax.tree_util.tree_map(lambda t: t[start:stop], params["blocks"])
        ng = (jax.tree_util.tree_map(lambda t: t[start:stop], nas["blocks"])
              if nas is not None else None)
        kg = keys[start:stop] if keys is not None else None
        x = _scan_blocks(bf, pg, ng, x, remat, keys=kg)
        start = stop
    return x


def _forward_encdec(params, nas, cfg, batch, policy, remat):
    """whisper: stub frame embeddings -> encoder; tokens -> decoder."""
    cd = cfg.cdtype
    enc = batch["frames"].astype(cd)                 # (B, Se, d) stub frontend
    Se = enc.shape[1]
    enc = enc + L.sinusoidal_positions(Se, cfg.d_model).astype(cd)
    positions_e = jnp.arange(Se)

    def ebf(h, p, n, k=None):
        pol = policy if k is None else policy.with_sr_key(k)
        sub = (lambda pre: {kk[len(pre):]: v for kk, v in n.items()
                            if kk.startswith(pre)}) if n is not None else (lambda pre: None)
        a = attn.gqa_forward(p["attn"], sub("attn."), pol, cfg,
                             L.apply_norm(h, p["ln1"], cfg.norm), positions_e,
                             causal=False)
        h = h + a.astype(h.dtype)
        f = mlp_forward(p["mlp"], sub("mlp."), pol, cfg,
                        L.apply_norm(h, p["ln2"], cfg.norm))
        return h + f.astype(h.dtype)

    enc = _scan_blocks(ebf, params["enc_blocks"],
                       None if nas is None else nas["enc_blocks"], enc, remat,
                       keys=_layer_keys(policy, cfg.n_encoder_layers, 1))
    enc = L.apply_norm(enc, params["enc_ln_f"], cfg.norm)

    x = params["embed"][batch["tokens"]].astype(cd)
    B, S, _ = x.shape
    x = x + L.sinusoidal_positions(S, cfg.d_model).astype(cd)
    positions = jnp.arange(S)

    def dbf(h, p, n, k=None):
        pol = policy if k is None else policy.with_sr_key(k)
        sub = (lambda pre: {kk[len(pre):]: v for kk, v in n.items()
                            if kk.startswith(pre)}) if n is not None else (lambda pre: None)
        a = attn.gqa_forward(p["attn"], sub("attn."), pol, cfg,
                             L.apply_norm(h, p["ln1"], cfg.norm), positions,
                             causal=True)
        h = h + a.astype(h.dtype)
        xa = attn.cross_forward(p["xattn"], sub("xattn."), pol, cfg,
                                L.apply_norm(h, p["ln2"], cfg.norm), enc)
        h = h + xa.astype(h.dtype)
        f = mlp_forward(p["mlp"], sub("mlp."), pol, cfg,
                        L.apply_norm(h, p["ln3"], cfg.norm))
        return h + f.astype(h.dtype)

    x = _scan_blocks(dbf, params["dec_blocks"],
                     None if nas is None else nas["dec_blocks"], x, remat,
                     keys=_layer_keys(policy, cfg.n_layers, 2))
    x = L.apply_norm(x, params["ln_f"], cfg.norm)
    head_nas = nas["lm_head"] if nas is not None else None
    logits = L.qlinear(x, params["lm_head"], head_nas, policy, cfg.quant,
                       compute_dtype=cd,
                  partial_dtype=L.partial_dtype_of(cfg))
    return _mask_pad(logits.astype(jnp.float32), cfg)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(logits: jnp.ndarray, batch: dict) -> jnp.ndarray:
    """Next-token cross-entropy (labels already shifted by the pipeline)."""
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def forward_with_mtp(params, nas, cfg, batch, policy, remat=True):
    """DeepSeek MTP: main CE + 0.3 x next-next-token CE via one extra block."""
    logits = forward(params, nas, cfg, batch, policy, remat)
    if not cfg.mtp:
        return logits, None
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(x.shape[1])
    n_mtp = nas["mtp_block"] if nas is not None else None
    h = block_forward(params["mtp_block"], n_mtp, policy, cfg,
                      L.apply_norm(x, params["mtp_ln"], cfg.norm), positions)
    head_nas = nas["lm_head"] if nas is not None else None
    mtp_logits = L.qlinear(L.apply_norm(h, params["ln_f"], cfg.norm),
                           params["lm_head"], head_nas, policy, cfg.quant,
                           compute_dtype=cfg.cdtype)
    return logits, mtp_logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# NAS-tree flattening: nested {"blocks": {"attn.wq": {...}}} -> dotted paths
# matching cost_specs keys.  A leaf is any dict holding a "gamma" array.
# ---------------------------------------------------------------------------

def flatten_nas(nas: dict, prefix: str = "") -> dict:
    flat = {}
    for k, v in nas.items():
        path = f"{prefix}{k}" if not prefix else f"{prefix}.{k}"
        if isinstance(v, dict) and "gamma" in v:
            flat[path] = v
        elif isinstance(v, dict):
            flat.update(flatten_nas(v, path))
        else:
            raise TypeError(f"unexpected NAS leaf at {path}: {type(v)}")
    return flat


# ---------------------------------------------------------------------------
# Cost specs (Eq. 7/8) for every searchable site of a model
# ---------------------------------------------------------------------------

def _site_specs_for_linear(name: str, c_out: int, c_in: int, tokens: int,
                           n_layers: int = 1) -> LayerCostSpec:
    return LayerCostSpec(name=name, c_out=n_layers * c_out,
                         weights_per_channel=c_in,
                         ops=n_layers * c_out * c_in * tokens)


def cost_specs(cfg, tokens: int) -> dict:
    """LayerCostSpec per NAS site, keyed to match the nas tree layout
    (dotted paths under blocks.* fold the layer axis)."""
    d, ff = cfg.d_model, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Ln = cfg.n_layers
    specs = {}

    def add(prefix, name, c_out, c_in, layers=1, tok=tokens):
        specs[f"{prefix}{name}"] = _site_specs_for_linear(
            f"{prefix}{name}", c_out, c_in, tok, layers)

    if cfg.family in ("dense", "vlm", "moe", "hybrid", "audio"):
        if cfg.use_mla:
            qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
            nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
            att = [("attn.wq_a", qr, d), ("attn.wq_b", H * (nope + rope), qr),
                   ("attn.wkv_a", kvr + rope, d),
                   ("attn.wkv_b", H * (nope + vd), kvr),
                   ("attn.wo", d, H * vd)]
        else:
            att = [("attn.wq", H * hd, d), ("attn.wk", KV * hd, d),
                   ("attn.wv", KV * hd, d), ("attn.wo", d, H * hd)]
        n_attn_layers = Ln if cfg.family != "hybrid" else 1  # shared block
        prefix = "blocks." if cfg.family != "hybrid" else "shared_attn."
        if cfg.family == "audio":
            for nm, co, ci in att:
                add("enc_blocks.", nm, co, ci, cfg.n_encoder_layers,
                    cfg.encoder_seq)
                add("dec_blocks.", nm, co, ci, Ln)
                add("dec_blocks.", nm.replace("attn.", "xattn."), co, ci, Ln)
        else:
            for nm, co, ci in att:
                add(prefix, nm, co, ci, n_attn_layers)
                if cfg.mtp:
                    add("mtp_block.", nm, co, ci, 1)
        if cfg.n_experts:
            E, eff = cfg.n_experts, cfg.moe_d_ff
            # ops: only top-k experts execute per token
            act_frac = cfg.experts_per_token / E
            moe_prefixes = ["blocks."] + (["mtp_block."] if cfg.mtp else [])
            for pfx in moe_prefixes:
                nl = Ln if pfx == "blocks." else 1
                for nm, co, ci in [("ffn.we_gate", E * eff, d),
                                   ("ffn.we_up", E * eff, d),
                                   ("ffn.we_down", E * d, eff)]:
                    specs[pfx + nm] = _site_specs_for_linear(
                        pfx + nm, co, ci, max(1, int(tokens * act_frac)), nl)
                if cfg.n_shared_experts:
                    sff = cfg.moe_d_ff * cfg.n_shared_experts
                    add(pfx, "ffn.shared.w_gate", sff, d, nl)
                    add(pfx, "ffn.shared.w_up", sff, d, nl)
                    add(pfx, "ffn.shared.w_down", d, sff, nl)
                if cfg.dense_residual_ff:
                    rff = cfg.dense_residual_ff
                    add(pfx, "ffn.dense_res.w_gate", rff, d, nl)
                    add(pfx, "ffn.dense_res.w_up", rff, d, nl)
                    add(pfx, "ffn.dense_res.w_down", d, rff, nl)
        elif cfg.d_ff:
            mlp_prefix = ("blocks.ffn." if cfg.family in ("dense", "vlm")
                          else "shared_attn.ffn." if cfg.family == "hybrid"
                          else "dec_blocks.mlp.")
            n_mlp = 1 if cfg.family == "hybrid" else Ln
            if cfg.mlp_type == "swiglu":
                names = [("w_gate", ff, d), ("w_up", ff, d), ("w_down", d, ff)]
            else:
                names = [("w_in", ff, d), ("w_down", d, ff)]
            for nm, co, ci in names:
                add(mlp_prefix, nm, co, ci, n_mlp)
            if cfg.family == "audio":
                for nm, co, ci in names:
                    add("enc_blocks.mlp.", nm, co, ci, cfg.n_encoder_layers,
                        cfg.encoder_seq)

    if cfg.family in ("ssm", "hybrid"):
        d_inner, Hs, N, P = ssm_mod.dims(cfg)
        add("blocks.", "in_proj", 2 * d_inner + 2 * N + Hs, d, Ln)
        add("blocks.", "out_proj", d, d_inner, Ln)

    add("", "lm_head", cfg.padded_vocab, d, 1)
    return specs
