"""Mixture-of-Experts layer with sort-based capacity dispatch.

Routing avoids the classic (T, E, C) one-hot dispatch tensor — at
deepseek-v3 scale (T≈1M tokens, E=256) that tensor is unbuildable.  Instead:

  1. top-k gates per token,
  2. flatten (token, slot) assignments, stable-sort by expert id,
  3. position-in-expert = rank within the sorted run (arange - segment start),
  4. scatter tokens into an (E, C, d) buffer, dense per-expert einsum,
  5. gather back and combine with gate weights.

Memory is O(T·k + E·C·d); the sort is O(T·k log).  Tokens over capacity are
dropped (standard capacity-factor routing; capacity_factor from config).

The (E, C, d) buffer is sharded over the *model* mesh axis on E (expert
parallelism) — the scatter/gather lower to all-to-alls under GSPMD.

Expert weights are quantization-aware: the per-channel gamma covers each
expert's output channels independently (the paper's channel-wise assignment
extends naturally: an expert's FFN rows are just more channels).

DeepSeek extras supported: shared experts (always-on dense branch) and
sigmoid routing with top-k over scores; Arctic extras: dense residual MLP in
parallel with the MoE branch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.api.policy import Phase, PrecisionPolicy
from repro.models import layers as L


def init_moe(key, cfg, dtype) -> tuple[dict, dict]:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 8)
    params = {
        "router": L.linear_init(ks[0], d, E, dtype),   # kept high-precision
        "we_gate": L.linear_init(ks[1], d, E * ff, dtype),
        "we_up": L.linear_init(ks[2], d, E * ff, dtype),
        "we_down": L.linear_init(ks[3], ff, E * d, dtype),
    }
    # reshape expert weights to (E, c_out, c_in)
    params["we_gate"]["w"] = params["we_gate"]["w"].reshape(E, ff, d)
    params["we_gate"]["aw"] = params["we_gate"]["aw"].reshape(E, ff)
    params["we_up"]["w"] = params["we_up"]["w"].reshape(E, ff, d)
    params["we_up"]["aw"] = params["we_up"]["aw"].reshape(E, ff)
    params["we_down"]["w"] = params["we_down"]["w"].reshape(E, d, ff)
    params["we_down"]["aw"] = params["we_down"]["aw"].reshape(E, d)
    nas = {
        name: L.nas_init(ks[4], E * params[name]["w"].shape[1], cfg.quant)
        for name in ("we_gate", "we_up", "we_down")
    }
    # reshape gammas to (E, c_out, |P|) to ride along the expert axis
    if cfg.quant.per_channel:
        for name in nas:
            g = nas[name]["gamma"]
            nas[name]["gamma"] = g.reshape(E, params[name]["w"].shape[1], -1)
    if cfg.n_shared_experts:
        params["shared"] = {
            "w_gate": L.linear_init(ks[5], d, ff * cfg.n_shared_experts, dtype),
            "w_up": L.linear_init(ks[6], d, ff * cfg.n_shared_experts, dtype),
            "w_down": L.linear_init(ks[7], ff * cfg.n_shared_experts, d, dtype),
        }
        nas["shared.w_gate"] = L.nas_init(ks[5], ff * cfg.n_shared_experts, cfg.quant)
        nas["shared.w_up"] = L.nas_init(ks[6], ff * cfg.n_shared_experts, cfg.quant)
        nas["shared.w_down"] = L.nas_init(ks[7], d, cfg.quant)
    if cfg.dense_residual_ff:
        params["dense_res"] = {
            "w_gate": L.linear_init(ks[5], d, cfg.dense_residual_ff, dtype),
            "w_up": L.linear_init(ks[6], d, cfg.dense_residual_ff, dtype),
            "w_down": L.linear_init(ks[7], cfg.dense_residual_ff, d, dtype),
        }
        nas["dense_res.w_gate"] = L.nas_init(ks[5], cfg.dense_residual_ff, cfg.quant)
        nas["dense_res.w_up"] = L.nas_init(ks[6], cfg.dense_residual_ff, cfg.quant)
        nas["dense_res.w_down"] = L.nas_init(ks[7], d, cfg.quant)
    return params, nas


def _expert_weights(p, nas, policy, qcfg):
    """Policy-appropriate fake quantization of stacked (E, c_out, c_in)
    float weights (search-time phases).  Deployed QTensor stacks never come
    through here — ``moe_forward`` contracts them packed (expert-batched
    fused kernel) instead of dequantizing a dense stack."""
    from repro.core import mixedprec as mp
    from repro.core import quantizers as qz
    w = p["w"]
    E, co, ci = w.shape
    if policy.phase is Phase.FLOAT:
        return w
    aw = p["aw"].reshape(E * co)
    wf = w.reshape(E * co, ci)
    if policy.phase is Phase.QAT8:
        out = qz.quantize_weight(wf, aw[:, None], 8)
    elif policy.phase is Phase.SEARCH:
        g = nas["gamma"].reshape(E * co, -1)
        out = mp.effective_weight(wf, g, aw, policy.tau, qcfg)
    elif policy.phase is Phase.FROZEN:
        g = nas["gamma"].reshape(E * co, -1)
        out = mp.frozen_weight(wf, g, aw, qcfg)
    else:
        raise ValueError(policy)
    return out.reshape(E, co, ci)


def route_topk(logits: jnp.ndarray, k: int, routing: str = "softmax"
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-token top-k gates.  Returns (gates (T,k), experts (T,k))."""
    if routing == "sigmoid":   # deepseek-v3 style
        scores = jax.nn.sigmoid(logits.astype(jnp.float32))
        topv, topi = jax.lax.top_k(scores, k)
        gates = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)
    else:
        topv, topi = jax.lax.top_k(logits.astype(jnp.float32), k)
        gates = jax.nn.softmax(topv, axis=-1)
    return gates, topi


def dispatch_indices(experts: jnp.ndarray, n_experts: int, capacity: int
                     ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort-based positions: returns (dest_slot, keep_mask, inv_order).

    ``experts``: flat (T*k,) expert ids.  ``dest_slot[i] = e_i*C + pos_i`` for
    kept assignments (pos < capacity), else clamped to slot 0 with keep=False.
    """
    n = experts.shape[0]
    order = jnp.argsort(experts, stable=True)
    sorted_e = experts[order]
    counts = jnp.bincount(experts, length=n_experts)
    starts = jnp.cumsum(counts) - counts                     # exclusive
    pos = jnp.arange(n) - starts[sorted_e]                   # rank in expert
    keep_sorted = pos < capacity
    dest_sorted = jnp.where(keep_sorted, sorted_e * capacity + pos, 0)
    # undo the sort: scatter back to assignment order
    inv = jnp.argsort(order, stable=True)
    return dest_sorted[inv], keep_sorted[inv], order


def moe_forward(p: dict, nas: Optional[dict], policy: PrecisionPolicy, cfg,
                x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, k, ff = cfg.n_experts, cfg.experts_per_token, cfg.moe_d_ff
    cd = cfg.cdtype
    T = B * S
    xt = x.reshape(T, d)
    getn = (lambda n: nas[n]) if nas is not None else (lambda n: None)

    # router in float32 (precision-sensitive; analogous to the paper keeping
    # first/last layers at 8b)
    logits = L.qlinear(xt, p["router"], None, PrecisionPolicy.FLOAT, cfg.quant,
                       compute_dtype=jnp.float32)
    routing = "sigmoid" if cfg.n_shared_experts else "softmax"
    gates, topi = route_topk(logits, k, routing)             # (T,k)

    capacity = int(cfg.capacity_factor * T * k / E)
    capacity = max(8, min(capacity, T))
    flat_e = topi.reshape(T * k)
    dest, keep, _ = dispatch_indices(flat_e, E, capacity)

    # scatter tokens into (E*C, d) buffer
    src = jnp.repeat(jnp.arange(T), k)
    xt = constrain(xt, "D", None)
    contrib = constrain(jnp.where(keep[:, None], xt[src].astype(cd), 0),
                        "D", None)
    buf = jnp.zeros((E * capacity, d), cd).at[dest].add(
        jnp.where(keep[:, None], contrib, 0))
    # expert-major buffer lives sharded over the model axis (experts) with
    # capacity over data — without this constraint SPMD replicates the
    # (E, C, d) buffer and all-reduces it per layer (§Perf measurement)
    buf = constrain(buf.reshape(E, capacity, d), "M", "D", None)

    from repro.api.qtensor import QTensor
    if isinstance(p["we_gate"]["w"], QTensor):
        # deployed: expert-stacked QTensors contract the (E, C, d) buffer
        # packed — one expert-batched fused launch per weight under
        # backend="pallas" — instead of dequantizing a dense (E, co, ci)
        # stack (the pre-PR4 bandwidth leak)
        bk = policy.backend
        h = L.swiglu(p["we_gate"]["w"].matmul(buf, cd, bk),
                     p["we_up"]["w"].matmul(buf, cd, bk))
        out_buf = p["we_down"]["w"].matmul(h, cd, bk)
    else:
        wg = _expert_weights(p["we_gate"], getn("we_gate"), policy, cfg.quant).astype(cd)
        wu = _expert_weights(p["we_up"], getn("we_up"), policy, cfg.quant).astype(cd)
        wd = _expert_weights(p["we_down"], getn("we_down"), policy, cfg.quant).astype(cd)
        h = L.swiglu(jnp.einsum("ecd,efd->ecf", buf, wg),
                     jnp.einsum("ecd,efd->ecf", buf, wu))
        out_buf = jnp.einsum("ecf,edf->ecd", h, wd)
    out_buf = constrain(out_buf, "M", "D", None).reshape(E * capacity, d)

    # gather back, weight by gates, sum the k slots
    gathered = constrain(jnp.where(keep[:, None], out_buf[dest], 0),
                         "D", None)
    weighted = gathered * gates.reshape(T * k, 1).astype(cd)
    out = constrain(jnp.zeros((T, d), cd).at[src].add(weighted), "D", None)

    if cfg.n_shared_experts:
        sp = p["shared"]
        h = L.swiglu(
            L.qlinear(xt, sp["w_gate"], getn("shared.w_gate"), policy,
                      cfg.quant, compute_dtype=cd),
            L.qlinear(xt, sp["w_up"], getn("shared.w_up"), policy,
                      cfg.quant, compute_dtype=cd))
        out = out + L.qlinear(h, sp["w_down"], getn("shared.w_down"),
                              policy, cfg.quant, compute_dtype=cd)
    if cfg.dense_residual_ff:
        dp = p["dense_res"]
        h = L.swiglu(
            L.qlinear(xt, dp["w_gate"], getn("dense_res.w_gate"), policy,
                      cfg.quant, compute_dtype=cd),
            L.qlinear(xt, dp["w_up"], getn("dense_res.w_up"), policy,
                      cfg.quant, compute_dtype=cd))
        out = out + L.qlinear(h, dp["w_down"], getn("dense_res.w_down"),
                              policy, cfg.quant, compute_dtype=cd)
    return out.reshape(B, S, d).astype(x.dtype)


def aux_load_balance_loss(logits: jnp.ndarray, topi: jnp.ndarray,
                          n_experts: int) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (fraction × probability)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)
    onehot = jax.nn.one_hot(topi[:, 0], n_experts)
    ce = jnp.mean(onehot, axis=0)
    return n_experts * jnp.sum(me * ce)
