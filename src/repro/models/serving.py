"""Deployed-model serving: mixed-precision packed weights + int8 KV caches.

This is the paper's Sec. III-C output running as a production inference
path.  Each searched linear becomes up to |P_W| per-precision row groups
(channels reordered offline, group sizes static and 128-aligned — see
core/deploy.py and config.DeploySpec), stored packed in uint8.  At run time
each group is a dense sub-GEMM after an in-register dequant — the TPU
analogue of the paper's "three parallel sub-convolutions", implemented by
kernels/quant_matmul.py (Pallas) with a pure-jnp fallback used on CPU.

Deployed weights move HBM->VMEM as *packed bytes*: a 2-bit channel costs 1/4
the bandwidth of an 8-bit one.  Decode is bandwidth-bound, so the searched
assignment directly scales serving throughput — the paper's memory saving
becomes a latency/energy saving on TPU (DESIGN.md §2).

Formats
-------
A deployed linear is ``{"w": repro.api.QTensor[, "bias": (c_out,)]}`` — the
QTensor (a registered pytree) carries the packed per-precision groups,
per-channel scales and the optional canonical-order restore permutation
(structure-sensitive consumers: attention heads, residual stream).  MoE
expert weights carry a leading E axis on the QTensor's leaves.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.qtensor import QTensor
from repro.cache import paged
from repro.dist import sharding as shd
from repro.core import quantizers as qz
from repro.models import attention as attn
from repro.models import kv_quant as kvq
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# Deployed linear: init (static assignment from DeploySpec) and apply
# ---------------------------------------------------------------------------

def init_deployed_linear(key, c_in: int, c_out: int, cfg,
                         bias: bool = False, expert_axis: int = 0,
                         tile_n="auto") -> dict:
    """Random-weight deployed linear with the config's static group sizes.

    ``expert_axis``: if >0, adds a leading expert dimension E=expert_axis to
    every leaf (MoE).  Weights are synthesized then truly quantized+packed so
    dry-run tensors have exactly the deployed bytes.  Static assignments are
    built group-contiguous, so no permutation is carried.

    ``tile_n`` (default ``"auto"``) additionally builds the tile-aligned
    **fused single-launch layout** — per-expert ragged byte buffers under
    one static tile schedule (the schedule depends only on the static group
    sizes, so all experts share it) — which lets ``backend="pallas"`` serve
    the site as ONE ``pallas_call``, expert-batched for MoE stacks.  Pass
    ``None`` for per-group-only packing.  The builder is traced-safe:
    ``init_deployed_model`` vmaps it over layers, so the schedule is pure
    Python/numpy over static sizes and the byte buffers are jnp ops.
    Contractions beyond the fused kernel's single-K-step budget skip the
    fused layout (per-group fall-back, as in ``QTensor.from_assignment``).

    NOTE this is the traced-safe sibling of
    ``repro.api.qtensor._fused_tile_layout`` (the numpy builder behind
    ``QTensor.from_assignment``): both emit the contract consumed by
    ``kernels/quant_matmul.fused_tile_offsets`` and the fused kernels —
    tile segments contiguous in walk order, per-tile bytes
    ``tile_n * Kp * b/8``, zero scales on padding rows, ``fused_perm``
    None iff padding lands only past ``c_out``.  Here the assignment is
    group-contiguous and ascending-bit, so the walk order is the natural
    group order and no tile sort is needed; change the layout in BOTH
    builders or the kernel asserts / parity harnesses will fail.
    """
    from repro.api.qtensor import _auto_tile_n
    from repro.kernels import quant_matmul as qmk
    sizes = cfg.deploy.group_sizes(c_out, sorted(cfg.quant.weight_bits))
    E = max(expert_axis, 1)
    if tile_n == "auto":
        # group sizes are align-rounded, so an align-divisible tile keeps
        # the walk order identity (no output gather) for most layers
        tile_n = min(_auto_tile_n(c_out), cfg.deploy.align)
    Kp = -(-c_in // qmk.FUSED_K_ALIGN) * qmk.FUSED_K_ALIGN
    if tile_n is not None and Kp > qmk.K_SINGLE_STEP_MAX:
        tile_n = None                  # contraction too deep to fuse
    packed_groups, scale_groups, used_bits = [], [], []
    fused_p, fused_s, tile_bits, tcol = [], [], [], []
    dep = 0
    for b, n in sizes.items():
        if n == 0:
            continue
        f = qz.pack_factor(b)
        kpad = Kp if tile_n is not None else -(-c_in // f) * f
        kw, ks = jax.random.split(jax.random.fold_in(key, b))
        w = jax.random.normal(kw, (E, n, c_in)) / np.sqrt(c_in)
        alpha = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
        q, scale = qz.quantize_weight_int(w, alpha, b)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, kpad - c_in)))
        packed = qz.pack_int(q, b)                     # (E, n, kpad/f)
        packed_groups.append(packed if expert_axis else packed[0])
        scale_groups.append((scale[..., 0] if expert_axis
                             else scale[0, :, 0]).astype(jnp.float32))
        used_bits.append(b)
        if tile_n is not None:
            pad = (-n) % tile_n
            qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
            sp = jnp.pad(scale[..., 0].astype(jnp.float32),
                         ((0, 0), (0, pad)))
            # tiles are contiguous row runs, so the group's row-major bytes
            # ARE its tile segments in walk order
            fused_p.append(qz.pack_int(qp, b).reshape(E, -1))
            fused_s.append(sp)
            tile_bits += [b] * ((n + pad) // tile_n)
            tcol += list(range(dep, dep + n)) + [-1] * pad
        dep += n
    fused = {}
    if tile_n is not None:
        fp = jnp.concatenate(fused_p, axis=-1)
        fs = jnp.concatenate(fused_s, axis=-1)
        tcol = np.asarray(tcol)
        if (tcol[:c_out] == np.arange(c_out)).all() and (tcol[c_out:] < 0).all():
            fperm = None               # tile padding only past c_out
        else:
            cols = np.nonzero(tcol >= 0)[0].astype(np.int32)
            gather = np.zeros(c_out, np.int32)
            gather[tcol[cols]] = cols
            fperm = jnp.asarray(gather)
        fused = dict(fused_packed=fp if expert_axis else fp[0],
                     fused_scales=fs if expert_axis else fs[0],
                     fused_perm=fperm, tile_bits=tuple(tile_bits),
                     tile_n=tile_n)
    qt = QTensor(tuple(packed_groups), tuple(scale_groups), None,
                 tuple(used_bits), c_out, c_in,
                 act_bits=cfg.deploy.act_bits, restore_order=False,
                 experts=E if expert_axis else None, **fused)
    out = {"w": qt}
    if bias:
        out["bias"] = jnp.zeros((E, c_out) if expert_axis else (c_out,),
                                jnp.bfloat16)
    return out


def dq_linear(x: jnp.ndarray, dp: dict, compute_dtype=jnp.bfloat16,
              backend: str = "jnp") -> jnp.ndarray:
    """Apply a deployed linear: x (..., c_in) -> (..., c_out).

    Thin wrapper over :meth:`QTensor.matmul` plus the optional bias.
    ``backend="pallas"`` uses the single-launch fused multi-precision
    kernel when the QTensor carries the tile-aligned layout and falls back
    to one unpack+dequant+GEMM launch per precision group otherwise
    (``"pallas-pergroup"`` forces the per-group reference path).

    An expert-stacked QTensor (MoE) maps ``x (E, ..., c_in) -> (E, ...,
    c_out)`` per expert — one expert-batched fused launch under
    ``backend="pallas"``.
    """
    y = dp["w"].matmul(x, compute_dtype, backend)
    if "bias" in dp:
        b = dp["bias"].astype(y.dtype)
        if dp["w"].experts is not None:     # (E, c_out) broadcast over rows
            b = b.reshape((b.shape[0],) + (1,) * (y.ndim - 2) + (b.shape[-1],))
        y = y + b
    return y


def debug_dense_view(dp: dict, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Dense float view of a deployed linear — DEBUG / ANALYSIS ONLY.

    ``(c_out, c_in)`` for a plain linear, stacked ``(E, c_out, c_in)`` for
    MoE expert weights.  Replaces the removed ``dense_view`` /
    ``dq_expert_weights`` helpers: as of PR 4 **no serving hot path
    dequantizes a full weight** — MoE experts run through the expert-batched
    fused kernel and MLA decode expands its latents through the packed
    ``wkv_b`` matmul (enforced by the all-family monkeypatch guard in
    tests/test_serving_consistency.py).
    """
    return dp["w"].dequantize(compute_dtype)


def deployed_from_search(w, gamma, alpha_w, delta, alpha_x, cfg,
                         restore_order: bool = False) -> dict:
    """Real Sec. III-C transform of a searched linear into deployed format."""
    from repro.core import deploy as dpl
    qt = dpl.deploy_linear(np.asarray(w), np.asarray(gamma),
                           np.asarray(alpha_w),
                           None if delta is None else np.asarray(delta),
                           float(alpha_x), cfg.quant, align=cfg.deploy.align,
                           restore_order=restore_order)
    return {"w": qt}


# ---------------------------------------------------------------------------
# Deployed whole-model init (static assignment — used by the serve dry-run)
# ---------------------------------------------------------------------------

def _dl(key, c_in, c_out, cfg, bias=False):
    return init_deployed_linear(key, c_in, c_out, cfg, bias=bias)


def _init_deployed_attn(key, cfg):
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    if cfg.use_mla:
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return {
            "wq_a": _dl(ks[0], d, qr, cfg),
            "wq_b": _dl(ks[1], qr, H * (nope + rope), cfg),
            "wkv_a": _dl(ks[2], d, kvr + rope, cfg),
            "wkv_b": _dl(ks[3], kvr, H * (nope + vd), cfg),
            "wo": _dl(ks[4], H * vd, d, cfg),
            "q_norm": L.norm_init(qr, "rmsnorm", jnp.bfloat16),
            "kv_norm": L.norm_init(kvr, "rmsnorm", jnp.bfloat16),
        }
    return {
        "wq": _dl(ks[0], d, H * hd, cfg, bias=cfg.qkv_bias),
        "wk": _dl(ks[1], d, KV * hd, cfg, bias=cfg.qkv_bias),
        "wv": _dl(ks[2], d, KV * hd, cfg, bias=cfg.qkv_bias),
        "wo": _dl(ks[3], H * hd, d, cfg),
    }


def _init_deployed_ffn(key, cfg):
    d = cfg.d_model
    # 10 keys: a config with BOTH a shared expert and a dense residual MLP
    # (deepseek + arctic extras combined) must not reuse ks[4..6] for the
    # two sub-trees — they would deploy identical weights (PR 4 bugfix,
    # regression-tested in tests/test_expert_parity.py)
    ks = jax.random.split(key, 10)
    if cfg.n_experts:
        E, ff = cfg.n_experts, cfg.moe_d_ff
        p = {
            "router": (jax.random.normal(ks[0], (E, d)) / np.sqrt(d)
                       ).astype(jnp.bfloat16),
            "we_gate": init_deployed_linear(ks[1], d, ff, cfg, expert_axis=E),
            "we_up": init_deployed_linear(ks[2], d, ff, cfg, expert_axis=E),
            "we_down": init_deployed_linear(ks[3], ff, d, cfg, expert_axis=E),
        }
        if cfg.n_shared_experts:
            sff = ff * cfg.n_shared_experts
            p["shared"] = {"w_gate": _dl(ks[4], d, sff, cfg),
                           "w_up": _dl(ks[5], d, sff, cfg),
                           "w_down": _dl(ks[6], sff, d, cfg)}
        if cfg.dense_residual_ff:
            rff = cfg.dense_residual_ff
            p["dense_res"] = {"w_gate": _dl(ks[7], d, rff, cfg),
                              "w_up": _dl(ks[8], d, rff, cfg),
                              "w_down": _dl(ks[9], rff, d, cfg)}
        return p
    if cfg.mlp_type == "swiglu":
        return {"w_gate": _dl(ks[0], d, cfg.d_ff, cfg),
                "w_up": _dl(ks[1], d, cfg.d_ff, cfg),
                "w_down": _dl(ks[2], cfg.d_ff, d, cfg)}
    return {"w_in": _dl(ks[0], d, cfg.d_ff, cfg),
            "w_down": _dl(ks[1], cfg.d_ff, d, cfg)}


def _init_deployed_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"attn": _init_deployed_attn(k1, cfg),
            "ffn": _init_deployed_ffn(k2, cfg),
            "ln1": L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16),
            "ln2": L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16)}


def _init_deployed_mamba(key, cfg):
    d = cfg.d_model
    d_inner, H, N, P = ssm_mod.dims(cfg)
    ks = jax.random.split(key, 3)
    return {
        "in_proj": _dl(ks[0], d, 2 * d_inner + 2 * N + H, cfg),
        "out_proj": _dl(ks[1], d_inner, d, cfg),
        "conv_w": (jax.random.normal(ks[2], (ssm_mod.CONV_K, d_inner + 2 * N))
                   / 2.0).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((d_inner + 2 * N,), jnp.bfloat16),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": L.norm_init(d_inner, "rmsnorm", jnp.bfloat16),
        "ln": L.norm_init(d, cfg.norm, jnp.bfloat16),
    }


def init_deployed_model(cfg, key) -> dict:
    ks = jax.random.split(key, 5)
    params = {"embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
                        * 0.02).astype(jnp.bfloat16)}
    stack = lambda fn, k, n: jax.vmap(fn)(jax.random.split(k, n))
    if cfg.family in ("dense", "vlm", "moe"):
        params["blocks"] = stack(lambda k: _init_deployed_block(k, cfg),
                                 ks[1], cfg.n_layers)
    elif cfg.family == "ssm":
        params["blocks"] = stack(lambda k: _init_deployed_mamba(k, cfg),
                                 ks[1], cfg.n_layers)
    elif cfg.family == "hybrid":
        params["blocks"] = stack(lambda k: _init_deployed_mamba(k, cfg),
                                 ks[1], cfg.n_layers)
        params["shared_attn"] = _init_deployed_block(ks[2], cfg)
    elif cfg.family == "audio":
        params["enc_blocks"] = stack(
            lambda k: {"attn": _init_deployed_attn(k, cfg),
                       "mlp": _init_deployed_ffn(k, cfg),
                       "ln1": L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16),
                       "ln2": L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16)},
            ks[1], cfg.n_encoder_layers)
        params["dec_blocks"] = stack(
            lambda k: {"attn": _init_deployed_attn(k, cfg),
                       "xattn": _init_deployed_attn(k, cfg),
                       "mlp": _init_deployed_ffn(k, cfg),
                       "ln1": L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16),
                       "ln2": L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16),
                       "ln3": L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16)},
            ks[2], cfg.n_layers)
        params["enc_ln_f"] = L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16)
    params["ln_f"] = L.norm_init(cfg.d_model, cfg.norm, jnp.bfloat16)
    params["lm_head"] = _dl(ks[3], cfg.d_model, cfg.vocab_size, cfg)
    return params


# ---------------------------------------------------------------------------
# Serving forward passes
# ---------------------------------------------------------------------------

def _dq(cd, backend="jnp"):
    return lambda x, dp: dq_linear(x, dp, cd, backend)


def kv_specs(cfg, kv_bits):
    """Resolve the ``kv_bits`` cache policy knob into per-site channel-group
    specs: ``(gqa_spec, mla_spec)``.

    ``kv_bits=None`` keeps the legacy int8-per-token cache contract on every
    ring (``(None, None)``).  An int or bit-tuple builds a
    :class:`~repro.models.kv_quant.KVQuantSpec` over each ring's feature
    axis — ``head_dim`` for GQA K/V (dense/vlm/moe attention, the hybrid
    shared block, audio self+cross) and ``kv_lora_rank`` for the MLA latent.
    ``ssm`` has no per-token ring, so the knob is a no-op there.  Raises at
    resolution time (engine construction) when a feature axis cannot honor
    the requested packing, never inside a jitted step.
    """
    if kv_bits is None or cfg.family == "ssm":
        return None, None
    if cfg.use_mla and cfg.family in ("dense", "vlm", "moe"):
        return None, kvq.spec_for(kv_bits, cfg.kv_lora_rank)
    return kvq.spec_for(kv_bits, cfg.head_dim), None


def _deployed_attn_full(p, cfg, x, positions, causal=True, enc=None,
                        backend="jnp", build_cache=False, kv_spec=None):
    """Full-seq attention on deployed weights; optionally emit a quantized
    cache (legacy int8 per token, or channel-wise packed under ``kv_spec``)."""
    B, S, _ = x.shape
    cd = cfg.cdtype
    dq = _dq(cd, backend)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if enc is None else enc
    q = dq(x, p["wq"]).reshape(B, S, H, hd)
    k = dq(src, p["wk"]).reshape(B, src.shape[1], KV, hd)
    v = dq(src, p["wv"]).reshape(B, src.shape[1], KV, hd)
    if enc is None and cfg.rope_partial > 0:
        cos, sin, rot = L.rope_freqs(hd, cfg.rope_theta, positions,
                                     cfg.rope_partial)
        q = L.apply_rope(q, cos, sin, rot)
        k = L.apply_rope(k, cos, sin, rot)
    o = attn.gqa_core(q, k, v, H, KV, causal=causal and enc is None)
    y = dq(o.reshape(B, S, H * hd), p["wo"])
    cache = None
    if build_cache:
        if kv_spec is None:
            kq, ksc = attn.quant_per_token(k.transpose(0, 2, 1, 3))
            vq, vsc = attn.quant_per_token(v.transpose(0, 2, 1, 3))
        else:
            kq, ksc = kvq.quant_channelwise(k.transpose(0, 2, 1, 3), kv_spec)
            vq, vsc = kvq.quant_channelwise(v.transpose(0, 2, 1, 3), kv_spec)
        cache = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
    return y, cache


def _deployed_mla_full(p, cfg, x, positions, backend="jnp",
                       build_cache=False, kv_spec=None):
    B, S, _ = x.shape
    cd = cfg.cdtype
    dq = _dq(cd, backend)
    H = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    cq = L.rmsnorm(dq(x, p["wq_a"]), p["q_norm"])
    q = dq(cq, p["wq_b"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv = dq(x, p["wkv_a"])
    c_kv, k_rope = ckv[..., :kvr], ckv[..., kvr:]
    c_kv = L.rmsnorm(c_kv, p["kv_norm"])
    kv = dq(c_kv, p["wkv_b"]).reshape(B, S, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    cos, sin, rot = L.rope_freqs(rope, cfg.rope_theta, positions, 1.0)
    q_rope = L.apply_rope(q_rope, cos, sin, rot)
    k_rope_r = L.apply_rope(k_rope[:, :, None, :], cos, sin, rot)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_r, (B, S, H, rope))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = attn.gqa_core(q_full, k_full, v, H, H, causal=True)
    y = dq(o.reshape(B, S, H * vd), p["wo"])
    cache = None
    if build_cache:
        if kv_spec is None:
            qc, qs = attn.quant_per_token(c_kv)
        else:
            qc, qs = kvq.quant_channelwise(c_kv, kv_spec)
        cache = {"ckv": qc, "ckv_scale": qs,
                 "krope": k_rope_r[:, :, 0].astype(jnp.bfloat16)}
    return y, cache


def _deployed_ffn_full(p, cfg, x, backend="jnp"):
    cd = cfg.cdtype
    dq = _dq(cd, backend)
    if cfg.n_experts:
        return _deployed_moe(p, cfg, x, backend)
    if cfg.mlp_type == "swiglu":
        h = L.swiglu(dq(x, p["w_gate"]), dq(x, p["w_up"]))
    else:
        h = jax.nn.gelu(dq(x, p["w_in"]))
    return dq(h, p["w_down"])


def _deployed_moe(p, cfg, x, backend="jnp"):
    B, S, d = x.shape
    cd = cfg.cdtype
    dq = _dq(cd, backend)
    E, k, ff = cfg.n_experts, cfg.experts_per_token, cfg.moe_d_ff
    T = B * S
    xt = x.reshape(T, d)
    # mesh serving: the router is the one f32 GEMM on the decode path — its
    # reduction order must not depend on the mesh, so input and weight stay
    # replicated (ShardingRules replicates "router"); identity off-mesh
    xt = shd.replicate_serving(xt)
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32).T)
    routing = "sigmoid" if cfg.n_shared_experts else "softmax"
    gates, topi = moe_mod.route_topk(logits, k, routing)
    capacity = max(8, min(int(cfg.capacity_factor * T * k / E), T))
    dest, keep, _ = moe_mod.dispatch_indices(topi.reshape(-1), E, capacity)
    src = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E * capacity, d), cd).at[dest].add(
        jnp.where(keep[:, None], xt[src].astype(cd), 0)).reshape(E, capacity, d)
    # packed grouped expert GEMMs: the expert-stacked QTensors contract the
    # (E, C, d) buffer per expert — ONE expert-batched fused launch each
    # under backend="pallas"; no (E, c_out, c_in) dense stack materializes
    h = L.swiglu(dq(buf, p["we_gate"]), dq(buf, p["we_up"]))
    # mesh serving: the expert GEMMs above run expert-parallel; the combine
    # scatter-adds in cd with duplicate destinations, so it replicates to
    # keep the addition order mesh-independent (identity off-mesh)
    out_buf = shd.replicate_serving(
        dq(h, p["we_down"])).reshape(E * capacity, d)
    gathered = jnp.where(keep[:, None], out_buf[dest], 0)
    out = jnp.zeros((T, d), cd).at[src].add(
        gathered * gates.reshape(-1, 1).astype(cd))
    if cfg.n_shared_experts:
        sp = p["shared"]
        h = L.swiglu(dq(xt, sp["w_gate"]), dq(xt, sp["w_up"]))
        out = out + dq(h, sp["w_down"])
    if cfg.dense_residual_ff:
        dp_ = p["dense_res"]
        h = L.swiglu(dq(xt, dp_["w_gate"]), dq(xt, dp_["w_up"]))
        out = out + dq(h, dp_["w_down"])
    return out.reshape(B, S, d)


def _deployed_mamba_full(p, cfg, x, backend="jnp", lens=None):
    """Deployed mamba block; returns (y, final ssm state).

    ``lens``: optional (B,) per-row true prompt lengths for right-padded
    batches.  Padded steps are made exact no-ops on the recurrence by
    zeroing ``dt`` there (``dA = 0`` -> decay 1, ``x*dt = 0`` -> no input),
    so the returned state is the state *at each row's own last real token*;
    the conv ring tail is gathered per row at ``lens`` instead of the
    static trailing slice.  With ``lens`` full (or None) both reductions
    see identical operands, so the padded path is bit-identical to the
    unpadded one.
    """
    B, S, d = x.shape
    cd = cfg.cdtype
    dq = _dq(cd, backend)
    d_inner, H, N, P = ssm_mod.dims(cfg)
    h_in = L.apply_norm(x, p["ln"], cfg.norm)
    zxbcdt = dq(h_in, p["in_proj"])
    z = zxbcdt[..., :d_inner]
    xbc_in = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    xbc = ssm_mod._causal_conv(xbc_in,
                               p["conv_w"].astype(cd), p["conv_b"].astype(cd))
    xs = xbc[..., :d_inner].reshape(B, S, H, P)
    Bm = xbc[..., d_inner:d_inner + N]
    Cm = xbc[..., d_inner + N:]
    dt = jax.nn.softplus(zxbcdt[..., -H:].astype(jnp.float32) + p["dt_bias"])
    if lens is not None:
        pad_mask = jnp.arange(S)[None, :] < lens[:, None]    # (B, S)
        dt = jnp.where(pad_mask[..., None], dt, 0.0)
    A = jnp.exp(p["A_log"])
    y, hT = ssm_mod.ssd_chunked(xs.astype(jnp.float32), dt, A,
                                Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                                cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(cd)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"])
    K1 = ssm_mod.CONV_K - 1
    if lens is None:
        conv_tail = xbc_in[:, -K1:]
    else:
        idx = lens[:, None] - K1 + jnp.arange(K1)[None, :]   # (B, K-1)
        tail = jnp.take_along_axis(xbc_in, jnp.maximum(idx, 0)[..., None],
                                   axis=1)
        conv_tail = jnp.where((idx >= 0)[..., None], tail, 0.0)
    return x + dq(y, p["out_proj"]).astype(x.dtype), {
        "h": hT, "conv": conv_tail.astype(jnp.bfloat16)}


def _last_token(x, lens):
    """Per-row last real token of a right-padded batch: (B, S, d) -> (B, 1, d).

    ``lens=None`` keeps the static ``x[:, -1:]`` slice (full-length batch);
    with ``lens`` the gather at ``lens-1`` reads the same elements when the
    row is full-length, so the padded path stays bit-identical there.
    """
    if lens is None:
        return x[:, -1:]
    idx = (jnp.maximum(lens, 1) - 1).astype(jnp.int32)[:, None, None]
    return jnp.take_along_axis(x, idx, axis=1)


def prefill(dparams, cfg, batch, backend: str = "jnp", lens=None,
            kv_bits=None):
    """Full-sequence deployed forward.  Returns (last-token logits, caches).

    ``lens``: optional (B,) int32 per-row true prompt lengths for a
    right-padded ``tokens`` batch (the continuous-batching admission path —
    api/scheduler.py pads every prompt to one static prefill width so
    admission never re-jits).  Logits are then taken at each row's own
    last real token; SSM states stop at ``lens`` (padded steps are exact
    no-ops); attention caches still carry entries for the padded tail, but
    those sit strictly *above* each slot's position and every decode mask
    is ``<= pos``, and the first ``pos`` advance overwrites index ``lens``
    before it ever becomes visible — so the padding is never attended.

    ``kv_bits``: cache quantization policy (see :func:`kv_specs`) — the
    emitted caches then carry the channel-wise packed layout and must pair
    with ``init_caches``/``init_paged_caches``/``decode_step`` at the SAME
    ``kv_bits``.
    """
    cd = cfg.cdtype
    gqa_spec, mla_spec = kv_specs(cfg, kv_bits)
    if cfg.family == "audio":
        return _prefill_encdec(dparams, cfg, batch, backend, lens, gqa_spec)
    x = dparams["embed"][batch["tokens"]].astype(cd)
    if cfg.n_prefix_tokens and "prefix_embeds" in batch:
        n = cfg.n_prefix_tokens
        x = jnp.concatenate([batch["prefix_embeds"].astype(cd), x[:, n:]], 1)
    B, S, _ = x.shape
    positions = jnp.arange(S)

    caches = None
    if cfg.family in ("dense", "vlm", "moe"):
        def body(h, p):
            hn = L.apply_norm(h, p["ln1"], cfg.norm)
            if cfg.use_mla:
                a, c = _deployed_mla_full(p["attn"], cfg, hn, positions,
                                          backend, build_cache=True,
                                          kv_spec=mla_spec)
            else:
                a, c = _deployed_attn_full(p["attn"], cfg, hn, positions,
                                           backend=backend, build_cache=True,
                                           kv_spec=gqa_spec)
            h = h + a.astype(h.dtype)
            f = _deployed_ffn_full(p["ffn"], cfg,
                                   L.apply_norm(h, p["ln2"], cfg.norm), backend)
            return h + f.astype(h.dtype), c
        x, caches = jax.lax.scan(body, x, dparams["blocks"])
    elif cfg.family == "ssm":
        def body(h, p):
            h2, st = _deployed_mamba_full(p, cfg, h, backend, lens)
            return h2, st
        x, caches = jax.lax.scan(body, x, dparams["blocks"])
    elif cfg.family == "hybrid":
        caches = {"ssm": [], "attn": []}
        Ltot, kk = cfg.n_layers, cfg.attn_every
        start = 0
        while start < Ltot:
            hn = L.apply_norm(x, dparams["shared_attn"]["ln1"], cfg.norm)
            a, c = _deployed_attn_full(dparams["shared_attn"]["attn"], cfg, hn,
                                       positions, backend=backend,
                                       build_cache=True, kv_spec=gqa_spec)
            x = x + a.astype(x.dtype)
            f = _deployed_ffn_full(
                dparams["shared_attn"]["ffn"], cfg,
                L.apply_norm(x, dparams["shared_attn"]["ln2"], cfg.norm),
                backend)
            x = x + f.astype(x.dtype)
            caches["attn"].append(c)
            stop = min(start + kk, Ltot)
            pg = jax.tree_util.tree_map(lambda t: t[start:stop],
                                        dparams["blocks"])
            def body(h, p):
                h2, st = _deployed_mamba_full(p, cfg, h, backend, lens)
                return h2, st
            x, st = jax.lax.scan(body, x, pg)
            caches["ssm"].append(st)
            start = stop
        caches["attn"] = jax.tree_util.tree_map(
            lambda *t: jnp.stack(t), *caches["attn"])
        caches["ssm"] = jax.tree_util.tree_map(
            lambda *t: jnp.concatenate(t), *caches["ssm"])

    x = L.apply_norm(x, dparams["ln_f"], cfg.norm)
    logits = dq_linear(_last_token(x, lens), dparams["lm_head"], cd, backend)
    return logits.astype(jnp.float32), caches


def _prefill_encdec(dparams, cfg, batch, backend, lens=None, kv_spec=None):
    cd = cfg.cdtype
    enc = batch["frames"].astype(cd)
    Se = enc.shape[1]
    enc = enc + L.sinusoidal_positions(Se, cfg.d_model).astype(cd)
    pos_e = jnp.arange(Se)

    def ebody(h, p):
        a, _ = _deployed_attn_full(p["attn"], cfg,
                                   L.apply_norm(h, p["ln1"], cfg.norm), pos_e,
                                   causal=False, backend=backend)
        h = h + a.astype(h.dtype)
        f = _deployed_ffn_full(p["mlp"], cfg,
                               L.apply_norm(h, p["ln2"], cfg.norm), backend)
        return h + f.astype(h.dtype), None
    enc, _ = jax.lax.scan(ebody, enc, dparams["enc_blocks"])
    enc = L.apply_norm(enc, dparams["enc_ln_f"], cfg.norm)

    x = dparams["embed"][batch["tokens"]].astype(cd)
    B, S, _ = x.shape
    x = x + L.sinusoidal_positions(S, cfg.d_model).astype(cd)
    pos = jnp.arange(S)

    def dbody(h, p):
        a, c = _deployed_attn_full(p["attn"], cfg,
                                   L.apply_norm(h, p["ln1"], cfg.norm), pos,
                                   backend=backend, build_cache=True,
                                   kv_spec=kv_spec)
        h = h + a.astype(h.dtype)
        xa, cc = _deployed_attn_full(p["xattn"], cfg,
                                     L.apply_norm(h, p["ln2"], cfg.norm), pos,
                                     enc=enc, backend=backend,
                                     build_cache=True, kv_spec=kv_spec)
        h = h + xa.astype(h.dtype)
        f = _deployed_ffn_full(p["mlp"], cfg,
                               L.apply_norm(h, p["ln3"], cfg.norm), backend)
        return h + f.astype(h.dtype), {"self": c, "cross": cc}
    x, caches = jax.lax.scan(dbody, x, dparams["dec_blocks"])
    x = L.apply_norm(x, dparams["ln_f"], cfg.norm)
    logits = dq_linear(_last_token(x, lens), dparams["lm_head"], cd, backend)
    return logits.astype(jnp.float32), caches


# ---------------------------------------------------------------------------
# Decode step (one new token, full KV cache) — the decode_* dry-run workload
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int, kv_bits=None):
    """Empty caches for decode-only dry-runs (shape stand-ins).

    ``kv_bits`` (see :func:`kv_specs`) swaps the ring leaves for the
    channel-wise packed layout — same tree structure, packed-byte dtypes.
    """
    gqa_spec, mla_spec = kv_specs(cfg, kv_bits)
    if cfg.family in ("dense", "vlm", "moe"):
        one = (attn.init_mla_cache(cfg, batch, max_len, mla_spec)
               if cfg.use_mla
               else attn.init_gqa_cache(cfg, batch, max_len, gqa_spec))
        return jax.tree_util.tree_map(
            lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), one)
    if cfg.family == "ssm":
        one = ssm_mod.init_ssm_cache(cfg, batch)
        return jax.tree_util.tree_map(
            lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), one)
    if cfg.family == "hybrid":
        ssm_one = ssm_mod.init_ssm_cache(cfg, batch)
        attn_one = attn.init_gqa_cache(cfg, batch, max_len, gqa_spec)
        n_groups = -(-cfg.n_layers // cfg.attn_every)
        return {
            "ssm": jax.tree_util.tree_map(
                lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), ssm_one),
            "attn": jax.tree_util.tree_map(
                lambda t: jnp.zeros((n_groups,) + t.shape, t.dtype), attn_one),
        }
    if cfg.family == "audio":
        self_c = attn.init_gqa_cache(cfg, batch, max_len, gqa_spec)
        cross_c = attn.init_gqa_cache(cfg, batch, cfg.encoder_seq, gqa_spec)
        # Zero-scale decode-only contract: this cross cache ships all-zero
        # int8 values AND all-zero per-token scales, so the dequantized
        # encoder KV is exactly 0 and cross-attention softmaxes to uniform
        # weights over encoder positions — a shape stand-in for decode-only
        # dry-runs, never a real serving state.  Real generation embeds the
        # prefill's encoder-built cross cache over these zeros
        # (embed_caches / merge_paged_caches).
        return jax.tree_util.tree_map(
            lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype),
            {"self": self_c, "cross": cross_c})
    raise ValueError(cfg.family)


def supports_paging(cfg) -> bool:
    """Whether the family has a ring axis the paged KV cache can page.

    ``ssm`` is pure recurrent state (no per-token ring), so the engine
    silently serves it dense; ``hybrid`` pages only its attention subtree
    and ``audio`` only the decoder self-attention ring (the cross cache is
    encoder-length per slot, written once at admission).
    """
    return cfg.family in ("dense", "vlm", "moe", "hybrid", "audio")


def supports_speculative(cfg) -> bool:
    """Whether the family can run as a speculative draft/verifier.

    Needs (a) a multi-token verify path — attention rings rewind for free
    (rejected entries stay masked by ``<= pos`` until overwritten) but
    recurrent SSM state cannot un-apply a token, ruling out ``ssm`` and
    ``hybrid`` — and (b) a decode path that is width-generic (``audio``'s
    cross-attention decode is hardcoded to one query token).
    """
    return cfg.family in ("dense", "vlm", "moe")


def draft_model(dparams, cfg, draft_bits: int):
    """Derive a low-bit draft policy from a deployed verifier param tree.

    Every :class:`QTensor` leaf is re-quantized to a uniform ``draft_bits``
    channel assignment (api/qtensor.requantize) — the one-checkpoint-many-
    precisions trick: the aggressive end of the paper's channel-wise Pareto
    front drafts, the searched 8-bit deploy verifies.  Non-QTensor leaves
    (the embedding / lm_head table, norms, biases) are shared **by
    reference** with the verifier tree, so the draft costs only the packed
    low-bit linears.
    """
    from repro.api.qtensor import requantize
    if not supports_speculative(cfg):
        raise ValueError(
            f"family {cfg.family!r} cannot draft (see supports_speculative)")
    return jax.tree_util.tree_map(
        lambda leaf: (requantize(leaf, draft_bits)
                      if isinstance(leaf, QTensor) else leaf),
        dparams, is_leaf=lambda leaf: isinstance(leaf, QTensor))


def init_paged_caches(cfg, max_slots: int, num_pages: int, page_size: int,
                      kv_bits=None):
    """Paged serving caches: ring leaves become physical page pools.

    Each paged leaf swaps its per-slot ``(max_slots, .., max_len, F)`` ring
    for ``(num_pages, .., page_size, F)`` — same tree structure as
    :func:`init_caches`, so the decode scan is unchanged; only the batch
    axis meaning differs (physical pages indexed through the scheduler's
    page table instead of slots).  Page 0 is the NULL page: never written,
    always zero (repro/cache).  Non-ring leaves (hybrid SSM state, audio
    cross caches) keep their per-slot layout.

    ``kv_bits`` packs the page pools channel-wise (:func:`kv_specs`): the
    packing is feature-axis only, so a page boundary never splits a packed
    byte and the page-table machinery is unchanged — pages just carry fewer
    bytes per token.
    """
    gqa_spec, mla_spec = kv_specs(cfg, kv_bits)
    stackN = lambda one, n: jax.tree_util.tree_map(
        lambda t: jnp.zeros((n,) + t.shape, t.dtype), one)
    if cfg.family in ("dense", "vlm", "moe"):
        one = (attn.init_mla_cache(cfg, num_pages, page_size, mla_spec)
               if cfg.use_mla
               else attn.init_gqa_cache(cfg, num_pages, page_size, gqa_spec))
        return stackN(one, cfg.n_layers)
    if cfg.family == "hybrid":
        n_groups = -(-cfg.n_layers // cfg.attn_every)
        return {
            "ssm": stackN(ssm_mod.init_ssm_cache(cfg, max_slots),
                          cfg.n_layers),
            "attn": stackN(attn.init_gqa_cache(cfg, num_pages, page_size,
                                               gqa_spec),
                           n_groups),
        }
    if cfg.family == "audio":
        # cross keeps the zero-scale stand-in contract of init_caches; real
        # serving admit-merges the prefill's encoder-built cross cache in.
        return stackN({"self": attn.init_gqa_cache(cfg, num_pages, page_size,
                                                   gqa_spec),
                       "cross": attn.init_gqa_cache(cfg, max_slots,
                                                    cfg.encoder_seq,
                                                    gqa_spec)},
                      cfg.n_layers)
    raise ValueError(f"family {cfg.family!r} has no paged cache layout "
                     "(see supports_paging)")


def paged_leaf_mask(cfg):
    """Bool tree over the serving cache structure: True = page-pool leaf
    (indexed through the page table), False = per-slot leaf (admit-merged
    and decoded exactly as in the dense engine)."""
    tmap = jax.tree_util.tree_map
    if cfg.family in ("dense", "vlm", "moe"):
        one = (attn.init_mla_cache(cfg, 1, 1) if cfg.use_mla
               else attn.init_gqa_cache(cfg, 1, 1))
        return tmap(lambda t: True, one)
    if cfg.family == "hybrid":
        return {"ssm": tmap(lambda t: False, ssm_mod.init_ssm_cache(cfg, 1)),
                "attn": tmap(lambda t: True, attn.init_gqa_cache(cfg, 1, 1))}
    if cfg.family == "audio":
        one = attn.init_gqa_cache(cfg, 1, 1)
        return {"self": tmap(lambda t: True, one),
                "cross": tmap(lambda t: False, one)}
    raise ValueError(f"family {cfg.family!r} has no paged cache layout "
                     "(see supports_paging)")


def merge_paged_caches(cfg, prefill_caches, caches, admit, wp_flat):
    """Admit a prefill into the paged caches — the paged counterpart of
    ``embed_caches`` + where-merge in the dense engine.

    Page-pool leaves scatter whole prompt pages through ``wp_flat (B *
    n_pp,)`` (``cache.paged.scatter_prefill``): non-admitted slots, junk
    tails past short prompts and prefix-shared (read-only) pages carry the
    out-of-bounds sentinel and are dropped.  Per-slot leaves (hybrid SSM
    state, audio cross) right-pad to the ring shape and where-merge on
    ``admit (B,) bool`` exactly as the dense engine does, preserving
    non-admitted slots bit-for-bit.
    """
    def one(m, pc, full):
        if m:
            return paged.scatter_prefill(full, pc, wp_flat)
        if pc.shape != full.shape:
            diff = [i for i, (a, b) in enumerate(zip(pc.shape, full.shape))
                    if a != b]
            assert len(diff) == 1, (pc.shape, full.shape)
            widths = [(0, 0)] * pc.ndim
            widths[diff[0]] = (0, full.shape[diff[0]] - pc.shape[diff[0]])
            pc = jnp.pad(pc, widths)
        sel = admit.reshape((1, -1) + (1,) * (pc.ndim - 2))
        return jnp.where(sel, pc.astype(full.dtype), full)
    return jax.tree_util.tree_map(one, paged_leaf_mask(cfg),
                                  prefill_caches, caches)


def embed_caches(prefill_caches, ring):
    """Right-pad the S-deep prefill caches into the max_len ring.

    Each leaf differs from its ring counterpart in at most the sequence
    axis; zero-padding IS the empty-slot convention (decode masks by
    position), so generation really attends to the prompt.  One embedding
    rule shared by the request-level scheduler's dense mode
    (api/scheduler.py) and the lockstep oracle loops over
    ``engine.serving_jits`` (paged engines merge via
    :func:`merge_paged_caches` instead).
    """
    def one(pc, full):
        if pc.shape == full.shape:
            return pc.astype(full.dtype)
        diff = [i for i, (a, b) in enumerate(zip(pc.shape, full.shape))
                if a != b]
        assert len(diff) == 1, (pc.shape, full.shape)
        widths = [(0, 0)] * pc.ndim
        widths[diff[0]] = (0, full.shape[diff[0]] - pc.shape[diff[0]])
        return jnp.pad(pc, widths).astype(full.dtype)
    return jax.tree_util.tree_map(one, prefill_caches, ring)


def _cross_decode(p, cfg, x, cache, backend, kv_spec=None):
    """Cross-attention decode: query new token against the cached encoder KV.

    Query heads fold to ``(B, KV, rep, hd)`` groups so the encoder KV stays
    at its ``KV`` kv-heads inside the einsums — no ``jnp.repeat`` ever
    materializes the ``rep``-fold redundant f32 encoder tensors (the head
    broadcast happens in the contraction).  Under ``kv_spec`` the encoder
    rings are channel-wise packed; zero codes dequantize to exact 0.0 under
    any scale, so the decode-only zero-scale cross-cache stand-in (all-zero
    packed bytes AND zero scales — see :func:`init_caches`) is preserved
    exactly on the packed path too.
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = H // KV
    cd = cfg.cdtype
    dq = _dq(cd, backend)
    # (B, H, hd) is head-major, so the group fold/unfold is a pure reshape
    qg = dq(x, p["wq"]).reshape(B, H, hd).reshape(B, KV, rep, hd)
    if kv_spec is None:
        kf = (cache["k"].astype(jnp.float32) * cache["k_scale"]).astype(cd)
        vf = (cache["v"].astype(jnp.float32) * cache["v_scale"]).astype(cd)
    else:
        kf = kvq.dequant_channelwise(cache["k"], cache["k_scale"], kv_spec, cd)
        vf = kvq.dequant_channelwise(cache["v"], cache["v_scale"], kv_spec, cd)
    s = jnp.einsum("bgrd,bgkd->bgrk", qg, kf).astype(jnp.float32)
    s = s / np.sqrt(hd)
    w = jax.nn.softmax(s, axis=-1).astype(cd)
    o = jnp.einsum("bgrk,bgkd->bgrd", w, vf)
    return dq(o.reshape(B, 1, H * hd), p["wo"])


def decode_step(dparams, cfg, tokens, caches, pos, backend: str = "jnp",
                live=None, pages=None, page_size=None, kv_bits=None):
    """One decode step: tokens (B, W) -> (logits (B, W, V), caches').

    ``W`` is normally 1.  ``W > 1`` is the speculative **verify** launch:
    row ``b``'s token ``j`` is scored at position ``pos[b] + j`` (the
    attention multi-token path writes all W KV entries in one scatter and
    masks per step — see models/attention._gqa_decode_multi), supported for
    the ``dense``/``vlm``/``moe`` families only (:func:`supports_speculative`).

    ``pos`` is a **per-slot position vector** (B,) int32: row ``b`` writes
    its new cache entry at its own ring index ``pos[b]`` and attends to
    ``<= pos[b]`` — independently-progressed requests (continuous
    batching, api/scheduler.py) decode in ONE fixed-width launch.  A
    scalar ``pos`` is accepted for migration and broadcasts to the
    all-slots-synchronized vector (see docs/serving.md).

    ``live``: optional (B,) bool slot mask — rows with ``live=False``
    (freed slots awaiting re-admission) leave every cache untouched:
    attention/MLA ring writes are dropped and SSM state updates are
    slot-masked.  Their logits row is garbage and must be ignored.

    ``pages``: optional (B, P) int32 page table — ``caches`` then hold the
    paged layout of :func:`init_paged_caches` (``P * page_size ==
    max_len``) and every ring read/write routes through the table; the
    gathered per-slot view is exactly the dense ring, so logits are
    bit-identical to the dense path.  Non-ring leaves ignore the table.

    ``kv_bits``: cache quantization policy (:func:`kv_specs`); must match
    the policy the caches were built with.  Under ``backend="pallas"`` the
    packed GQA rings decode through the fused dequant decode-attention
    kernel (kernels/decode_attention.py).
    """
    gqa_spec, mla_spec = kv_specs(cfg, kv_bits)
    cd = cfg.cdtype
    dq = _dq(cd, backend)
    x = dparams["embed"][tokens].astype(cd)
    B = tokens.shape[0]
    if tokens.shape[1] > 1 and not supports_speculative(cfg):
        raise ValueError(
            f"family {cfg.family!r} has no multi-token verify path "
            "(see supports_speculative)")
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:                 # legacy scalar: all slots synchronized
        pos = jnp.broadcast_to(pos[None], (B,))

    if cfg.family in ("dense", "vlm", "moe"):
        def body(h, pc):
            p, c = pc
            hn = L.apply_norm(h, p["ln1"], cfg.norm)
            if cfg.use_mla:
                a, c2 = attn.mla_decode(p["attn"], cfg, hn, c, pos, dq, live,
                                        pages, page_size, mla_spec)
            else:
                a, c2 = attn.gqa_decode(p["attn"], cfg, hn, c, pos, dq, live,
                                        pages, page_size, gqa_spec, backend)
            h = h + a.astype(h.dtype)
            f = _deployed_ffn_full(p["ffn"], cfg,
                                   L.apply_norm(h, p["ln2"], cfg.norm), backend)
            return h + f.astype(h.dtype), c2
        x, caches = jax.lax.scan(body, x, (dparams["blocks"], caches))
    elif cfg.family == "ssm":
        def body(h, pc):
            p, c = pc
            hn = L.apply_norm(h, p["ln"], cfg.norm)
            y, c2 = ssm_mod.mamba2_decode(p, cfg, hn, c, dq, live)
            return h + y.astype(h.dtype), c2
        x, caches = jax.lax.scan(body, x, (dparams["blocks"], caches))
    elif cfg.family == "hybrid":
        Ltot, kk = cfg.n_layers, cfg.attn_every
        new_attn, new_ssm = [], []
        start, g = 0, 0
        while start < Ltot:
            c_att = jax.tree_util.tree_map(lambda t: t[g], caches["attn"])
            hn = L.apply_norm(x, dparams["shared_attn"]["ln1"], cfg.norm)
            a, c2 = attn.gqa_decode(dparams["shared_attn"]["attn"], cfg,
                                    hn, c_att, pos, dq, live, pages,
                                    page_size, gqa_spec, backend)
            x = x + a.astype(x.dtype)
            f = _deployed_ffn_full(
                dparams["shared_attn"]["ffn"], cfg,
                L.apply_norm(x, dparams["shared_attn"]["ln2"], cfg.norm),
                backend)
            x = x + f.astype(x.dtype)
            new_attn.append(c2)
            stop = min(start + kk, Ltot)
            pg = jax.tree_util.tree_map(lambda t: t[start:stop],
                                        dparams["blocks"])
            cg = jax.tree_util.tree_map(lambda t: t[start:stop], caches["ssm"])
            def body(h, pc):
                p, c = pc
                hn2 = L.apply_norm(h, p["ln"], cfg.norm)
                y, cn = ssm_mod.mamba2_decode(p, cfg, hn2, c, dq, live)
                return h + y.astype(h.dtype), cn
            x, cs = jax.lax.scan(body, x, (pg, cg))
            new_ssm.append(cs)
            start, g = stop, g + 1
        caches = {
            "attn": jax.tree_util.tree_map(lambda *t: jnp.stack(t), *new_attn),
            "ssm": jax.tree_util.tree_map(lambda *t: jnp.concatenate(t),
                                          *new_ssm),
        }
    elif cfg.family == "audio":
        def body(h, pc):
            p, c = pc
            hn = L.apply_norm(h, p["ln1"], cfg.norm)
            a, c2 = attn.gqa_decode(p["attn"], cfg, hn, c["self"], pos, dq,
                                    live, pages, page_size, gqa_spec, backend)
            h = h + a.astype(h.dtype)
            xa = _cross_decode(p["xattn"], cfg,
                               L.apply_norm(h, p["ln2"], cfg.norm), c["cross"],
                               backend, gqa_spec)
            h = h + xa.astype(h.dtype)
            f = _deployed_ffn_full(p["mlp"], cfg,
                                   L.apply_norm(h, p["ln3"], cfg.norm), backend)
            return h + f.astype(h.dtype), {"self": c2, "cross": c["cross"]}
        x, caches = jax.lax.scan(body, x, (dparams["dec_blocks"], caches))

    x = L.apply_norm(x, dparams["ln_f"], cfg.norm)
    logits = dq_linear(x, dparams["lm_head"], cd, backend)
    return logits.astype(jnp.float32), caches
