"""Quantization-aware neural-net primitives (pure functional JAX).

Every weight that the paper's method searches over goes through
:func:`qlinear` / :func:`qconv2d` — the single entry points of the
``repro.api`` surface.  They dispatch on a typed
:class:`repro.api.PrecisionPolicy` (never a string) **and** on the weight
leaf's type:

  PrecisionPolicy.FLOAT          — no quantization (reference / baseline)
  PrecisionPolicy.QAT8           — fixed 8-bit PACT QAT (warmup, Alg. 1 l.1-2)
  PrecisionPolicy.search(tau)    — DNAS mixture, Eq. 4-6 (search phase)
  PrecisionPolicy.FROZEN         — argmax assignment (fine-tuning phase)
  PrecisionPolicy.deployed(bk)   — true-integer packed weights; the weight
                                   leaf is a :class:`repro.api.QTensor`:
                                   ``bk="pallas"`` serves the whole mixed-
                                   precision weight as ONE fused kernel
                                   launch (tile-aligned deploy),
                                   ``bk="pallas-pergroup"`` keeps one
                                   sub-GEMM launch per precision group
                                   (kernels/quant_matmul)

The NAS state for a layer-site is a dict {"gamma","delta"}; the quantizer
clips live in the *params* tree ({"aw","ax"}) because they train with W, not
with theta (PACT clips are weights as far as Alg. 1 is concerned).

Weights are stored ``(c_out, c_in[, ...])`` — axis 0 is the channel axis the
paper assigns precision to.  Matmuls use einsum '...i,oi->...o' so no
transposes materialize.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.api.policy import Phase, PrecisionPolicy
from repro.api.qtensor import QTensor
from repro.core import mixedprec as mp
from repro.core import quantizers as qz
from repro.kernels import quant_conv as qc_kernel
from repro.qtrain import linear as qt_linear


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def linear_init(key, c_in: int, c_out: int, dtype=jnp.float32,
                bias: bool = False, scale: Optional[float] = None) -> dict:
    w = jax.random.normal(key, (c_out, c_in), dtype=jnp.float32)
    w = w * (scale if scale is not None else (1.0 / math.sqrt(c_in)))
    p = {"w": w.astype(dtype), "aw": qz.init_weight_alpha(w),
         "ax": qz.init_act_alpha()}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def conv2d_init(key, c_in: int, c_out: int, kh: int, kw: int,
                dtype=jnp.float32, bias: bool = True, groups: int = 1) -> dict:
    fan_in = c_in // groups * kh * kw
    w = jax.random.normal(key, (c_out, c_in // groups, kh, kw)) / math.sqrt(fan_in)
    p = {"w": w.astype(dtype), "aw": qz.init_weight_alpha(w),
         "ax": qz.init_act_alpha()}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def nas_init(key, c_out: int, qcfg: mp.MixedPrecConfig) -> dict:
    return mp.init_nas_params(key, c_out, qcfg)


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def norm_init(d: int, kind: str = "rmsnorm", dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# Quantization-aware apply
# ---------------------------------------------------------------------------

def _quant_pair(x, w, p, nas, policy: PrecisionPolicy,
                qcfg: mp.MixedPrecConfig, signed_act: bool):
    """Return (x', w') after policy-appropriate fake quantization."""
    if policy.phase is Phase.FLOAT:
        return x, w
    aw = p["aw"].reshape((w.shape[0],) + (1,) * (w.ndim - 1))
    ax = p["ax"]
    if policy.phase is Phase.QAT8:
        return (qz.quantize_act_any(x, ax, 8, signed_act),
                qz.quantize_weight(w, aw, 8))
    if policy.phase is Phase.SEARCH:
        return (mp.effective_act(x, nas["delta"], ax, policy.tau, qcfg,
                                 signed_act),
                mp.effective_weight(w, nas["gamma"], p["aw"], policy.tau,
                                    qcfg))
    if policy.phase is Phase.FROZEN:
        return (mp.frozen_act(x, nas["delta"], ax, qcfg, signed_act),
                mp.frozen_weight(w, nas["gamma"], p["aw"], qcfg))
    raise ValueError(f"unhandled policy {policy!r}")


def deployed_act(x: jnp.ndarray, qt: QTensor, signed: bool) -> jnp.ndarray:
    """Layer-wise activation quantization of the deployed path.

    ``qt.act_scale`` stores the *unsigned* step ``alpha_x / (2^b - 1)``
    (core/deploy.py), so the learned PACT clip is recovered as
    ``act_scale * levels`` and the signed/unsigned step fall out of the
    quantizer itself — numerically identical to the fine-tune phase's
    ``frozen_act`` with the same argmaxed delta, for either signedness."""
    alpha = jnp.asarray(qt.act_scale * ((1 << qt.act_bits) - 1))
    return qz.quantize_act_any(x, alpha, qt.act_bits, signed)


def partial_dtype_of(cfg):
    """preferred_element_type for TP-sharded dots, from ArchConfig."""
    pd = getattr(cfg, "partial_dtype", "")
    return jnp.dtype(pd) if pd else None


def _site_key(policy: PrecisionPolicy, w: jnp.ndarray):
    """Per-site stochastic-rounding key: the policy's key folded by a salt
    from the weight geometry, so same-step sites of different shape draw
    independent rounding noise even when the caller does not fan out
    per-layer keys (transformer scans do; see ``_layer_keys``)."""
    if policy.sr_key is None:
        return None
    salt = (w.shape[0] * 1000003 + w.shape[-1]) & 0x7FFFFFFF
    return jax.random.fold_in(policy.sr_key, salt)


def qlinear(x: jnp.ndarray, p: dict, nas: Optional[dict],
            policy: PrecisionPolicy, qcfg: mp.MixedPrecConfig,
            signed_act: bool = True, compute_dtype=None,
            partial_dtype=None) -> jnp.ndarray:
    """Quantization-aware linear: x (..., c_in) @ w (c_out, c_in)^T.

    The single linear entry point for every phase: when the weight leaf is a
    :class:`QTensor` (``policy`` DEPLOYED), the packed weight runs through
    ``QTensor.matmul`` — one fused multi-precision kernel launch
    (``policy.backend == "pallas"`` on a tile-aligned deploy), per-group
    sub-GEMM launches (``"pallas-pergroup"``) or the jnp fallback;
    otherwise the float master weight is fake-quantized per the policy.

    ``partial_dtype`` sets the dot's preferred_element_type: with bf16 the
    TP partial sums cross the ICI at half width (collective compression —
    §Perf knob; default keeps the backend's f32 accumulation).

    ``policy.train_compute`` selects the training arithmetic after fake
    quantization: ``"f32"`` is this function's legacy body unchanged,
    ``"bf16"`` forces bf16 operands (f32 accumulation), ``"int8"`` routes
    the matmul — forward AND both backward GEMMs — through
    :func:`repro.qtrain.int8_linear` (dynamic int8 with stochastic-rounded
    backward when ``policy.sr_key`` is set).
    """
    w = p["w"]
    if isinstance(w, QTensor):
        xq = deployed_act(x, w, signed_act)
        y = w.matmul(xq, compute_dtype or jnp.float32, policy.backend)
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y
    if policy.phase is Phase.DEPLOYED:
        raise TypeError("DEPLOYED policy requires a QTensor weight leaf "
                        "(run engine.deploy / core.deploy.deploy_linear)")
    x, w = _quant_pair(x, w, p, nas, policy, qcfg, signed_act)
    if policy.train_compute == "int8":
        y = qt_linear.int8_linear(x, w, _site_key(policy, w),
                                  qt_linear.DEFAULT)
        if compute_dtype is not None:
            y = y.astype(compute_dtype)
    elif policy.train_compute == "bf16":
        xb, wb = x.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
        y = jnp.einsum("...i,oi->...o", xb, wb,
                       preferred_element_type=partial_dtype or jnp.float32)
        if compute_dtype is not None:
            y = y.astype(compute_dtype)
    else:
        if compute_dtype is not None:
            x, w = x.astype(compute_dtype), w.astype(compute_dtype)
        if partial_dtype is not None:
            y = jnp.einsum("...i,oi->...o", x, w,
                           preferred_element_type=partial_dtype)
        else:
            y = jnp.einsum("...i,oi->...o", x, w)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def qconv2d(x: jnp.ndarray, p: dict, nas: Optional[dict],
            policy: PrecisionPolicy, qcfg: mp.MixedPrecConfig,
            stride: int = 1, padding: str = "SAME",
            groups: int = 1, signed_act: bool = False) -> jnp.ndarray:
    """Quantization-aware NHWC conv with (c_out, c_in/g, kh, kw) weights.

    ``signed_act=False`` matches the paper's post-ReLU unsigned activations.
    A QTensor weight (deployed phase) runs fully packed as an im2col
    patch-GEMM: one fused multi-precision kernel launch for all groups
    (``policy.backend == "pallas"`` on a tile-aligned deploy), per-group
    launches (``"pallas-pergroup"``) or the jnp fallback —
    ``QTensor.conv2d`` owns the routing, and no dense float kernel is ever
    materialized (depthwise convs use its grouped per-channel path).
    """
    w = p["w"]
    if isinstance(w, QTensor):
        xq = deployed_act(x, w, signed_act)
        y = w.conv2d(xq, stride=stride, padding=padding, groups=groups,
                     compute_dtype=jnp.float32, backend=policy.backend)
        if "b" in p:
            y = y + p["b"]
        return y
    if policy.phase is Phase.DEPLOYED:
        raise TypeError("DEPLOYED policy requires a QTensor weight leaf")
    x, w = _quant_pair(x, w, p, nas, policy, qcfg, signed_act)
    if policy.train_compute == "int8" and groups == 1:
        # im2col (differentiable) + the int8 custom_vjp patch-GEMM: the
        # same channel-major lowering the deployed path uses, so the
        # contraction axis is C*kh*kw and grads flow back through the
        # patch extraction.  Depthwise (groups>1) convs contract only
        # kh*kw<=9 values per output — too narrow to win anything from
        # int8 — and fall through to the float path below.
        patches = qc_kernel.im2col(x, w.shape[2], w.shape[3], stride,
                                   padding)
        y = qt_linear.int8_linear(patches, w.reshape(w.shape[0], -1),
                                  _site_key(policy, w), qt_linear.DEFAULT)
    else:
        if policy.train_compute == "bf16":
            x, w = x.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
        # lax wants (kh, kw, c_in/g, c_out) for NHWC/HWIO
        kernel = jnp.transpose(w, (2, 3, 1, 0))
        y = jax.lax.conv_general_dilated(
            x, kernel, window_strides=(stride, stride), padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
        if policy.train_compute == "bf16":
            y = y.astype(jnp.float32)
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms / activations / positional encodings (float — the paper leaves
# normalization and elementwise ops unquantized)
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, p: dict, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x: jnp.ndarray, p: dict, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p.get(
        "bias", jnp.zeros((), jnp.float32)).astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x, p, kind: str):
    return rmsnorm(x, p) if kind == "rmsnorm" else layernorm(x, p)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate) * up


def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray,
               partial: float = 1.0) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """cos/sin tables for (possibly partial) RoPE.

    ``partial`` < 1 rotates only the first ``int(head_dim*partial)`` dims
    (chatglm3's 2D-RoPE applies rotation to half the dims; the rest pass
    through).  Returns (cos, sin, rot_dim).
    """
    rot = int(head_dim * partial)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, rot/2)
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               rot: int) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); cos/sin: (S, rot/2) or broadcastable."""
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    # cos/sin broadcast over head axis: (S, 1, rot/2)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1) if xp.shape[-1] else out


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (S, d)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# KV-cache quantization (layer-wise int8 — the paper's layer-wise activation
# scheme applied to the cache; DESIGN.md §2)
# ---------------------------------------------------------------------------

def quantize_kv(kv: jnp.ndarray, bits: int = 8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-(layerwise)-tensor quantization of new KV entries.

    Scale is computed per (batch, head) slice over the last two dims to keep
    the reduction cheap; returns (int8 values, float scale broadcastable)."""
    amax = jnp.max(jnp.abs(kv), axis=(-2, -1), keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(kv / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)
