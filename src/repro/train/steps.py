"""Step factories: the production train_step / serve_step per architecture.

``train_step`` is the paper's **search-phase W update** (Alg. 1 line 7 — the
80% path that dominates wall time): forward under ``PrecisionPolicy.search``
(DNAS mixture of fake-quantized weights/activations), next-token CE, AdamW
update.  The theta
update (line 5) is built by ``make_theta_step`` and uses the Eq. 7/8
regularizer; the launcher alternates them 20/80 like Alg. 1.

Distribution: pure pjit — the step is jitted with in_shardings derived from
dist/sharding.py rules; donate_argnums recycles the state buffers.

State pytree:
    {"params": ..., "nas": ..., "opt_w": ..., "opt_t": ..., "tau": scalar,
     "step": scalar}
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.api.policy import PrecisionPolicy
from repro.core import regularizers as reg
from repro.models import transformer as tfm
from repro.optim import optimizers as opt_mod


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    lr_theta: float = 1e-2
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    lam: float = 1e-12            # Eq. 2 regularization strength
    objective: str = "size"       # Eq. 7 ("size") or Eq. 8 ("energy")
    lut_name: str = "tpu_bw"
    schedule: str = "cosine"      # cosine | wsd | constant
    optimizer: str = "adamw"      # adamw | adafactor (factored, 100B+ configs)
    opt_state_dtype: str = "bfloat16"   # compressed optimizer moments
    mtp_weight: float = 0.3
    remat: bool = True
    train_compute: str = "f32"    # matmul arithmetic: f32 | bf16 | int8
    sr_seed: int = 0              # int8 stochastic-rounding base seed

    @classmethod
    def for_arch(cls, cfg, **overrides) -> "TrainHParams":
        """Per-arch system defaults (optimizer/schedule) from the config."""
        kw = dict(optimizer=getattr(cfg, "optimizer", "adamw"),
                  schedule=getattr(cfg, "lr_schedule", "cosine"))
        kw.update(overrides)
        return cls(**kw)


def make_optimizers(hp: TrainHParams):
    if hp.schedule == "wsd":
        sched = opt_mod.wsd_schedule(hp.lr, hp.warmup_steps,
                                     int(hp.total_steps * 0.8),
                                     int(hp.total_steps * 0.2) or 1)
    elif hp.schedule == "cosine":
        sched = opt_mod.cosine_schedule(hp.lr, hp.warmup_steps,
                                        hp.total_steps)
    else:
        sched = opt_mod.constant_schedule(hp.lr)
    if hp.optimizer == "adafactor":
        opt_w = opt_mod.Adafactor(schedule=sched,
                                  weight_decay=hp.weight_decay,
                                  state_dtype=jnp.dtype(hp.opt_state_dtype))
    else:
        opt_w = opt_mod.AdamW(schedule=sched, weight_decay=hp.weight_decay,
                              clip_norm=hp.clip_norm,
                              state_dtype=jnp.dtype(hp.opt_state_dtype))
    opt_t = opt_mod.AdamW(schedule=opt_mod.constant_schedule(hp.lr_theta),
                          clip_norm=None,
                          state_dtype=jnp.dtype(hp.opt_state_dtype))
    return opt_w, opt_t


def init_train_state(cfg, hp: TrainHParams, key) -> dict:
    params, nas = tfm.init_model(cfg, key)
    opt_w, opt_t = make_optimizers(hp)
    return {
        "params": params,
        "nas": nas,
        "opt_w": opt_w.init(params),
        "opt_t": opt_t.init(nas),
        "tau": jnp.asarray(cfg.quant.tau0, jnp.float32),
        "step": jnp.zeros((), jnp.int32),
    }


def _train_policy(hp: TrainHParams, base: PrecisionPolicy, step):
    """Attach the hparams' compute axis to a phase policy.

    ``train_compute="f32"`` returns ``base`` untouched — the step traces to
    byte-for-byte the pre-compute-axis jaxpr (the bit-identity contract).
    int8 derives a fresh stochastic-rounding key from (sr_seed, step) so
    rounding noise decorrelates across steps without retracing.
    """
    if hp.train_compute == "f32":
        return base
    sr_key = None
    if hp.train_compute == "int8":
        sr_key = jax.random.fold_in(jax.random.PRNGKey(hp.sr_seed), step)
    return base.with_train_compute(hp.train_compute, sr_key)


def _task_loss(cfg, hp, params, nas, policy, batch):
    if cfg.mtp:
        logits, mtp_logits = tfm.forward_with_mtp(params, nas, cfg,
                                                  batch, policy, hp.remat)
        loss = tfm.lm_loss(logits, batch)
        if mtp_logits is not None:
            # next-next-token targets: shift labels by one more
            mtp_batch = {"labels": jnp.roll(batch["labels"], -1, axis=1),
                         "mask": jnp.ones_like(batch["labels"],
                                               jnp.float32).at[:, -1].set(0)}
            loss = loss + hp.mtp_weight * tfm.lm_loss(mtp_logits, mtp_batch)
        return loss
    logits = tfm.forward(params, nas, cfg, batch, policy, hp.remat)
    return tfm.lm_loss(logits, batch)


def make_train_step(cfg, hp: TrainHParams) -> Callable:
    """W-update search step (the dominant workload — dry-run target)."""
    opt_w, _ = make_optimizers(hp)

    def train_step(state, batch):
        pol = _train_policy(hp, PrecisionPolicy.search(state["tau"]),
                            state["step"])

        def loss_fn(params):
            return _task_loss(cfg, hp, params, state["nas"], pol, batch)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        updates, new_opt = opt_w.update(grads, state["opt_w"],
                                        state["params"], state["step"])
        new_params = opt_mod.apply_updates(state["params"], updates)
        return {
            "params": new_params,
            "nas": state["nas"],
            "opt_w": new_opt,
            "opt_t": state["opt_t"],
            "tau": state["tau"],
            "step": state["step"] + 1,
        }, {"loss": loss}

    return train_step


def make_theta_step(cfg, hp: TrainHParams, tokens_per_batch: int) -> Callable:
    """theta-update step: L_T + lambda * L_R(theta) (Alg. 1 line 5)."""
    _, opt_t = make_optimizers(hp)
    specs = tfm.cost_specs(cfg, tokens_per_batch)

    def theta_step(state, batch):
        pol = _train_policy(hp, PrecisionPolicy.search(state["tau"]),
                            state["step"])

        def loss_fn(nas):
            lt = _task_loss(cfg, hp, state["params"], nas, pol, batch)
            flat = tfm.flatten_nas(nas)
            lr_cost = reg.total_cost(flat, state["tau"], specs, cfg.quant,
                                     hp.objective, hp.lut_name)
            return lt + hp.lam * lr_cost, (lt, lr_cost)
        (loss, (lt, lr_cost)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["nas"])
        updates, new_opt = opt_t.update(grads, state["opt_t"], state["nas"],
                                        state["step"])
        new_nas = opt_mod.apply_updates(state["nas"], updates)
        return {
            "params": state["params"],
            "nas": new_nas,
            "opt_w": state["opt_w"],
            "opt_t": new_opt,
            "tau": state["tau"],
            "step": state["step"] + 1,
        }, {"loss": lt, "reg_cost": lr_cost}

    return theta_step


def make_qat_warmup_step(cfg, hp: TrainHParams) -> Callable:
    """Alg. 1 warmup: QAT @ 8b, NAS frozen."""
    opt_w, _ = make_optimizers(hp)

    def warmup_step(state, batch):
        pol = _train_policy(hp, PrecisionPolicy.QAT8, state["step"])

        def loss_fn(params):
            return _task_loss(cfg, hp, params, None, pol, batch)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        updates, new_opt = opt_w.update(grads, state["opt_w"],
                                        state["params"], state["step"])
        return {**state, "params": opt_mod.apply_updates(state["params"],
                                                         updates),
                "opt_w": new_opt, "step": state["step"] + 1}, {"loss": loss}

    return warmup_step


def anneal_epoch(state, cfg) -> dict:
    """End-of-epoch tau annealing (Alg. 1 line 8)."""
    from repro.core import mixedprec as mp
    return {**state, "tau": mp.anneal_tau(state["tau"], cfg.quant)}
