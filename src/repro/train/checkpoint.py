"""Fault-tolerant sharded checkpointing (no tensorstore in this env).

Layout:  <dir>/step_<N>/
            manifest.json       — step, config hash, mesh shape, leaf index,
                                  pipeline state, wall time
            shard_<host>.npz    — this host's leaf shards (here: all leaves;
                                  on a real multi-host pod each host saves
                                  only its addressable shards)

Durability protocol:
  * writes go to ``step_<N>.tmp`` then ``os.rename`` to ``step_<N>`` —
    atomic commit, a crash mid-save never corrupts the latest checkpoint;
  * ``latest_step()`` scans for the newest *committed* directory and
    validates the manifest, so restart always finds a consistent state;
  * saves can run on a background thread (async checkpointing overlaps the
    serialization with the next training steps — the standard trick for
    minimizing checkpoint stalls at scale);
  * ``keep`` bounds disk usage (old steps garbage-collected after commit).

Restore reshards automatically: leaves are loaded host-locally then
``jax.device_put`` with the *current* mesh's NamedShardings — this is what
makes elastic restarts (different host/device count) work, as long as the
logical mesh axes still divide the arrays.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = np.dtype(jnp.bfloat16)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == _BF16:
            # npz can't round-trip bfloat16 — store the raw bits
            out[key + "~bf16"] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def _unflatten_into(template, loaded: dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key + "~bf16" in loaded:
            arr = loaded[key + "~bf16"].view(_BF16)
        elif key in loaded:
            arr = loaded[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def config_hash(cfg) -> str:
    return hashlib.sha1(repr(cfg).encode()).hexdigest()[:12]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------

    def save(self, step: int, state: dict, meta: Optional[dict] = None,
             blocking: bool = False, block: Optional[bool] = None) -> None:
        """``state`` is any pytree (params/opt/nas/pipeline...); ``meta`` is
        json-serializable extra info (config hash, mesh, pipeline state).
        Default is ASYNC (background-thread serialization overlapping the
        next steps); pass ``block=True`` to wait for the commit."""
        if block is not None:
            blocking = block
        self.wait()   # never two concurrent saves
        if blocking:
            self._save(step, state, meta or {})
        else:
            # snapshot to host memory on the caller's thread (cheap copy of
            # device arrays), serialize on the background thread
            host_state = jax.tree_util.tree_map(np.asarray, state)
            self._thread = threading.Thread(
                target=self._save, args=(step, host_state, meta or {}),
                daemon=True)
            self._thread.start()

    def _save(self, step: int, state, meta: dict) -> None:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_leaves": len(flat),
            "leaves": sorted(flat),
            **meta,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic commit
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                mf = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(mf):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:010d}",
                               "manifest.json")) as f:
            return json.load(f)

    def restore(self, step: int, template, shardings=None):
        """Load into the structure of ``template``; optionally device_put
        with ``shardings`` (NamedSharding pytree) for resharded restore."""
        path = os.path.join(self.dir, f"step_{step:010d}",
                            f"shard_{self.host_id}.npz")
        with np.load(path) as z:
            loaded = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, loaded)
        if shardings is not None:
            tree = jax.tree_util.tree_map(jax.device_put, tree, shardings)
        return tree

    def restore_latest(self, template, shardings=None
                       ) -> tuple[Any, Optional[int], dict]:
        """Returns (state | None, step | None, manifest meta)."""
        step = self.latest_step()
        if step is None:
            return None, None, {}
        return (self.restore(step, template, shardings), step,
                self.manifest(step))
