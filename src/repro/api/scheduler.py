"""Request-level serving: continuous batching over a slot-pooled KV cache.

The pre-PR5 public serving surface was ``ServingSession.generate`` — a
lockstep loop where one fixed batch prefills together, decodes together and
finishes together, so real traffic (requests arriving at different times
with different prompt/output lengths) leaves the fused deployed kernels
idle behind the shortest-job barrier.  :class:`ServingEngine` redesigns the
surface around **requests**:

* a persistent ``(max_slots, max_len)`` cache pool is allocated once; each
  slot carries its own position, length budget and live/free flag;
* ``submit`` queues a :class:`Request`; admission pads queued prompts into
  ONE fixed ``(max_slots, prefill_len)`` prefill launch (per-row true
  lengths via ``serving.prefill(..., lens=...)``) and where-merges only the
  admitted slots' rows into the pool — in-flight slots are untouched, so
  prefill of new arrivals interleaves with decode of in-flight ones;
* every decode tick is ONE fixed-width ``decode_step`` launch with a
  **per-slot position vector** and a live mask (freed slots drop their ring
  writes / SSM state updates — models/attention.py, models/ssm.py);
* a finished slot (EOS or ``max_tokens``) is reclaimed and refilled from
  the admission queue **without re-jitting**: every launch has the same
  static shapes, so after one warmup pass the jit caches never grow
  (``compile_counts`` exposes the counters the tests and the
  ``continuous_batching`` benchmark section assert on).

Numerical contract: with all slots admitted at once, full-length prompts
and every slot live, each launch is operand-for-operand the lockstep
session's launch — ``run`` is then bit-identical to
``ServingSession.generate`` (tests/test_continuous_batching.py).  On
staggered traces each slot's tokens depend only on its own request for the
row-independent families (dense / ssm / hybrid attention); MoE couples
rows only through expert-capacity overflow drops.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import sampling as smp


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tokens``: (L,) int prompt ids; ``max_tokens``: total generated tokens
    INCLUDING the one sampled from the prefill logits (so ``max_tokens=G``
    corresponds to ``ServingSession.generate(gen=G-1)``); ``eos_id``: stop
    early when this id is sampled (still counted in the output);
    ``extras``: per-request prefill arrays keyed like the batch dict
    (``frames`` for audio, ``prefix_embeds`` for vlm) — rows of slots not
    being admitted are zero-filled.
    """
    tokens: np.ndarray
    max_tokens: int = 16
    eos_id: Optional[int] = None
    extras: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RequestOutput:
    rid: int
    tokens: np.ndarray              # (n_generated,) int32, eos included
    prompt_len: int
    finish_reason: str              # "length" | "eos"


# Module-level jitted admission/step executables, keyed on (cfg id, backend,
# sampling): the same hoisting rule as engine.serving_jits — two engines
# over one deployed config share executables, and re-constructing an engine
# never recompiles.  cfg is strongly referenced so its id() stays unique.
_ENGINE_JITS: dict = {}


def _engine_jits(cfg, backend: str, sampling: smp.SamplingParams) -> dict:
    key = (id(cfg), backend, sampling)
    ent = _ENGINE_JITS.get(key)
    if ent is None:
        from repro.models import serving

        def _admit(dp, batch, lens, admit, tok_old, caches, key):
            """One admission: fixed-width prefill + slot-masked merge.

            ``admit`` (B,) bool selects the slots being (re)filled; their
            prefill caches are right-padded into the pool ring and merged
            row-wise, everything else keeps the in-flight state.  Returns
            the next-token batch (admitted rows freshly sampled from their
            own last-prompt-token logits, others untouched).
            """
            logits, pf = serving.prefill(dp, cfg, batch, backend, lens=lens)
            ring = jax.tree_util.tree_map(jnp.zeros_like, caches)
            emb = serving.embed_caches(pf, ring)

            def merge(new, old):   # stacked cache leaves: batch axis is 1
                m = admit.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)
            caches = jax.tree_util.tree_map(merge, emb, caches)
            tok = smp.sample(logits, sampling, key)          # (B, 1)
            return jnp.where(admit[:, None], tok, tok_old), caches

        def _step(dp, tokens, caches, pos, live, key):
            """One decode tick: per-slot positions, live-masked cache."""
            logits, caches = serving.decode_step(dp, cfg, tokens, caches,
                                                 pos, backend, live=live)
            return smp.sample(logits, sampling, key), caches

        ent = {"cfg": cfg,
               "admit": jax.jit(_admit, donate_argnums=(5,)),
               "step": jax.jit(_step, donate_argnums=(2,))}
        _ENGINE_JITS[key] = ent
    return ent


class _Slot:
    __slots__ = ("rid", "prompt_len", "max_tokens", "eos_id", "generated")

    def __init__(self, rid, prompt_len, max_tokens, eos_id):
        self.rid, self.prompt_len = rid, prompt_len
        self.max_tokens, self.eos_id = max_tokens, eos_id
        self.generated: List[int] = []


class ServingEngine:
    """Continuous-batching serving engine over a deployed LM.

        eng = ServingEngine(cfg, dparams, backend="jnp",
                            max_slots=4, max_len=64, prefill_len=16)
        rid = eng.submit(Request(prompt_ids, max_tokens=20))
        while eng.step()["kind"] != "idle": ...
        outs = eng.collect()                 # finished RequestOutputs

    or, for a whole trace, ``eng.run(requests, arrivals)``.  One engine
    ``step()`` is exactly one device launch (an admission prefill when
    slots are free and requests are queued, else a decode tick over the
    live slots), which is what the stats count.
    """

    def __init__(self, cfg, dparams, backend: str = "jnp",
                 max_slots: int = 4, max_len: int = 64,
                 prefill_len: Optional[int] = None,
                 sampling: smp.SamplingParams = smp.GREEDY, seed: int = 0):
        from repro.models import serving
        self.cfg, self.dparams, self.backend = cfg, dparams, backend
        self.max_slots, self.max_len = max_slots, max_len
        self.prefill_len = prefill_len or max_len // 2
        if self.prefill_len > max_len:
            raise ValueError("prefill_len exceeds the slot ring max_len")
        self.sampling = sampling
        fns = _engine_jits(cfg, backend, sampling)
        self._admit_fn, self._step_fn = fns["admit"], fns["step"]
        self.caches = serving.init_caches(cfg, max_slots, max_len)
        self.tokens = jnp.zeros((max_slots, 1), jnp.int32)
        self._pos = np.zeros(max_slots, np.int64)
        self._live = np.zeros(max_slots, bool)
        self._slots: List[Optional[_Slot]] = [None] * max_slots
        self.queue: List[int] = []
        self._pending: Dict[int, Request] = {}
        self._finished: List[RequestOutput] = []
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)
        self.stats = dict(prefill_launches=0, decode_launches=0,
                          useful_tokens=0, occupancy_sum=0.0, idle_ticks=0)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request for admission; returns its request id."""
        L = int(np.asarray(request.tokens).shape[0])
        if not 1 <= L <= self.prefill_len:
            raise ValueError(f"prompt length {L} not in [1, "
                             f"prefill_len={self.prefill_len}]")
        if request.max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        if L + request.max_tokens - 1 > self.max_len:
            raise ValueError(
                f"prompt_len {L} + max_tokens {request.max_tokens} "
                f"overflows the slot ring (max_len={self.max_len})")
        if self.cfg.family == "vlm" and self.cfg.n_prefix_tokens:
            # the first n_prefix_tokens positions ARE the image context
            # (prefill swaps them for prefix_embeds); a shorter prompt would
            # gather its logits inside the prefix region and let decode
            # ring-writes overwrite it, and a missing embed array would be
            # zero-filled — a silently different model input
            if L <= self.cfg.n_prefix_tokens:
                raise ValueError(
                    f"vlm prompt length {L} must exceed n_prefix_tokens="
                    f"{self.cfg.n_prefix_tokens} (the prefix-embed region)")
            if "prefix_embeds" not in request.extras:
                raise ValueError(
                    "vlm requests need extras['prefix_embeds'] — the "
                    "admission batch would otherwise swap the prefix "
                    "region for zeros")
        if self.cfg.family == "audio" and "frames" not in request.extras:
            raise ValueError(
                "audio requests need extras['frames'] (encoder input) — "
                "an empty slot row would cross-attend to an all-zero "
                "encoder and decode garbage")
        rid = self._next_rid
        self._next_rid += 1
        self._pending[rid] = request
        self.queue.append(rid)
        return rid

    def collect(self) -> List[RequestOutput]:
        """Drain and return the finished request outputs."""
        out, self._finished = self._finished, []
        return out

    @property
    def live_slots(self) -> int:
        return int(self._live.sum())

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self._live.any())

    def compile_counts(self) -> dict:
        """Jit-cache sizes of the two engine executables (recompile guard:
        after a warmup trace these must never grow — same-shaped launches
        forever, the whole point of the fixed-width slot pool)."""
        return {"admit": self._admit_fn._cache_size(),
                "step": self._step_fn._cache_size()}

    # -- scheduler ticks -----------------------------------------------------
    def step(self) -> dict:
        """One scheduler tick = at most one device launch.

        Admission has priority: if any slot is free and requests are
        queued, refill (one fixed-width prefill launch, first token
        sampled).  Otherwise run one decode tick over the live slots.
        Returns a small stats dict (``kind`` in {"prefill", "decode",
        "idle"}).
        """
        free = [i for i, s in enumerate(self._slots) if s is None]
        if self.queue and free:
            return self._admit_tick(free)
        if self._live.any():
            return self._decode_tick()
        self.stats["idle_ticks"] += 1
        return {"kind": "idle"}

    def _next_key(self):
        if self.sampling.kind == "greedy":
            return self._key                     # unused by argmax
        self._key, k = jax.random.split(self._key)
        return k

    def _admit_tick(self, free: List[int]) -> dict:
        B, P = self.max_slots, self.prefill_len
        take = self.queue[:len(free)]
        del self.queue[:len(take)]
        rows = np.zeros((B, P), np.int32)
        lens = np.ones(B, np.int32)
        admit = np.zeros(B, bool)
        extras: Dict[str, np.ndarray] = {}
        if self.cfg.family == "audio":
            extras["frames"] = np.zeros(
                (B, self.cfg.encoder_seq, self.cfg.d_model), np.float32)
        if self.cfg.family == "vlm" and self.cfg.n_prefix_tokens:
            extras["prefix_embeds"] = np.zeros(
                (B, self.cfg.n_prefix_tokens, self.cfg.d_model), np.float32)
        for slot, rid in zip(free, take):
            req = self._pending.pop(rid)
            toks = np.asarray(req.tokens, np.int32)
            L = toks.shape[0]
            rows[slot, :L] = toks
            lens[slot] = L
            admit[slot] = True
            for k, v in req.extras.items():
                extras[k][slot] = v
            self._slots[slot] = _Slot(rid, L, req.max_tokens, req.eos_id)
            self._pos[slot] = L
            self._live[slot] = True
        batch = {"tokens": jnp.asarray(rows)}
        batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        self.tokens, self.caches = self._admit_fn(
            self.dparams, batch, jnp.asarray(lens), jnp.asarray(admit),
            self.tokens, self.caches, self._next_key())
        self.stats["prefill_launches"] += 1
        self.stats["useful_tokens"] += len(take)
        tok_np = np.asarray(self.tokens[:, 0])
        for slot, rid in zip(free, take):
            self._record(slot, int(tok_np[slot]))
        return {"kind": "prefill", "admitted": list(take)}

    def _decode_tick(self) -> dict:
        live = self._live.copy()
        self.tokens, self.caches = self._step_fn(
            self.dparams, self.tokens, self.caches,
            jnp.asarray(self._pos, jnp.int32), jnp.asarray(live),
            self._next_key())
        self.stats["decode_launches"] += 1
        n_live = int(live.sum())
        self.stats["useful_tokens"] += n_live
        self.stats["occupancy_sum"] += n_live / self.max_slots
        self._pos[live] += 1
        tok_np = np.asarray(self.tokens[:, 0])
        for slot in np.nonzero(live)[0]:
            self._record(int(slot), int(tok_np[slot]))
        return {"kind": "decode", "live": n_live}

    def _record(self, slot: int, token: int) -> None:
        st = self._slots[slot]
        st.generated.append(token)
        done_len = len(st.generated) >= st.max_tokens
        done_eos = st.eos_id is not None and token == st.eos_id
        if done_len or done_eos:
            self._finished.append(RequestOutput(
                rid=st.rid, tokens=np.asarray(st.generated, np.int32),
                prompt_len=st.prompt_len,
                finish_reason="eos" if done_eos else "length"))
            self._slots[slot] = None
            self._live[slot] = False

    # -- whole-trace driver --------------------------------------------------
    def run(self, requests: Sequence[Request],
            arrivals: Optional[Sequence[int]] = None
            ) -> Dict[int, RequestOutput]:
        """Serve a trace to completion; returns outputs keyed by the
        request's index in ``requests``.

        ``arrivals``: optional per-request arrival times in scheduler
        ticks (default: all at tick 0 — the synchronized case).  A request
        is submitted the first tick at/after its arrival; the loop runs
        idle ticks while waiting on future arrivals.
        """
        arrivals = ([0] * len(requests) if arrivals is None
                    else [int(a) for a in arrivals])
        if len(arrivals) != len(requests):
            raise ValueError("arrivals and requests length mismatch")
        order = sorted(range(len(requests)), key=lambda i: (arrivals[i], i))
        rid_to_idx: Dict[int, int] = {}
        outs: Dict[int, RequestOutput] = {}
        nxt, t = 0, 0
        while nxt < len(order) or self.has_work():
            while nxt < len(order) and arrivals[order[nxt]] <= t:
                i = order[nxt]
                rid_to_idx[self.submit(requests[i])] = i
                nxt += 1
            self.step()
            for out in self.collect():
                outs[rid_to_idx[out.rid]] = out
            t += 1
        return outs
