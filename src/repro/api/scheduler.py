"""Request-level serving: continuous batching over a paged KV cache.

The pre-PR5 public serving surface was ``ServingSession.generate`` — a
lockstep loop where one fixed batch prefills together, decodes together and
finishes together, so real traffic (requests arriving at different times
with different prompt/output lengths) leaves the fused deployed kernels
idle behind the shortest-job barrier.  :class:`ServingEngine` redesigns the
surface around **requests**:

* ``submit`` queues a :class:`Request`; admission pads queued prompts into
  ONE fixed ``(max_slots, prefill_len)`` prefill launch (per-row true
  lengths via ``serving.prefill(..., lens=...)``) and merges only the
  admitted slots' cache rows — in-flight slots are untouched, so prefill of
  new arrivals interleaves with decode of in-flight ones;
* every decode tick is ONE fixed-width ``decode_step`` launch with a
  **per-slot position vector** and a live mask (freed slots drop their ring
  writes / SSM state updates — models/attention.py, models/ssm.py);
* a finished slot (EOS or ``max_tokens``) is reclaimed and refilled from
  the admission queue **without re-jitting**: every launch has the same
  static shapes, so after one warmup pass the jit caches never grow
  (``compile_counts`` exposes the counters the tests and the
  ``continuous_batching`` / ``paged_cache`` benchmark sections assert on).

Paged KV cache (PR 6).  By default the ring leaves are no longer dense
``(max_slots, max_len)`` rows but **physical pages** managed by
``repro.cache``: each slot carries a ``(pages_per_slot,)`` page-table row,
admission allocates only ``ceil(prompt_len / page_size)`` pages and decode
lazily maps one more page each time a slot's position crosses a page
boundary, so resident KV bytes track the tokens actually held instead of
``max_slots * max_len``.  Admission is gated by a **page reservation**
invariant (``available >= reserved``) that guarantees a lazy decode
allocation can never fail mid-request; when the head of the queue does not
fit it waits (strict FIFO, ``deferred_admissions`` stat) while decode keeps
ticking.  A radix index over prompt tokens additionally shares identical
prompt prefixes **copy-free** (``prefix_sharing``, default on for the
``dense`` family): matched full pages are mapped read-only with a refcount
bump, a fully-cached prompt skips its prefill launch entirely (the slot
bootstraps from the last prompt token in its first decode tick — zero
prefill FLOPs), and pages of finished requests stay cached while free
space lasts (LRU leaf-first eviction under pressure).  ``page_size=None``
restores the dense PR5 pool bit-for-bit — the parity oracle the paged
tests compare against.

Numerical contract: the paged engine's launches gather per-slot ring views
that are element-for-element the dense rings (``repro.cache.paged``), so
its tokens are **bit-identical** to the dense engine's on any trace without
prefix hits; a full-prefix hit samples its first token from a decode-step
launch instead of the prefill launch (same math, different launch path).
MoE couples rows through expert-capacity overflow, so sharing pages built
under a different batch composition is approximate — prefix sharing there
is an explicit opt-in.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import sampling as smp
from repro.cache import NULL_PAGE, PagePool
from repro.dist import fault
from repro.dist import sharding as shd


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tokens``: (L,) int prompt ids; ``max_tokens``: total generated tokens
    INCLUDING the one sampled from the prefill logits (so ``max_tokens=G``
    corresponds to the old lockstep ``generate(gen=G-1)``); ``eos_id``:
    stop early when this id is sampled (still counted in the output);
    ``extras``: per-request prefill arrays keyed like the batch dict
    (``frames`` for audio, ``prefix_embeds`` for vlm) — rows of slots not
    being admitted are zero-filled.
    """
    tokens: np.ndarray
    max_tokens: int = 16
    eos_id: Optional[int] = None
    extras: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RequestOutput:
    rid: int
    tokens: np.ndarray              # (n_generated,) int32, eos included
    prompt_len: int
    finish_reason: str              # "length" | "eos"


# Module-level jitted admission/step executables, keyed on (cfg id, backend,
# sampling, page_size, kv_bits): the same hoisting rule as
# engine.serving_jits — two engines over one deployed config share
# executables, and re-constructing an engine never recompiles.  cfg is
# strongly referenced so its id() stays unique.
_ENGINE_JITS: dict = {}


def _engine_jits(cfg, backend: str, sampling: smp.SamplingParams,
                 page_size: Optional[int], kv_bits=None,
                 speculate_k: int = 0, draft_kv_bits=None, mesh=None) -> dict:
    key = (id(cfg), backend, sampling, page_size, kv_bits, speculate_k,
           draft_kv_bits, mesh)
    ent = _ENGINE_JITS.get(key)
    if ent is None:
        from repro.models import serving

        ctx = shd.MeshContext(mesh)

        def _meshed(fn, cache_outs=()):
            """Trace ``fn`` inside the serving-mesh context (fused kernels
            route to their shard_map TP/EP forms, attention/router
            annotations activate) and pin output shardings: the cache trees
            at positions ``cache_outs`` keep their slot/page-axis ``data``
            sharding, every other output (tokens, logits rows, accept
            counts) replicates.  Identity without a mesh — the single-device
            trace is byte-for-byte the pre-mesh one."""
            if not ctx.is_active:
                return fn

            def wrapped(*args):
                with shd.serving_mesh(ctx):
                    out = fn(*args)
                    return tuple(
                        ctx.constrain_caches(o) if i in cache_outs
                        else ctx.constrain_replicated(o)
                        for i, o in enumerate(out))
            return wrapped

        if page_size is None:
            def _admit(dp, batch, lens, admit, tok_old, caches, key):
                """One admission: fixed-width prefill + slot-masked merge.

                ``admit`` (B,) bool selects the slots being (re)filled;
                their prefill caches are right-padded into the pool ring
                and merged row-wise, everything else keeps the in-flight
                state.  Returns the next-token batch (admitted rows
                freshly sampled from their own last-prompt-token logits,
                others untouched).
                """
                logits, pf = serving.prefill(dp, cfg, batch, backend,
                                             lens=lens, kv_bits=kv_bits)
                ring = jax.tree_util.tree_map(jnp.zeros_like, caches)
                emb = serving.embed_caches(pf, ring)

                def merge(new, old):  # stacked cache leaves: batch axis 1
                    m = admit.reshape((1, -1) + (1,) * (new.ndim - 2))
                    return jnp.where(m, new, old)
                caches = jax.tree_util.tree_map(merge, emb, caches)
                tok = smp.sample(logits, sampling, key)          # (B, 1)
                return jnp.where(admit[:, None], tok, tok_old), caches

            def _step(dp, tokens, caches, pos, live, key):
                """One decode tick: per-slot positions, live-masked cache."""
                logits, caches = serving.decode_step(dp, cfg, tokens, caches,
                                                     pos, backend, live=live,
                                                     kv_bits=kv_bits)
                return smp.sample(logits, sampling, key), caches
        else:
            def _admit(dp, batch, lens, admit, tok_old, caches, wp_flat,
                       key):
                """Paged admission: fixed-width prefill + page scatter.

                ``wp_flat (B * n_prompt_pages,)`` maps each slot's prompt
                pages to physical pages (out-of-bounds = skip the write:
                non-admitted slots, junk tails, prefix-shared read-only
                pages); per-slot leaves (hybrid SSM state, audio cross)
                still merge on ``admit``.  Same launch shape regardless of
                how many slots admit — zero recompiles after warmup.
                """
                logits, pf = serving.prefill(dp, cfg, batch, backend,
                                             lens=lens, kv_bits=kv_bits)
                caches = serving.merge_paged_caches(cfg, pf, caches, admit,
                                                    wp_flat)
                tok = smp.sample(logits, sampling, key)          # (B, 1)
                return jnp.where(admit[:, None], tok, tok_old), caches

            def _step(dp, tokens, caches, pos, live_write, pages, key):
                """One paged decode tick: the ``(B, pages_per_slot)`` page
                table routes every ring read/write; ``live_write`` also
                masks rows whose write is suppressed for one tick (a
                full-prefix hit whose last prompt position is already
                cached in a shared page)."""
                logits, caches = serving.decode_step(
                    dp, cfg, tokens, caches, pos, backend, live=live_write,
                    pages=pages, page_size=page_size, kv_bits=kv_bits)
                return smp.sample(logits, sampling, key), caches

        ent = {"cfg": cfg,
               "admit": jax.jit(_meshed(_admit, cache_outs=(1,)),
                                donate_argnums=(5,)),
               "step": jax.jit(_meshed(_step, cache_outs=(1,)),
                               donate_argnums=(2,))}

        if speculate_k:
            # Speculative serving replaces the admission executable with a
            # combined verifier+draft prefill (the draft pool has no prefix
            # sharing, so it prefills even slots the radix index admits with
            # zero verifier FLOPs — ``dadmit`` covers ``admit``) and adds the
            # round executables: a single-token draft step that also returns
            # the logits row its token was sampled from, and a (k+1)-wide
            # verify that fuses the multi-token decode with rejection
            # sampling (api/sampling.speculative_accept).  The baseline
            # ``step`` stays — it is the suppressed-slot fallback tick.
            if page_size is None:
                def _admit_spec(dp, ddp, batch, lens, admit, dadmit, tok_old,
                                caches, dcaches, key):
                    logits, pf = serving.prefill(dp, cfg, batch, backend,
                                                 lens=lens, kv_bits=kv_bits)
                    emb = serving.embed_caches(
                        pf, jax.tree_util.tree_map(jnp.zeros_like, caches))

                    def merge(sel):
                        def m(new, old):
                            s = sel.reshape((1, -1) + (1,) * (new.ndim - 2))
                            return jnp.where(s, new, old)
                        return m
                    caches = jax.tree_util.tree_map(merge(admit), emb, caches)
                    _, dpf = serving.prefill(ddp, cfg, batch, backend,
                                             lens=lens, kv_bits=draft_kv_bits)
                    demb = serving.embed_caches(
                        dpf, jax.tree_util.tree_map(jnp.zeros_like, dcaches))
                    dcaches = jax.tree_util.tree_map(merge(dadmit), demb,
                                                     dcaches)
                    tok = smp.sample(logits, sampling, key)
                    return (jnp.where(admit[:, None], tok, tok_old), caches,
                            dcaches)

                def _draft(ddp, tokens, dcaches, pos, live, key):
                    lg, dcaches = serving.decode_step(
                        ddp, cfg, tokens, dcaches, pos, backend, live=live,
                        kv_bits=draft_kv_bits)
                    return smp.sample(lg, sampling, key), lg[:, 0], dcaches

                def _verify(dp, tokens, caches, pos, live, dtok, dlg, key):
                    lg, caches = serving.decode_step(
                        dp, cfg, tokens, caches, pos, backend, live=live,
                        kv_bits=kv_bits)
                    acc, out = smp.speculative_accept(dtok, dlg, lg,
                                                      sampling, key)
                    return acc, out, caches
            else:
                def _admit_spec(dp, ddp, batch, lens, admit, dadmit, tok_old,
                                caches, dcaches, wp_flat, dwp_flat, key):
                    logits, pf = serving.prefill(dp, cfg, batch, backend,
                                                 lens=lens, kv_bits=kv_bits)
                    caches = serving.merge_paged_caches(cfg, pf, caches,
                                                        admit, wp_flat)
                    _, dpf = serving.prefill(ddp, cfg, batch, backend,
                                             lens=lens, kv_bits=draft_kv_bits)
                    dcaches = serving.merge_paged_caches(cfg, dpf, dcaches,
                                                         dadmit, dwp_flat)
                    tok = smp.sample(logits, sampling, key)
                    return (jnp.where(admit[:, None], tok, tok_old), caches,
                            dcaches)

                def _draft(ddp, tokens, dcaches, pos, live, pages, key):
                    lg, dcaches = serving.decode_step(
                        ddp, cfg, tokens, dcaches, pos, backend, live=live,
                        pages=pages, page_size=page_size,
                        kv_bits=draft_kv_bits)
                    return smp.sample(lg, sampling, key), lg[:, 0], dcaches

                def _verify(dp, tokens, caches, pos, live, pages, dtok, dlg,
                            key):
                    lg, caches = serving.decode_step(
                        dp, cfg, tokens, caches, pos, backend, live=live,
                        pages=pages, page_size=page_size, kv_bits=kv_bits)
                    acc, out = smp.speculative_accept(dtok, dlg, lg,
                                                      sampling, key)
                    return acc, out, caches

            ent["admit"] = jax.jit(_meshed(_admit_spec, cache_outs=(1, 2)),
                                   donate_argnums=(7, 8))
            ent["draft_step"] = jax.jit(_meshed(_draft, cache_outs=(2,)),
                                        donate_argnums=(2,))
            ent["verify"] = jax.jit(_meshed(_verify, cache_outs=(2,)),
                                    donate_argnums=(2,))
        _ENGINE_JITS[key] = ent
    return ent


def auto_page_size(cfg, max_len: int, prefill_len: int,
                   cap: int = 16) -> Optional[int]:
    """Default page size: the largest divisor of gcd(max_len, prefill_len)
    not exceeding ``cap`` (both widths must split into whole pages so the
    gathered ring and the scattered prefill stay exact-shape).  ``None``
    (dense) for families with no ring to page (ssm)."""
    from repro.models import serving
    if not serving.supports_paging(cfg):
        return None
    g = math.gcd(max_len, prefill_len)
    return max(t for t in range(1, min(cap, g) + 1) if g % t == 0)


class _Slot:
    __slots__ = ("rid", "prompt_len", "max_tokens", "eos_id", "generated",
                 "worst", "mapped")

    def __init__(self, rid, prompt_len, max_tokens, eos_id,
                 worst=0, mapped=0):
        self.rid, self.prompt_len = rid, prompt_len
        self.max_tokens, self.eos_id = max_tokens, eos_id
        self.generated: List[int] = []
        self.worst = worst              # page budget ceil((L+mt-1)/T)
        self.mapped = mapped            # pages currently in the table row


class ServingEngine:
    """Continuous-batching serving engine over a deployed LM.

        eng = ServingEngine(cfg, dparams, backend="jnp",
                            max_slots=4, max_len=64, prefill_len=16)
        rid = eng.submit(Request(prompt_ids, max_tokens=20))
        while eng.step()["kind"] != "idle": ...
        outs = eng.collect()                 # finished RequestOutputs

    or, for a whole trace, ``eng.run(requests, arrivals)``.  One engine
    ``step()`` is at most one device launch (an admission prefill when
    slots and pages are free and requests are queued, else a decode tick
    over the live slots), which is what the stats count.

    ``page_size``: ``"auto"`` (default) pages the KV cache with
    :func:`auto_page_size`; an int forces that page size; ``None`` serves
    the dense PR5 slot pool.  ``num_pages`` (paged mode) sizes the physical
    pool — default ``1 + max_slots * max_len / page_size``, the dense
    capacity plus the NULL page, so default engines never defer.
    ``prefix_sharing``: ``"auto"`` enables the radix prompt index for the
    ``dense`` family; ``True`` additionally allows ``moe`` (approximate —
    expert-capacity coupling makes prefill rows batch-dependent); families
    whose generation depends on non-token inputs (vlm prefix embeds, audio
    frames) or uncached recurrent state (ssm, hybrid) reject it.

    ``kv_bits``: cache quantization policy (``serving.kv_specs``).  ``None``
    (default) keeps the legacy int8-per-token cache; an int or bit-tuple
    stores the rings channel-wise packed (models/kv_quant.py) — page pools
    shrink to the packed bytes, ``kv_bytes_*`` price the packed layout, and
    ``backend="pallas"`` decodes GQA rings through the fused dequant
    decode-attention kernel.  Part of the jit key: one policy = one warmup,
    zero recompiles after.

    ``speculate_k`` > 0 turns every decode tick into a speculative round
    (``_speculative_tick``): a draft model (``draft_dparams``, default the
    verifier itself; pair it with a low-bit re-quantization from
    ``serving.draft_model`` / dual-policy ``Engine.deploy``) proposes k
    tokens in k single-token launches against its own private KV pool
    (``draft_kv_bits`` independently settable), then ONE (k+1)-wide verify
    launch scores all of them and rejection sampling keeps the longest
    valid prefix plus a correction token.  Under greedy sampling the
    emitted stream is bit-identical to the non-speculative engine's on the
    same backend — the parity anchor tests/test_speculative.py pins.
    """

    def __init__(self, cfg, dparams, backend: str = "jnp",
                 max_slots: int = 4, max_len: int = 64,
                 prefill_len: Optional[int] = None,
                 sampling: smp.SamplingParams = smp.GREEDY, seed: int = 0,
                 page_size="auto", num_pages: Optional[int] = None,
                 prefix_sharing="auto", kv_bits=None, speculate_k: int = 0,
                 draft_dparams=None, draft_kv_bits=None, mesh=None,
                 heartbeat_timeout: float = 2.0):
        from repro.models import serving
        self.cfg, self.dparams, self.backend = cfg, dparams, backend
        self.max_slots, self.max_len = max_slots, max_len
        # mesh=None: today's single-device engine, bit-for-bit.  With a
        # (data, model) mesh the context owns placement (weights by the
        # sharding rules, caches along the slot/page axis, scheduler state
        # replicated) and its data-axis size doubles as the host fleet for
        # the heartbeat/drain story below.
        self.mesh_ctx = shd.MeshContext(mesh)
        self.speculate_k = int(speculate_k)
        if self.speculate_k < 0:
            raise ValueError("speculate_k must be >= 0")
        if self.speculate_k:
            if not serving.supports_speculative(cfg):
                raise ValueError(
                    f"family {cfg.family!r} cannot serve speculatively "
                    "(serving.supports_speculative): rewinding to the "
                    "accepted length needs position-addressed cache writes")
            if isinstance(draft_kv_bits, (list, tuple)):
                draft_kv_bits = tuple(int(b) for b in draft_kv_bits)
            serving.kv_specs(cfg, draft_kv_bits)
        else:
            draft_kv_bits = None
            draft_dparams = None
        self.draft_kv_bits = draft_kv_bits
        # self-draft by default: the verifier proposes for itself — the
        # degenerate case the greedy parity tests pin (every proposal
        # accepted, output bit-identical to the baseline engine)
        self.draft_dparams = (dparams if (self.speculate_k
                                          and draft_dparams is None)
                              else draft_dparams)
        if self.mesh_ctx.is_active:
            self.dparams = self.mesh_ctx.put_params(self.dparams)
            if self.draft_dparams is dparams:
                # self-draft: keep sharing the verifier's placed weights
                self.draft_dparams = self.dparams
            elif self.draft_dparams is not None:
                self.draft_dparams = self.mesh_ctx.put_params(
                    self.draft_dparams)
        # normalize to a hashable jit-key component and resolve eagerly: an
        # unpackable feature axis raises HERE (engine construction), never
        # inside a jitted launch
        if isinstance(kv_bits, (list, tuple)):
            kv_bits = tuple(int(b) for b in kv_bits)
        serving.kv_specs(cfg, kv_bits)
        self.kv_bits = kv_bits
        self.prefill_len = prefill_len or max_len // 2
        if self.prefill_len > max_len:
            raise ValueError("prefill_len exceeds the slot ring max_len")

        if page_size == "auto":
            page_size = auto_page_size(cfg, max_len, self.prefill_len)
        if page_size is not None:
            if not serving.supports_paging(cfg):
                raise ValueError(f"family {cfg.family!r} has no ring axis "
                                 "to page (pass page_size=None)")
            if max_len % page_size or self.prefill_len % page_size:
                raise ValueError(
                    f"page_size {page_size} must divide both max_len "
                    f"{max_len} and prefill_len {self.prefill_len}")
        self.page_size = page_size
        self.pages_per_slot = (0 if page_size is None
                               else max_len // page_size)
        self.n_prompt_pages = (0 if page_size is None
                               else self.prefill_len // page_size)
        if prefix_sharing == "auto":
            prefix_sharing = page_size is not None and cfg.family == "dense"
        elif prefix_sharing:
            if page_size is None:
                raise ValueError("prefix_sharing requires a paged cache")
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"prefix_sharing unavailable for family {cfg.family!r}: "
                    "its generation depends on inputs the token-keyed radix "
                    "index cannot see (prefix embeds / frames / recurrent "
                    "state)")
        self.prefix_sharing = bool(prefix_sharing)

        self.sampling = sampling
        fns = _engine_jits(cfg, backend, sampling, page_size, kv_bits,
                           speculate_k=self.speculate_k,
                           draft_kv_bits=draft_kv_bits,
                           mesh=self.mesh_ctx.mesh)
        self._admit_fn, self._step_fn = fns["admit"], fns["step"]
        if self.speculate_k:
            self._draft_fn = fns["draft_step"]
            self._verify_fn = fns["verify"]

        if page_size is None:
            self.pool = None
            self._pages = None
            self.caches = serving.init_caches(cfg, max_slots, max_len,
                                              kv_bits=kv_bits)
        else:
            user_pages = num_pages is not None
            if num_pages is None:
                num_pages = 1 + max_slots * self.pages_per_slot
            if num_pages < 2:
                raise ValueError("num_pages must be >= 2 (NULL page + one "
                                 "allocatable page)")
            # auto-sized pools round up so the physical-page axis divides
            # the data axis; an explicit num_pages is honored verbatim
            # (cache_shardings falls back to replication if it won't shard)
            self.pool = PagePool(num_pages, page_size,
                                 prefix_sharing=self.prefix_sharing,
                                 pad_to=(1 if user_pages
                                         else self.mesh_ctx.data))
            self._pages = np.full((max_slots, self.pages_per_slot),
                                  NULL_PAGE, np.int32)
            self.caches = serving.init_paged_caches(cfg, max_slots,
                                                    self.pool.num_pages,
                                                    page_size,
                                                    kv_bits=kv_bits)
            mask = serving.paged_leaf_mask(cfg)
            leaves = zip(jax.tree_util.tree_leaves(mask),
                         jax.tree_util.tree_leaves(self.caches))
            self._page_bytes = sum(t.nbytes // t.shape[1]
                                   for m, t in leaves if m)
        self._reserved = 0              # pages promised to live slots
        self._suppress = np.zeros(max_slots, bool)

        if self.speculate_k:
            if page_size is None:
                self.draft_caches = serving.init_caches(
                    cfg, max_slots, max_len, kv_bits=draft_kv_bits)
                self._draft_pages = None
                self._draft_num_pages = 0
            else:
                # private draft pool behind a STATIC identity page table:
                # slot i owns pages [1 + i*pps, 1 + (i+1)*pps) forever — no
                # allocator, no sharing, nothing to release.  Rewind after a
                # rejected proposal is the same masked-overwrite contract as
                # the verifier pool: entries above the accepted position are
                # never read (``<= pos`` masks) and the next round's writes
                # land on them in order.
                dnp = 1 + max_slots * self.pages_per_slot
                self.draft_caches = serving.init_paged_caches(
                    cfg, max_slots, dnp, page_size, kv_bits=draft_kv_bits)
                self._draft_num_pages = dnp
                self._draft_pages = jnp.asarray(
                    1 + np.arange(max_slots * self.pages_per_slot,
                                  dtype=np.int32).reshape(
                                      max_slots, self.pages_per_slot))
        # one pending catch-up token per slot: fed to the draft at pos-1
        # before the next round's proposals (set when a round accepts all k
        # — the draft never consumed its own last token — or when a
        # suppressed-slot fallback tick advanced the verifier without it)
        self._catchup = np.zeros(max_slots, bool)
        self._catchup_tok = np.zeros(max_slots, np.int64)

        self.tokens = jnp.zeros((max_slots, 1), jnp.int32)
        if self.mesh_ctx.is_active:
            self.caches = self.mesh_ctx.put_caches(self.caches)
            self.tokens = self.mesh_ctx.put_replicated(self.tokens)
            if self.speculate_k:
                self.draft_caches = self.mesh_ctx.put_caches(
                    self.draft_caches)
                if self._draft_pages is not None:
                    self._draft_pages = self.mesh_ctx.put_replicated(
                        self._draft_pages)
        self._pos = np.zeros(max_slots, np.int64)
        self._live = np.zeros(max_slots, bool)
        self._slots: List[Optional[_Slot]] = [None] * max_slots
        self.queue: List[int] = []
        self._pending: Dict[int, Request] = {}
        self._finished: List[RequestOutput] = []
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)
        self.stats = dict(prefill_launches=0, decode_launches=0,
                          useful_tokens=0, occupancy_sum=0.0, idle_ticks=0,
                          prefix_hits=0, zero_prefill_admits=0,
                          cached_tokens=0, deferred_admissions=0,
                          evictions=0, pages_peak=0, draft_launches=0,
                          verify_launches=0, spec_rounds=0,
                          accepted_tokens=0, host_drains=0,
                          drained_requests=0)
        # -- host liveness (drain-on-death) --------------------------------
        # The data axis doubles as the host fleet: host h owns the
        # contiguous slot range fault.owned_slots(h, max_slots, n_hosts).
        # The engine beats every non-failed host once per step() on a tick
        # clock; a host declared dead by the heartbeat has its slots'
        # requests drained back to the front of the admission queue (pages
        # freed through the normal refcount path) and its slots retired.
        self.n_hosts = self.mesh_ctx.data
        self.heartbeat = fault.Heartbeat(list(range(self.n_hosts)),
                                         timeout_s=heartbeat_timeout)
        self._hb_clock = 0
        self._failed_hosts: set[int] = set()
        self._dead_slots = np.zeros(max_slots, bool)
        self._requests: Dict[int, Request] = {}

    # -- request lifecycle ---------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request for admission; returns its request id."""
        rid = self._next_rid
        toks = np.asarray(request.tokens)
        if toks.ndim != 1:
            raise ValueError(
                f"request {rid}: prompt must be a 1-D array of token ids; "
                f"got shape {toks.shape}")
        if not np.issubdtype(toks.dtype, np.integer):
            raise ValueError(
                f"request {rid}: prompt dtype {toks.dtype} is not an "
                "integer type — token ids would be silently truncated")
        L = int(toks.shape[0])
        if not 1 <= L <= self.prefill_len:
            raise ValueError(f"request {rid}: prompt length {L} not in "
                             f"[1, prefill_len={self.prefill_len}]")
        if request.max_tokens < 1:
            raise ValueError(f"request {rid}: max_tokens must be >= 1")
        worst = (0 if self.pool is None
                 else -(-(L + request.max_tokens - 1) // self.page_size))
        if (L + request.max_tokens - 1 > self.max_len
                or (self.pool is not None and worst > self.pool.capacity)):
            budget = (f"slot rings {self.max_slots} x {self.max_len}"
                      if self.pool is None else
                      f"needs {worst} pages of {self.page_size} tokens, "
                      f"pages free {self.pool.available}"
                      f"/{self.pool.capacity}")
            raise ValueError(
                f"request {rid}: prompt_len {L} + max_tokens "
                f"{request.max_tokens} overflows the slot ring "
                f"(max_len={self.max_len}; {budget})")
        if self.cfg.family == "vlm" and self.cfg.n_prefix_tokens:
            # the first n_prefix_tokens positions ARE the image context
            # (prefill swaps them for prefix_embeds); a shorter prompt would
            # gather its logits inside the prefix region and let decode
            # ring-writes overwrite it, and a missing embed array would be
            # zero-filled — a silently different model input
            if L <= self.cfg.n_prefix_tokens:
                raise ValueError(
                    f"vlm prompt length {L} must exceed n_prefix_tokens="
                    f"{self.cfg.n_prefix_tokens} (the prefix-embed region)")
            if "prefix_embeds" not in request.extras:
                raise ValueError(
                    "vlm requests need extras['prefix_embeds'] — the "
                    "admission batch would otherwise swap the prefix "
                    "region for zeros")
        if self.cfg.family == "audio" and "frames" not in request.extras:
            raise ValueError(
                "audio requests need extras['frames'] (encoder input) — "
                "an empty slot row would cross-attend to an all-zero "
                "encoder and decode garbage")
        self._next_rid += 1
        self._pending[rid] = request
        # retained past admission so a host drain can requeue in-flight
        # requests verbatim (dropped again when the request finishes)
        self._requests[rid] = request
        self.queue.append(rid)
        return rid

    def collect(self) -> List[RequestOutput]:
        """Drain and return the finished request outputs."""
        out, self._finished = self._finished, []
        return out

    @property
    def live_slots(self) -> int:
        return int(self._live.sum())

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self._live.any())

    def compile_counts(self) -> dict:
        """Jit-cache sizes of the two engine executables (recompile guard:
        after a warmup trace these must never grow — same-shaped launches
        forever, the whole point of the fixed-width slot pool)."""
        out = {"admit": self._admit_fn._cache_size(),
               "step": self._step_fn._cache_size()}
        if self.speculate_k:
            out["draft"] = self._draft_fn._cache_size()
            out["verify"] = self._verify_fn._cache_size()
        return out

    # -- KV residency metrics ------------------------------------------------
    def kv_bytes_dense(self) -> int:
        """Bytes the dense ``(max_slots, max_len)`` cache pool holds
        resident for this config at THIS engine's ``kv_bits`` policy — the
        paged engine's baseline (packed layouts price their packed bytes)."""
        from repro.models import serving
        tree = jax.eval_shape(
            lambda: serving.init_caches(self.cfg, self.max_slots,
                                        self.max_len, kv_bits=self.kv_bits))
        return sum(int(np.prod(t.shape)) * np.dtype(t.dtype).itemsize
                   for t in jax.tree_util.tree_leaves(tree))

    def kv_bytes_resident(self) -> int:
        """KV bytes currently holding live or reusable data: pages in use
        (referenced + radix-resident) plus the always-resident per-slot
        leaves (hybrid SSM state, audio cross caches).  Dense mode: the
        whole pool."""
        if self.pool is None:
            return self.kv_bytes_dense()
        total = sum(t.nbytes for t in jax.tree_util.tree_leaves(self.caches))
        paged_total = self._page_bytes * self.pool.num_pages
        return (total - paged_total) + self._page_bytes * self.pool.in_use

    def kv_bytes_peak(self) -> int:
        """High-water resident KV bytes over the engine's lifetime — the
        benchmark's memory headline (``pages_peak`` priced in bytes)."""
        if self.pool is None:
            return self.kv_bytes_dense()
        total = sum(t.nbytes for t in jax.tree_util.tree_leaves(self.caches))
        paged_total = self._page_bytes * self.pool.num_pages
        return (total - paged_total) + \
            self._page_bytes * self.stats["pages_peak"]

    def _note_pool(self) -> None:
        self.stats["evictions"] = self.pool.evictions
        self.stats["pages_peak"] = max(self.stats["pages_peak"],
                                       self.pool.in_use)

    # -- scheduler ticks -----------------------------------------------------
    def step(self) -> dict:
        """One scheduler tick = at most one device launch.

        Admission has priority: if any slot is free, requests are queued
        and (paged mode) the head of the queue passes the page-reservation
        gate, refill (at most one fixed-width prefill launch; fully-cached
        prompts admit with NO launch).  Otherwise run one decode tick —
        a speculative round when ``speculate_k`` > 0 — over the live
        slots.  Returns a small stats dict (``kind`` in {"prefill",
        "cached", "decode", "speculative", "idle"}).
        """
        self._hb_clock += 1
        for h in range(self.n_hosts):
            if h not in self._failed_hosts:
                self.heartbeat.beat(h, self._hb_clock)
        for h in self.heartbeat.check(self._hb_clock):
            self._drain_host(h)
        free = [i for i, s in enumerate(self._slots)
                if s is None and not self._dead_slots[i]]
        if self.queue and free:
            out = self._admit_tick(free)
            if out is not None:
                return out
        if self._live.any():
            return (self._speculative_tick() if self.speculate_k
                    else self._decode_tick())
        self.stats["idle_ticks"] += 1
        return {"kind": "idle"}

    def _next_key(self):
        if self.sampling.kind == "greedy":
            return self._key                     # unused by argmax
        self._key, k = jax.random.split(self._key)
        return k

    def _plan_admission(self, toks: np.ndarray, max_tokens: int):
        """Page plan for one request, or None if it must wait.

        Returns ``(matched, full_hit, worst)``.  The gate keeps the
        invariant ``pool.available >= self._reserved`` — ``available``
        counts free + radix-resident (evictable) pages and residency is
        closed under prefix descendants, so a passing admission can take
        its prompt pages NOW and every future lazy decode allocation of
        every live slot is guaranteed to succeed.  Reviving a matched
        resident page consumes it from ``available``, hence the ``+ r``.
        """
        T = self.page_size
        L = len(toks)
        matched = self.pool.match_prefix(toks) if self.prefix_sharing else []
        m = len(matched)
        full_hit = self.prefix_sharing and m > 0 and m * T >= L - 1
        worst = -(-(L + max_tokens - 1) // T)
        r = sum(self.pool.is_resident(p) for p in matched)
        if self.pool.available - self._reserved < (worst - m) + r:
            return None
        return matched, full_hit, worst

    def _admit_tick(self, free: List[int]) -> Optional[dict]:
        """Admit queued requests into free slots; at most ONE prefill
        launch.  Paged mode walks the queue strictly FIFO and stops at the
        first request the page gate rejects (head-of-line waits; decode
        keeps draining pages).  Returns None when nothing was admitted so
        ``step`` falls through to a decode tick."""
        B, P, T = self.max_slots, self.prefill_len, self.page_size
        plans = {}
        if self.pool is None:
            take = self.queue[:len(free)]
        else:
            take = []
            for rid in self.queue[:len(free)]:
                req = self._pending[rid]
                plan = self._plan_admission(
                    np.asarray(req.tokens, np.int32), req.max_tokens)
                if plan is None:
                    self.stats["deferred_admissions"] += 1
                    break
                matched, full_hit, worst = plan
                toks = np.asarray(req.tokens, np.int32)
                L = toks.shape[0]
                # take the pages NOW: shared first (so they cannot be
                # evicted by our own fresh allocations), then fresh prompt
                # pages; decode pages stay reserved, mapped lazily.
                self.pool.acquire(matched)
                if full_hit:
                    row = list(matched)
                else:
                    n_prompt = -(-L // T)
                    row = list(matched) + self.pool.alloc(n_prompt -
                                                          len(matched))
                    # publish the full prompt pages BEFORE the launch: a
                    # same-tick duplicate prompt becomes a full hit whose
                    # shared reads happen only in later decode ticks,
                    # after this tick's prefill wrote the pages.
                    self.pool.index_prompt(toks, row[:L // T])
                self._reserved += worst - len(row)
                plans[rid] = (matched, full_hit, worst, row)
                take.append(rid)
            if not take:
                return None
        del self.queue[:len(take)]

        rows = np.zeros((B, P), np.int32)
        lens = np.ones(B, np.int32)
        admit = np.zeros(B, bool)
        dadmit = np.zeros(B, bool)
        wp_flat = (None if self.pool is None else
                   np.full(B * self.n_prompt_pages, self.pool.num_pages,
                           np.int32))
        dwp_flat = (None if (self.pool is None or not self.speculate_k) else
                    np.full(B * self.n_prompt_pages, self._draft_num_pages,
                            np.int32))
        boot: List[tuple] = []          # (slot, last prompt token)
        extras: Dict[str, np.ndarray] = {}
        if self.cfg.family == "audio":
            extras["frames"] = np.zeros(
                (B, self.cfg.encoder_seq, self.cfg.d_model), np.float32)
        if self.cfg.family == "vlm" and self.cfg.n_prefix_tokens:
            extras["prefix_embeds"] = np.zeros(
                (B, self.cfg.n_prefix_tokens, self.cfg.d_model), np.float32)
        for slot, rid in zip(free, take):
            req = self._pending.pop(rid)
            toks = np.asarray(req.tokens, np.int32)
            L = toks.shape[0]
            lens[slot] = L
            for k, v in req.extras.items():
                extras[k][slot] = v
            self._live[slot] = True
            if self.speculate_k:
                # the draft pool never prefix-shares: prefill it for every
                # admitted slot, including full-hit boots the verifier
                # admits with zero prefill FLOPs
                self._catchup[slot] = False
                dadmit[slot] = True
                if dwp_flat is not None:
                    dbase = slot * self.n_prompt_pages
                    dpage0 = 1 + slot * self.pages_per_slot
                    for j in range(-(-L // T)):
                        dwp_flat[dbase + j] = dpage0 + j
            if self.pool is None:
                rows[slot, :L] = toks
                admit[slot] = True
                self._slots[slot] = _Slot(rid, L, req.max_tokens, req.eos_id)
                self._pos[slot] = L
                continue
            matched, full_hit, worst, row = plans[rid]
            self._pages[slot, :len(row)] = row
            self._slots[slot] = _Slot(rid, L, req.max_tokens, req.eos_id,
                                      worst=worst, mapped=len(row))
            self.stats["prefix_hits"] += bool(matched)
            self.stats["cached_tokens"] += len(matched) * T
            if full_hit:
                # zero-prefill admission: every needed prompt position but
                # (at most) the last is cached; bootstrap the slot from the
                # last prompt token — its first decode tick writes that
                # token's KV (or suppresses the write for one tick if even
                # it is cached) and samples the first output token.
                self.stats["zero_prefill_admits"] += 1
                self._pos[slot] = L - 1
                self._suppress[slot] = len(matched) * T == L
                boot.append((slot, int(toks[-1])))
                if self.speculate_k:
                    # the verifier merge ignores this row (admit stays
                    # False); the draft prefill still needs the prompt
                    rows[slot, :L] = toks
            else:
                rows[slot, :L] = toks
                admit[slot] = True
                self._pos[slot] = L
                base = slot * self.n_prompt_pages
                # prefill writes only the pages this slot OWNS: matched
                # prefix pages stay read-only (their bits are already
                # identical), the tail past ceil(L/T) stays dropped.
                for j in range(len(matched), len(row)):
                    wp_flat[base + j] = row[j]
        if self.pool is not None:
            self._note_pool()

        launched = bool(dadmit.any() if self.speculate_k else admit.any())
        if launched:
            batch = {"tokens": jnp.asarray(rows)}
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
            if self.speculate_k:
                args = (self.dparams, self.draft_dparams, batch,
                        jnp.asarray(lens), jnp.asarray(admit),
                        jnp.asarray(dadmit), self.tokens, self.caches,
                        self.draft_caches)
                if self.pool is not None:
                    args += (jnp.asarray(wp_flat), jnp.asarray(dwp_flat))
                self.tokens, self.caches, self.draft_caches = \
                    self._admit_fn(*args, self._next_key())
            else:
                args = (self.dparams, batch, jnp.asarray(lens),
                        jnp.asarray(admit), self.tokens, self.caches)
                if self.pool is not None:
                    args += (jnp.asarray(wp_flat),)
                self.tokens, self.caches = self._admit_fn(*args,
                                                          self._next_key())
            self.stats["prefill_launches"] += 1
            self.stats["useful_tokens"] += int(admit.sum())
        if boot:
            tok_np = np.asarray(self.tokens).copy()
            for slot, last in boot:
                tok_np[slot, 0] = last
            self.tokens = jnp.asarray(tok_np)
        tok_np = np.asarray(self.tokens[:, 0])
        for slot, rid in zip(free, take):
            if admit[slot]:
                self._record(slot, int(tok_np[slot]))
        return ({"kind": "prefill", "admitted": list(take)} if launched
                else {"kind": "cached", "admitted": list(take)})

    def _decode_tick(self) -> dict:
        live = self._live.copy()
        if self.pool is not None:
            # lazily map the page under each live slot's write position —
            # the reservation gate guarantees this allocation succeeds
            for slot in np.nonzero(live)[0]:
                pidx = int(self._pos[slot]) // self.page_size
                if self._pages[slot, pidx] == NULL_PAGE:
                    (pg,) = self.pool.alloc(1)
                    self._pages[slot, pidx] = pg
                    self._slots[slot].mapped += 1
                    self._reserved -= 1
            self._note_pool()
        live_write = live & ~self._suppress
        args = (self.dparams, self.tokens, self.caches,
                jnp.asarray(self._pos, jnp.int32), jnp.asarray(live_write))
        if self.pool is not None:
            args += (jnp.asarray(self._pages),)
        self.tokens, self.caches = self._step_fn(*args, self._next_key())
        self.stats["decode_launches"] += 1
        n_live = int(live.sum())
        self.stats["useful_tokens"] += n_live
        self.stats["occupancy_sum"] += n_live / self.max_slots
        self._pos[live] += 1
        self._suppress[live] = False
        tok_np = np.asarray(self.tokens[:, 0])
        for slot in np.nonzero(live)[0]:
            self._record(int(slot), int(tok_np[slot]))
        return {"kind": "decode", "live": n_live}

    def _drain_catchup(self, live: np.ndarray) -> None:
        """Feed every pending catch-up token to the draft at ``pos - 1`` in
        ONE batched draft launch (its logits predict a position already
        emitted — discarded): afterwards the draft ring covers every
        position below each slot's frontier."""
        mask = self._catchup & live
        if not mask.any():
            return
        toks = np.asarray(self.tokens).copy()
        toks[mask, 0] = self._catchup_tok[mask]
        pos = self._pos.copy()
        pos[mask] -= 1
        args = (self.draft_dparams, jnp.asarray(toks), self.draft_caches,
                jnp.asarray(pos, jnp.int32), jnp.asarray(mask))
        if self.pool is not None:
            args += (self._draft_pages,)
        _, _, self.draft_caches = self._draft_fn(*args, self._next_key())
        self.stats["draft_launches"] += 1
        self._catchup[mask] = False

    def _speculative_tick(self) -> dict:
        """One speculative round: [catch-up draft] + k draft launches + ONE
        (k+1)-wide verify launch; every live slot emits 1..k+1 tokens.

        The draft proposes d_1..d_k from the last emitted token t at
        position p (each single-token launch also writes the draft's KV);
        the verify launch feeds ``[t, d_1..d_k]`` at positions ``p..p+k``
        through the multi-token decode path and fuses rejection sampling
        (greedy: longest argmax-prefix match, so the emitted stream is the
        baseline verifier stream token for token — for ANY draft).  Both
        caches rewind by masked overwrite: rejected positions are above the
        new frontier, never read, and overwritten in order next round.

        Suppressed slots (full-prefix-hit boot, first tick) fall back to a
        baseline decode tick for everyone: their write position lives in a
        shared read-only radix page, which the W-wide batched scatter
        cannot skip per-position; the draft catches up next round.
        """
        live = self._live.copy()
        k = self.speculate_k
        if (live & self._suppress).any():
            self._drain_catchup(live)
            fed = {int(s): int(np.asarray(self.tokens)[s, 0])
                   for s in np.nonzero(live)[0]}
            out = self._decode_tick()
            for s, t in fed.items():
                if self._slots[s] is not None:  # draft missed this token
                    self._catchup[s] = True
                    self._catchup_tok[s] = t
            return out
        if self.pool is not None:
            # map every verifier page the verify scatter can land on (up to
            # the slot's write budget — beyond it the entries stay NULL and
            # the writes drop); all within the reserved worst-case pages,
            # so these allocations are guaranteed to succeed
            T = self.page_size
            for slot in np.nonzero(live)[0]:
                st = self._slots[slot]
                p = int(self._pos[slot])
                last = min(p + k, st.prompt_len + st.max_tokens - 2)
                for pidx in range(p // T, last // T + 1):
                    if self._pages[slot, pidx] == NULL_PAGE:
                        (pg,) = self.pool.alloc(1)
                        self._pages[slot, pidx] = pg
                        st.mapped += 1
                        self._reserved -= 1
            self._note_pool()
        self._drain_catchup(live)
        live_j = jnp.asarray(live)
        pos0 = self._pos.copy()
        cur = self.tokens
        dtoks, dlgs = [], []
        for j in range(k):
            args = (self.draft_dparams, cur, self.draft_caches,
                    jnp.asarray(pos0 + j, jnp.int32), live_j)
            if self.pool is not None:
                args += (self._draft_pages,)
            cur, row, self.draft_caches = self._draft_fn(*args,
                                                         self._next_key())
            self.stats["draft_launches"] += 1
            dtoks.append(cur)
            dlgs.append(row)
        draft_toks = jnp.concatenate(dtoks, axis=1)           # (B, k)
        draft_logits = jnp.stack(dlgs, axis=1)                # (B, k, V)
        tokens_w = jnp.concatenate([self.tokens, draft_toks], axis=1)
        args = (self.dparams, tokens_w, self.caches,
                jnp.asarray(pos0, jnp.int32), live_j)
        if self.pool is not None:
            args += (jnp.asarray(self._pages),)
        accepted, out_tokens, self.caches = self._verify_fn(
            *args, draft_toks, draft_logits, self._next_key())
        self.stats["verify_launches"] += 1
        self.stats["spec_rounds"] += 1
        acc = np.asarray(accepted)
        out_np = np.asarray(out_tokens)
        n_live = int(live.sum())
        self.stats["occupancy_sum"] += n_live / self.max_slots
        tok_np = np.asarray(self.tokens).copy()
        for slot in np.nonzero(live)[0]:
            m = int(acc[slot])
            self.stats["accepted_tokens"] += m
            for j in range(m + 1):
                self._record(int(slot), int(out_np[slot, j]))
                self.stats["useful_tokens"] += 1
                if self._slots[slot] is None:   # finished mid-round: the
                    break                       # rest of the window drops
            self._pos[slot] += m + 1
            if self._slots[slot] is not None:
                tok_np[slot, 0] = out_np[slot, m]
                if m == k:
                    # all accepted: the draft sampled d_k but never fed it
                    # — its KV at position p+k is owed before next round
                    self._catchup[slot] = True
                    self._catchup_tok[slot] = int(out_np[slot, k - 1])
        self.tokens = jnp.asarray(tok_np)
        return {"kind": "speculative", "live": n_live,
                "accepted": [int(a) for a in acc[live]]}

    def _record(self, slot: int, token: int) -> None:
        st = self._slots[slot]
        st.generated.append(token)
        done_len = len(st.generated) >= st.max_tokens
        done_eos = st.eos_id is not None and token == st.eos_id
        if done_len or done_eos:
            self._finished.append(RequestOutput(
                rid=st.rid, tokens=np.asarray(st.generated, np.int32),
                prompt_len=st.prompt_len,
                finish_reason="eos" if done_eos else "length"))
            if self.pool is not None:
                row = self._pages[slot]
                self.pool.release(int(p) for p in row if p != NULL_PAGE)
                self._pages[slot, :] = NULL_PAGE
                self._reserved -= st.worst - st.mapped
                self._note_pool()
            self._slots[slot] = None
            self._live[slot] = False
            self._catchup[slot] = False
            self._requests.pop(st.rid, None)

    # -- host failure / drain ------------------------------------------------
    def fail_host(self, host: int) -> None:
        """Stop beating ``host``; the heartbeat declares it dead after
        ``timeout_s`` ticks and ``step()`` drains its slots (failure
        injection for tests and ``launch/serve.py --fail-host``)."""
        if not 0 <= host < self.n_hosts:
            raise ValueError(f"host {host} outside fleet of {self.n_hosts}")
        self._failed_hosts.add(host)

    def _drain_host(self, host: int) -> None:
        """Retire a dead host's slots and requeue their requests.

        Pages go back through the normal refcount release path, the slots
        are excluded from future admission, and the drained requests
        rejoin the FRONT of the admission queue (rid order) so surviving
        hosts replay them from scratch — greedy decoding makes the replay
        token-identical to an uninterrupted run.
        """
        drained = []
        for slot in fault.owned_slots(host, self.max_slots, self.n_hosts):
            self._dead_slots[slot] = True
            st = self._slots[slot]
            if st is None:
                continue
            if self.pool is not None:
                row = self._pages[slot]
                self.pool.release(int(p) for p in row if p != NULL_PAGE)
                self._pages[slot, :] = NULL_PAGE
                self._reserved -= st.worst - st.mapped
                self._note_pool()
            self._slots[slot] = None
            self._live[slot] = False
            self._suppress[slot] = False
            self._catchup[slot] = False
            drained.append(st.rid)
        for rid in sorted(drained):
            self._pending[rid] = self._requests[rid]
        self.queue = sorted(drained) + [r for r in self.queue
                                        if r not in drained]
        self.stats["host_drains"] += 1
        self.stats["drained_requests"] += len(drained)
        if self._dead_slots.all() and (self.queue or self._live.any()):
            raise fault.HostFailure(host)

    # -- whole-trace driver --------------------------------------------------
    def run(self, requests: Sequence[Request],
            arrivals: Optional[Sequence[int]] = None
            ) -> Dict[object, RequestOutput]:
        """Serve a trace to completion; returns outputs keyed by the
        request's index in ``requests``.

        ``arrivals``: optional per-request arrival times in scheduler
        ticks (default: all at tick 0 — the synchronized case).  A request
        is submitted the first tick at/after its arrival; the loop runs
        idle ticks while waiting on future arrivals.

        Requests that were ``submit()``-ed directly before this call also
        finish under the loop; since they have no index in ``requests``,
        their outputs come back under the string key ``f"rid:{rid}"``
        instead of clashing with (or crashing on) the positional keys.
        """
        arrivals = ([0] * len(requests) if arrivals is None
                    else [int(a) for a in arrivals])
        if len(arrivals) != len(requests):
            raise ValueError("arrivals and requests length mismatch")
        order = sorted(range(len(requests)), key=lambda i: (arrivals[i], i))
        rid_to_idx: Dict[int, int] = {}
        outs: Dict[object, RequestOutput] = {}
        nxt, t = 0, 0
        while nxt < len(order) or self.has_work():
            while nxt < len(order) and arrivals[order[nxt]] <= t:
                i = order[nxt]
                rid_to_idx[self.submit(requests[i])] = i
                nxt += 1
            self.step()
            for out in self.collect():
                if out.rid in rid_to_idx:
                    outs[rid_to_idx[out.rid]] = out
                else:           # submitted before run(): key by request id
                    outs[f"rid:{out.rid}"] = out
            t += 1
        return outs
