"""`QTensor` — one typed, jit/vmap-capable mixed-precision tensor.

The Sec. III-C deploy transform of a searched linear map produces, per
weight, up to |P_W| fixed-precision channel groups (channels reordered so
each group is contiguous), packed sub-byte into uint8.  ``QTensor`` carries
exactly that:

* ``packed``   — tuple of ``(rows_b, ceil(c_in * b / 8))`` uint8 arrays, one
  per non-empty precision group, ascending bit-width;
* ``scales``   — tuple of ``(rows_b,)`` float32 per-channel dequant steps;
* ``inv_perm`` — ``(c_out,)`` int32 restoring the canonical output channel
  order; the static ``restore_order`` flag says whether ``matmul`` applies
  it (when False the consumer instead permutes the next layer's ``c_in`` —
  the paper's Fig. 2 transform, see
  :func:`repro.core.deploy.propagate_perm`);
* static aux: the ``bits`` tuple, logical ``(c_out, c_in)``, the layer-wise
  activation quantization (``act_bits``/``act_scale``) and, for convolution
  weights, the original kernel tail shape.

With ``tile_n`` set (tile-aligned deploy, the default of ``Engine.deploy``),
the QTensor additionally carries the **fused single-launch layout**: every
precision group's channel count is padded up to the ``tile_n`` output tile
(zero rows, zero scales), the per-group packed buffers concatenate into one
ragged 1-D byte buffer (``fused_packed``) with a static per-tile bit-width
schedule (``tile_bits``), and ``matmul``/``conv2d`` run the whole
multi-precision weight as ONE ``pallas_call``
(kernels/quant_matmul.quant_matmul_fused_2d) — no per-group launches, no
concat.  The schedule's tile walk order is chosen so that, whenever the
canonical-order restore is tile-granular (single precision group, or
already-sorted assignments), the restore folds into the kernel's identity
output index map and ``fused_perm`` is ``None``; otherwise ``fused_perm``
is a single output gather.

Because it is a **registered pytree** (arrays are leaves, geometry is aux
data), a whole deployed model is just a params tree with ``QTensor`` leaves:
it flows through ``jax.jit`` / ``jax.vmap`` / ``device_put`` unchanged, and
``matmul`` routes through the fused single-launch kernel
(``backend="pallas"``), the per-group reference kernels
(``backend="pallas-pergroup"``) or the jnp fallback.
``conv2d`` lowers an NHWC conv to im2col patches (kernels/quant_conv.py)
and delegates to ``matmul`` — the deployed conv path never materializes a
dense float kernel (depthwise convs take a grouped per-channel fall-back).

With ``experts`` set (static E; the MoE layout built by
``models/serving.init_deployed_linear(expert_axis=E)``), every array leaf
carries a leading expert axis — including the fused buffers, which then
share ONE static tile schedule across experts — and ``matmul`` maps
``(E, ..., c_in) -> (E, ..., c_out)`` as a batched grouped GEMM: the packed
replacement for ``einsum("ecd,efd->ecf", x, dense_stack)``, served as a
single expert-batched ``pallas_call`` under ``backend="pallas"``.

This replaces the old offline-only ``core.deploy.DeployedLinear`` numpy
holder; the search-time, fine-tune, and serving paths now share one type.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizers as qz
from repro.kernels import quant_matmul as qmk

BACKENDS = ("jnp", "pallas", "pallas-pergroup")


def _auto_tile_n(c_out: int) -> int:
    """Default output-tile width: the largest power of two <= c_out, capped
    at the 128-wide MXU lane dimension.  Small edge layers get small tiles
    (bounding the zero-row padding), large layers get full MXU tiles."""
    return min(128, 1 << (max(int(c_out), 1).bit_length() - 1))


def _fused_tile_layout(groups, tile_n: int, Kp: int, c_out: int,
                       restore_order: bool):
    """Build the single-launch fused layout from per-group integer weights.

    ``groups`` is a list of ``(bits, q (n_g, Kp) int8, step (n_g,),
    canon_idx (n_g,))`` in ascending bit-width.  Each group is padded to a
    ``tile_n`` multiple (zero rows / zero scales / target -1) and split into
    tiles; tiles are then ordered by the target position of their first
    (always real) row — canonical position when ``restore_order``, deployed
    position otherwise.  When that walk order lays every real channel at
    its target column with padding only past ``c_out``, the order restore
    has folded into the kernel's identity output index map and the returned
    ``fused_perm`` is None; otherwise it is the (c_out,) output gather.

    Returns ``(fused_packed 1-D uint8, fused_scales (T*tile_n,) f32,
    fused_perm, tile_bits)``.

    ``models/serving.init_deployed_linear`` carries a traced-safe sibling
    of this builder (jnp ops, schedule from static group sizes only, an
    optional expert axis) for the vmap'd serving init — the two emit the
    same layout contract (see the NOTE there); keep them in sync.
    """
    tiles = []
    dep_start = 0
    for b, q, step, idx in groups:
        n = q.shape[0]
        assert q.shape[1] == Kp, (q.shape, Kp)
        pad = (-n) % tile_n
        qp = np.pad(np.asarray(q, np.int8), ((0, pad), (0, 0)))
        sp = np.pad(np.asarray(step, np.float32).reshape(-1), (0, pad))
        tgt = (np.asarray(idx, np.int64) if restore_order
               else np.arange(dep_start, dep_start + n, dtype=np.int64))
        tgt = np.concatenate([tgt, np.full(pad, -1, np.int64)])
        dep_start += n
        for t0 in range(0, n + pad, tile_n):
            sl = slice(t0, t0 + tile_n)
            tiles.append((b, qp[sl], sp[sl], tgt[sl]))
    tiles.sort(key=lambda t: int(t[3][0]))
    tile_bits = tuple(t[0] for t in tiles)
    fused_packed = np.concatenate(
        [np.asarray(qz.pack_int(jnp.asarray(q), b)).reshape(-1)
         for b, q, _, _ in tiles])
    fused_scales = np.concatenate([t[2] for t in tiles])
    tcol = np.concatenate([t[3] for t in tiles])
    if (tcol[:c_out] == np.arange(c_out)).all() and (tcol[c_out:] < 0).all():
        fused_perm = None                   # restore folded into the walk
    else:
        cols = np.nonzero(tcol >= 0)[0].astype(np.int32)
        fp = np.zeros(c_out, np.int32)
        fp[tcol[cols]] = cols
        fused_perm = jnp.asarray(fp)
    return (jnp.asarray(fused_packed), jnp.asarray(fused_scales),
            fused_perm, tile_bits)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    packed: tuple                 # tuple[jnp.ndarray] uint8, per group
    scales: tuple                 # tuple[jnp.ndarray] f32,  per group
    inv_perm: Optional[jnp.ndarray]   # (c_out,) i32; None = identity
    bits: tuple                   # static: ascending bit-widths, len==len(packed)
    c_out: int
    c_in: int                     # logical contraction dim (pre-padding)
    act_bits: int = 8
    act_scale: float = 1.0
    kernel_shape: Optional[tuple] = None   # conv tail (c_in/g, kh, kw)
    restore_order: bool = True    # matmul outputs canonical channel order
    # -- fused single-launch layout (tile-aligned deploy; None = absent) ----
    fused_packed: Optional[jnp.ndarray] = None   # 1-D uint8 ragged buffer
    fused_scales: Optional[jnp.ndarray] = None   # (T * tile_n,) f32
    fused_perm: Optional[jnp.ndarray] = None     # (c_out,) i32 output gather;
    #                                              None = restore folded into
    #                                              the tile walk order
    tile_bits: Optional[tuple] = None            # static per-tile bit-widths
    tile_n: Optional[int] = None                 # static output tile width
    # -- expert stacking (MoE) ---------------------------------------------
    experts: Optional[int] = None   # static E: every array leaf carries a
    #                                 leading expert axis and matmul maps
    #                                 (E, ..., c_in) -> (E, ..., c_out) as a
    #                                 batched grouped GEMM (one launch)

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        children = (
            (jax.tree_util.GetAttrKey("packed"), self.packed),
            (jax.tree_util.GetAttrKey("scales"), self.scales),
            (jax.tree_util.GetAttrKey("inv_perm"), self.inv_perm),
            (jax.tree_util.GetAttrKey("fused_packed"), self.fused_packed),
            (jax.tree_util.GetAttrKey("fused_scales"), self.fused_scales),
            (jax.tree_util.GetAttrKey("fused_perm"), self.fused_perm),
        )
        aux = (self.bits, self.c_out, self.c_in, self.act_bits,
               self.act_scale, self.kernel_shape, self.restore_order,
               self.tile_bits, self.tile_n, self.experts)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales, inv_perm, fused_packed, fused_scales, fperm = children
        (bits, c_out, c_in, act_bits, act_scale, kernel_shape,
         restore_order, tile_bits, tile_n, experts) = aux
        return cls(packed, scales, inv_perm, bits, c_out, c_in,
                   act_bits, act_scale, kernel_shape, restore_order,
                   fused_packed, fused_scales, fperm, tile_bits, tile_n,
                   experts)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_assignment(cls, w, bits_per_channel, alpha_w,
                        bitwidths=(2, 4, 8), align: int = 1,
                        restore_order: bool = True,
                        act_bits: int = 8, act_scale: float = 1.0,
                        tile_n=None) -> "QTensor":
        """Pack a float weight under an explicit per-channel assignment.

        ``w`` is ``(c_out, ...)``; trailing dims flatten into the contraction
        axis (conv kernels keep their tail shape for ``dense()``).

        ``tile_n`` enables the tile-aligned fused single-launch layout: an
        int pins the output tile width, ``"auto"`` picks the largest power
        of two ``<= c_out`` (capped at 128), ``None`` (default) packs only
        the per-group buffers.  With a fused layout the per-group buffers
        are packed at the common ``Kp`` byte width (c_in rounded up to the
        int2 pack factor) so the per-group reference path reduces the exact
        same K columns as the fused kernel — the bit-exactness contract.
        Contractions beyond ``K_SINGLE_STEP_MAX`` stay per-group (the fused
        kernel runs K as a single step).
        """
        from repro.core import deploy as dpl   # local: avoid import cycle
        w = np.asarray(w, np.float32)
        kernel_shape = tuple(w.shape[1:]) if w.ndim > 2 else None
        w2 = w.reshape(w.shape[0], -1)
        c_out, c_in = w2.shape
        bits_per_channel = np.asarray(bits_per_channel)
        alpha = np.asarray(alpha_w, np.float32)
        if alpha.ndim == 0:
            alpha = np.broadcast_to(alpha, (c_out,)).copy()
        perm, sizes = dpl.group_channels(bits_per_channel, bitwidths,
                                         align=align)
        if tile_n == "auto":
            tile_n = _auto_tile_n(c_out)
        Kp = -(-c_in // qmk.FUSED_K_ALIGN) * qmk.FUSED_K_ALIGN
        if tile_n is not None and Kp > qmk.K_SINGLE_STEP_MAX:
            tile_n = None                  # contraction too deep to fuse
        packed, scales, used_bits, groups = [], [], [], []
        offset = 0
        for b in sorted(bitwidths):
            n = sizes[b]
            if n == 0:
                continue
            idx = perm[offset: offset + n]
            offset += n
            q, step = qz.quantize_weight_int(
                jnp.asarray(w2[idx]), jnp.asarray(alpha[idx][:, None]), b)
            q = np.asarray(q)
            f = qz.pack_factor(b)
            kpad = Kp if tile_n is not None else -(-c_in // f) * f
            q = np.pad(q, ((0, 0), (0, kpad - c_in)))
            packed.append(jnp.asarray(qz.pack_int(jnp.asarray(q), b)))
            scales.append(jnp.asarray(step).reshape(-1).astype(jnp.float32))
            used_bits.append(b)
            groups.append((b, q, np.asarray(step).reshape(-1), idx))
        inv_perm = jnp.asarray(np.argsort(perm), jnp.int32)
        fused = dict(fused_packed=None, fused_scales=None, fused_perm=None,
                     tile_bits=None, tile_n=None)
        if tile_n is not None:
            fp, fs, fperm, tile_bits = _fused_tile_layout(
                groups, tile_n, Kp, c_out, restore_order)
            fused = dict(fused_packed=fp, fused_scales=fs, fused_perm=fperm,
                         tile_bits=tile_bits, tile_n=tile_n)
        return cls(tuple(packed), tuple(scales), inv_perm,
                   tuple(used_bits), c_out, c_in,
                   act_bits=act_bits, act_scale=act_scale,
                   kernel_shape=kernel_shape, restore_order=restore_order,
                   **fused)

    # -- geometry -----------------------------------------------------------
    @property
    def group_sizes(self) -> dict:
        return {b: p.shape[-2] for b, p in zip(self.bits, self.packed)}

    @property
    def perm(self) -> np.ndarray:
        """Deployed channel order (original index per deployed row)."""
        if self.inv_perm is None:
            return np.arange(self.c_out)
        return np.argsort(np.asarray(self.inv_perm))

    @property
    def memory_bits(self) -> int:
        """Deployed model-size contribution in bits (the Pareto x-axis).

        With a fused layout this is the ragged single-launch buffer — the
        weight bytes a deployed edge artifact ships, tile padding (zero
        rows up to ``tile_n``, K rounded to the int2 pack factor) included.
        Without one it is the per-group packed bytes, as before.  Note this
        models the *deployment* footprint: in-repo a tile-aligned QTensor
        additionally keeps the per-group buffers as live leaves (they back
        the ``pallas-pergroup``/``jnp`` reference paths, the depthwise
        fall-back and ``dequantize``), so host/device memory of this
        development representation is roughly double the reported figure.
        """
        if self.fused_packed is not None:
            return int(self.fused_packed.size) * 8
        return sum(int(p.size) * 8 for p in self.packed)

    # -- compute ------------------------------------------------------------
    def _group_dense(self, b: int, p: jnp.ndarray, s: jnp.ndarray,
                     compute_dtype) -> jnp.ndarray:
        """Unpack + dequant ONE precision group to ``(rows_b, c_in)`` — the
        jnp fall-back's small per-group materialization (never the whole
        canonical weight)."""
        w_int = qz.unpack_int(p, b)[..., : self.c_in]
        return (w_int.astype(jnp.float32) * s[..., None]).astype(compute_dtype)

    def _concat_restore(self, outs: list) -> jnp.ndarray:
        """Concat per-precision group outputs (deployed channel order) and
        restore canonical order — the single tail shared by ``matmul`` and
        both ``conv2d`` paths so the backends/layouts cannot drift."""
        y = jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
        if self.restore_order and self.inv_perm is not None:
            y = jnp.take(y, self.inv_perm, axis=-1)
        return y

    def _dequantize_groups(self) -> jnp.ndarray:
        """Float weight stack in **deployed** (group-contiguous) order."""
        outs = [self._group_dense(b, p, s, jnp.float32)
                for b, p, s in zip(self.bits, self.packed, self.scales)]
        return jnp.concatenate(outs, axis=-2) if len(outs) > 1 else outs[0]

    def dequantize_canonical(self, dtype=jnp.float32) -> jnp.ndarray:
        """Float ``(c_out, c_in)`` in canonical channel order regardless of
        ``restore_order`` — the analysis/reference view (tests, Pareto)."""
        w = self._dequantize_groups()
        if self.inv_perm is not None:
            w = jnp.take(w, self.inv_perm, axis=-2)
        return w.astype(dtype)

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        """Float ``(c_out, c_in)`` view in the same channel order ``matmul``
        produces: canonical when ``restore_order`` (the default), deployed
        (group-contiguous) otherwise — so dense-view consumers always agree
        with the packed runtime path."""
        w = self._dequantize_groups()
        if self.restore_order and self.inv_perm is not None:
            w = jnp.take(w, self.inv_perm, axis=-2)
        return w.astype(dtype)

    def dense(self, dtype=jnp.float32) -> jnp.ndarray:
        """``dequantize`` with the conv kernel tail restored."""
        w = self.dequantize(dtype)
        if self.kernel_shape is not None:
            w = w.reshape((self.c_out,) + self.kernel_shape)
        return w

    def matmul(self, x: jnp.ndarray, compute_dtype=jnp.float32,
               backend: str = "jnp") -> jnp.ndarray:
        """``x (..., c_in) -> (..., c_out)`` on one of three backends:

        * ``"pallas"`` — the serving hot path: with a fused layout (tile-
          aligned deploy) the whole multi-precision weight runs as ONE
          ``pallas_call`` (kernels/quant_matmul.quant_matmul_fused_2d), the
          order restore folded into the tile schedule (or a single output
          gather); without one it falls back to the per-group kernels.
        * ``"pallas-pergroup"`` — the per-group reference path: one
          unpack+dequant+GEMM kernel launch per precision group, outputs
          concatenated (the paper's parallel sub-convolutions), then the
          canonical-order restore when ``restore_order``.
        * ``"jnp"`` — per-group dense fallback (no Pallas).

        This method owns the routing and the concat/restore so the
        backends cannot drift.  ``compute_dtype`` reaches the kernel's MXU
        dot as well as the output cast: f32 (the default) is the bit-parity
        path with the fake-quant reference, bf16 the TPU fast path.

        An **expert-stacked** QTensor (``experts == E``; MoE weight stacks
        from ``serving.init_deployed_linear(expert_axis=E)``) instead maps
        ``x (E, ..., c_in) -> (E, ..., c_out)`` per expert — the packed
        form of ``einsum("ecd,efd->ecf", x, dense_stack)``; with a fused
        layout the whole grouped GEMM is ONE expert-batched launch.
        """
        if x.shape[-1] != self.c_in:
            raise ValueError(
                f"x contraction dim {x.shape[-1]} != c_in {self.c_in} "
                "(all backends reject this — the Pallas kernel would "
                "otherwise zero-pad and compute silently wrong outputs)")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        if self.experts is not None:
            return self._matmul_experts(x, compute_dtype, backend)
        if backend == "pallas" and self.fused_packed is not None:
            from repro.dist import sharding as shd
            from repro.kernels import ops as kops
            ctx = shd.serving_ctx()
            if ctx is not None and ctx.model > 1:
                chunk = qmk.tp_chunk(self.tile_bits, ctx.model)
                if chunk is not None:
                    return kops.quant_matmul_fused_tp(
                        x, self.fused_packed, self.fused_scales,
                        self.fused_perm, self.tile_bits, chunk, self.tile_n,
                        self.c_in, self.c_out, ctx.mesh,
                        out_dtype=compute_dtype, compute_dtype=compute_dtype)
            return kops.quant_matmul_fused(
                x, self.fused_packed, self.fused_scales, self.fused_perm,
                self.tile_bits, self.tile_n, self.c_in, self.c_out,
                out_dtype=compute_dtype, compute_dtype=compute_dtype)
        if backend in ("pallas", "pallas-pergroup"):
            from repro.kernels import ops as kops
            c_in = self.c_in
            if self.tile_n is not None:
                # fused-layout per-group buffers are packed at the common
                # Kp byte width: feed the kernel the same padded columns
                Kp = self.packed[-1].shape[-1] * qz.pack_factor(self.bits[-1])
                widths = [(0, 0)] * (x.ndim - 1) + [(0, Kp - c_in)]
                x = jnp.pad(x, widths)
                c_in = Kp

            def gemm(b, p, s):
                return kops.quant_matmul(x, p, s, b, c_in,
                                         out_dtype=compute_dtype,
                                         compute_dtype=compute_dtype)
        else:
            def gemm(b, p, s):
                w = self._group_dense(b, p, s, compute_dtype)
                return jnp.einsum("...i,oi->...o", x.astype(compute_dtype), w)
        outs = [gemm(b, p, s)
                for b, p, s in zip(self.bits, self.packed, self.scales)]
        return self._concat_restore(outs)

    def _matmul_experts(self, x: jnp.ndarray, compute_dtype,
                        backend: str) -> jnp.ndarray:
        """Stacked-leaf (MoE) dispatch: ``x (E, ..., c_in) -> (E, ...,
        c_out)``, each expert contracting its own packed weight.

        ``backend="pallas"`` with a fused layout runs the whole grouped
        GEMM as ONE expert-batched launch
        (kernels/ops.quant_matmul_fused_batched — bit-exact at f32 with
        the dense einsum reference); otherwise the per-group kernels run
        per expert (the reference path), or the jnp fall-back contracts
        each group's small dense slice with a batched einsum.  The serving
        hot path never dequantizes the full ``(E, c_out, c_in)`` stack.
        """
        E = self.experts
        if x.ndim < 2 or x.shape[0] != E:
            raise ValueError(
                f"expert-stacked QTensor (experts={E}) takes x of shape "
                f"(E, ..., c_in); got {x.shape}")
        if backend == "pallas" and self.fused_packed is not None:
            from repro.dist import sharding as shd
            from repro.kernels import ops as kops
            ctx = shd.serving_ctx()
            if ctx is not None and ctx.model > 1 and E % ctx.model == 0:
                return kops.quant_matmul_fused_batched_ep(
                    x, self.fused_packed, self.fused_scales, self.fused_perm,
                    self.tile_bits, self.tile_n, self.c_in, self.c_out,
                    ctx.mesh, out_dtype=compute_dtype,
                    compute_dtype=compute_dtype)
            return kops.quant_matmul_fused_batched(
                x, self.fused_packed, self.fused_scales, self.fused_perm,
                self.tile_bits, self.tile_n, self.c_in, self.c_out,
                out_dtype=compute_dtype, compute_dtype=compute_dtype)
        if backend in ("pallas", "pallas-pergroup"):
            from repro.kernels import ops as kops
            c_in = self.c_in
            if self.tile_n is not None:
                Kp = self.packed[-1].shape[-1] * qz.pack_factor(self.bits[-1])
                widths = [(0, 0)] * (x.ndim - 1) + [(0, Kp - c_in)]
                x = jnp.pad(x, widths)
                c_in = Kp

            def gemm(b, p, s):
                return jnp.stack([
                    kops.quant_matmul(x[e], p[e], s[e], b, c_in,
                                      out_dtype=compute_dtype,
                                      compute_dtype=compute_dtype)
                    for e in range(E)])
        else:
            def gemm(b, p, s):
                w = self._group_dense(b, p, s, compute_dtype)  # (E, n, c_in)
                return jnp.einsum("e...i,eoi->e...o",
                                  x.astype(compute_dtype), w)
        outs = [gemm(b, p, s)
                for b, p, s in zip(self.bits, self.packed, self.scales)]
        return self._concat_restore(outs)

    def conv2d(self, x: jnp.ndarray, stride=1, padding: str = "SAME",
               groups: int = 1, compute_dtype=jnp.float32,
               backend: str = "jnp") -> jnp.ndarray:
        """NHWC conv ``x (N, H, W, C) -> (N, Ho, Wo, c_out)`` fully packed.

        The deployed realization of the paper's parallel per-precision
        sub-convolutions: the input is lowered to im2col patches once
        (feature axis channel-major — the exact ``(c_out, c_in*kh*kw)``
        contraction layout this QTensor packs), then **delegates to**
        :meth:`matmul`, so the per-group sub-GEMMs, Pallas/jnp backend
        split, concat and canonical-order restore are one code path for
        linear and conv and cannot drift.  No dense float kernel is ever
        materialized.

        Depthwise weights (``groups == c_out``, kernel tail ``(1, kh, kw)``
        — DS-CNN/MobileNetV1 ``dwconv``) contract only the ``kh*kw`` taps of
        their own channel, which is not a single GEMM; they take the grouped
        fall-back below: per-precision-group gather of the channel-major
        patches + a tiny ``(rows, kh*kw)`` group unpack (the same amount the
        jnp matmul fall-back unpacks), identical for both backends.
        """
        if self.kernel_shape is None:
            raise TypeError("conv2d requires a conv QTensor "
                            "(kernel_shape is None — this is a linear map)")
        if self.experts is not None:
            raise TypeError("conv2d does not take expert-stacked QTensors "
                            "(the expert axis is a linear-map concept)")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        from repro.kernels import quant_conv as qc

        kh, kw = self.kernel_shape[-2:]
        if groups == 1:
            patches = qc.im2col(x, kh, kw, stride, padding)
            return self.matmul(patches, compute_dtype, backend)
        if groups != self.c_out or self.kernel_shape[0] != 1 \
                or x.shape[-1] != groups:
            raise NotImplementedError(
                f"grouped conv with groups={groups} (c_out={self.c_out}, "
                f"kernel_shape={self.kernel_shape}): only groups=1 and "
                "depthwise (groups == c_out, tail (1, kh, kw)) are packed")
        # -- depthwise fall-back: per-channel tap contraction ---------------
        patches = qc.depthwise_patches(x, kh, kw, stride, padding)
        if self.inv_perm is not None:
            # gather input channels into deployed (group-contiguous) order;
            # traced-safe (jnp.argsort, not the numpy .perm property)
            patches = jnp.take(patches, jnp.argsort(self.inv_perm), axis=-2)
        outs, offset = [], 0
        for b, p, s in zip(self.bits, self.packed, self.scales):
            rows = p.shape[-2]
            w = self._group_dense(b, p, s, compute_dtype)   # (rows, kh*kw)
            seg = patches[..., offset: offset + rows, :].astype(compute_dtype)
            outs.append(jnp.einsum("...ck,ck->...c", seg, w))
            offset += rows
        return self._concat_restore(outs)


def requantize(qt: QTensor, bits: int) -> QTensor:
    """Re-quantize a deployed QTensor to a uniform ``bits`` assignment.

    The one-checkpoint-many-precisions derivation behind speculative
    drafting (models/serving.draft_model): dequantize the searched deploy
    back to its canonical float view, then re-pack every channel at the
    single aggressive ``bits`` with fresh per-channel amax clipping — a new
    static assignment, NOT a lossy cast of the packed bytes.  Layer-stacked
    (scan) and expert-stacked (MoE) leaves round-trip: leading stack axes
    are rebuilt slice by slice offline and restacked, preserving the shared
    static tile schedule, and ``experts`` is restored on the result.  The
    fused single-launch layout, ``restore_order``, activation quantization
    and conv kernel tail all carry over.
    """
    if bits not in (2, 4, 8):
        raise ValueError(f"requantize bits must be one of (2, 4, 8); "
                         f"got {bits}")
    deq = lambda t: t.dequantize_canonical(jnp.float32)
    for _ in range(qt.packed[0].ndim - 2):      # layer/expert stack axes
        deq = jax.vmap(deq)
    w = np.asarray(deq(qt))                     # (*stack, c_out, c_in)
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])
    assign = np.full(qt.c_out, bits, np.int64)
    rebuilt = []
    for i in range(flat.shape[0]):
        wi = flat[i]
        alpha = np.maximum(np.max(np.abs(wi), axis=1), 1e-8)
        if qt.kernel_shape is not None:
            wi = wi.reshape((qt.c_out,) + qt.kernel_shape)
        rebuilt.append(QTensor.from_assignment(
            wi, assign, alpha, bitwidths=(2, 4, 8),
            restore_order=qt.restore_order, act_bits=qt.act_bits,
            act_scale=qt.act_scale, tile_n=qt.tile_n))
    if not lead:
        return rebuilt[0]
    out = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls).reshape(lead + ls[0].shape), *rebuilt)
    if qt.experts is not None:
        out = dataclasses.replace(out, experts=qt.experts)
    return out
