"""`QTensor` — one typed, jit/vmap-capable mixed-precision tensor.

The Sec. III-C deploy transform of a searched linear map produces, per
weight, up to |P_W| fixed-precision channel groups (channels reordered so
each group is contiguous), packed sub-byte into uint8.  ``QTensor`` carries
exactly that:

* ``packed``   — tuple of ``(rows_b, ceil(c_in * b / 8))`` uint8 arrays, one
  per non-empty precision group, ascending bit-width;
* ``scales``   — tuple of ``(rows_b,)`` float32 per-channel dequant steps;
* ``inv_perm`` — ``(c_out,)`` int32 restoring the canonical output channel
  order; the static ``restore_order`` flag says whether ``matmul`` applies
  it (when False the consumer instead permutes the next layer's ``c_in`` —
  the paper's Fig. 2 transform, see
  :func:`repro.core.deploy.propagate_perm`);
* static aux: the ``bits`` tuple, logical ``(c_out, c_in)``, the layer-wise
  activation quantization (``act_bits``/``act_scale``) and, for convolution
  weights, the original kernel tail shape.

Because it is a **registered pytree** (arrays are leaves, geometry is aux
data), a whole deployed model is just a params tree with ``QTensor`` leaves:
it flows through ``jax.jit`` / ``jax.vmap`` / ``device_put`` unchanged, and
``matmul`` routes each precision group through the Pallas
``quant_matmul`` kernel (``backend="pallas"``) or the jnp fallback.

This replaces the old offline-only ``core.deploy.DeployedLinear`` numpy
holder; the search-time, fine-tune, and serving paths now share one type.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizers as qz


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    packed: tuple                 # tuple[jnp.ndarray] uint8, per group
    scales: tuple                 # tuple[jnp.ndarray] f32,  per group
    inv_perm: Optional[jnp.ndarray]   # (c_out,) i32; None = identity
    bits: tuple                   # static: ascending bit-widths, len==len(packed)
    c_out: int
    c_in: int                     # logical contraction dim (pre-padding)
    act_bits: int = 8
    act_scale: float = 1.0
    kernel_shape: Optional[tuple] = None   # conv tail (c_in/g, kh, kw)
    restore_order: bool = True    # matmul outputs canonical channel order

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        children = (
            (jax.tree_util.GetAttrKey("packed"), self.packed),
            (jax.tree_util.GetAttrKey("scales"), self.scales),
            (jax.tree_util.GetAttrKey("inv_perm"), self.inv_perm),
        )
        aux = (self.bits, self.c_out, self.c_in, self.act_bits,
               self.act_scale, self.kernel_shape, self.restore_order)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales, inv_perm = children
        return cls(packed, scales, inv_perm, *aux)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_assignment(cls, w, bits_per_channel, alpha_w,
                        bitwidths=(2, 4, 8), align: int = 1,
                        restore_order: bool = True,
                        act_bits: int = 8, act_scale: float = 1.0
                        ) -> "QTensor":
        """Pack a float weight under an explicit per-channel assignment.

        ``w`` is ``(c_out, ...)``; trailing dims flatten into the contraction
        axis (conv kernels keep their tail shape for ``dense()``).
        """
        from repro.core import deploy as dpl   # local: avoid import cycle
        w = np.asarray(w, np.float32)
        kernel_shape = tuple(w.shape[1:]) if w.ndim > 2 else None
        w2 = w.reshape(w.shape[0], -1)
        c_out, c_in = w2.shape
        bits_per_channel = np.asarray(bits_per_channel)
        alpha = np.asarray(alpha_w, np.float32)
        if alpha.ndim == 0:
            alpha = np.broadcast_to(alpha, (c_out,)).copy()
        perm, sizes = dpl.group_channels(bits_per_channel, bitwidths,
                                         align=align)
        packed, scales, used_bits = [], [], []
        offset = 0
        for b in sorted(bitwidths):
            n = sizes[b]
            if n == 0:
                continue
            idx = perm[offset: offset + n]
            offset += n
            q, step = qz.quantize_weight_int(
                jnp.asarray(w2[idx]), jnp.asarray(alpha[idx][:, None]), b)
            q = np.asarray(q)
            f = qz.pack_factor(b)
            if c_in % f:
                q = np.pad(q, ((0, 0), (0, f - c_in % f)))
            packed.append(jnp.asarray(qz.pack_int(jnp.asarray(q), b)))
            scales.append(jnp.asarray(step).reshape(-1).astype(jnp.float32))
            used_bits.append(b)
        inv_perm = jnp.asarray(np.argsort(perm), jnp.int32)
        return cls(tuple(packed), tuple(scales), inv_perm,
                   tuple(used_bits), c_out, c_in,
                   act_bits=act_bits, act_scale=act_scale,
                   kernel_shape=kernel_shape, restore_order=restore_order)

    # -- geometry -----------------------------------------------------------
    @property
    def group_sizes(self) -> dict:
        return {b: p.shape[-2] for b, p in zip(self.bits, self.packed)}

    @property
    def perm(self) -> np.ndarray:
        """Deployed channel order (original index per deployed row)."""
        if self.inv_perm is None:
            return np.arange(self.c_out)
        return np.argsort(np.asarray(self.inv_perm))

    @property
    def memory_bits(self) -> int:
        """Deployed model-size contribution in bits (the Pareto x-axis)."""
        return sum(int(p.size) * 8 for p in self.packed)

    # -- compute ------------------------------------------------------------
    def _dequantize_groups(self) -> jnp.ndarray:
        """Float weight stack in **deployed** (group-contiguous) order."""
        outs = []
        for b, p, s in zip(self.bits, self.packed, self.scales):
            w_int = qz.unpack_int(p, b)[..., : self.c_in]
            outs.append(w_int.astype(jnp.float32) * s[..., None])
        return jnp.concatenate(outs, axis=-2) if len(outs) > 1 else outs[0]

    def dequantize_canonical(self, dtype=jnp.float32) -> jnp.ndarray:
        """Float ``(c_out, c_in)`` in canonical channel order regardless of
        ``restore_order`` — the analysis/reference view (tests, Pareto)."""
        w = self._dequantize_groups()
        if self.inv_perm is not None:
            w = jnp.take(w, self.inv_perm, axis=-2)
        return w.astype(dtype)

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        """Float ``(c_out, c_in)`` view in the same channel order ``matmul``
        produces: canonical when ``restore_order`` (the default), deployed
        (group-contiguous) otherwise — so dense-view consumers always agree
        with the packed runtime path."""
        w = self._dequantize_groups()
        if self.restore_order and self.inv_perm is not None:
            w = jnp.take(w, self.inv_perm, axis=-2)
        return w.astype(dtype)

    def dense(self, dtype=jnp.float32) -> jnp.ndarray:
        """``dequantize`` with the conv kernel tail restored."""
        w = self.dequantize(dtype)
        if self.kernel_shape is not None:
            w = w.reshape((self.c_out,) + self.kernel_shape)
        return w

    def matmul(self, x: jnp.ndarray, compute_dtype=jnp.float32,
               backend: str = "jnp") -> jnp.ndarray:
        """``x (..., c_in) -> (..., c_out)``: per-precision sub-GEMMs whose
        outputs concatenate (the paper's parallel sub-convolutions), then the
        canonical-order restore when ``restore_order``.  ``backend="pallas"``
        runs each sub-GEMM through the fused unpack+dequant+GEMM kernel
        (kernels/quant_matmul.py); this method owns the concat/restore so the
        two backends cannot drift."""
        if backend == "pallas":
            from repro.kernels import ops as kops

            def gemm(b, p, s):
                return kops.quant_matmul(x, p, s, b, self.c_in, compute_dtype)
        else:
            def gemm(b, p, s):
                w_int = qz.unpack_int(p, b)[..., : self.c_in]
                w = (w_int.astype(jnp.float32)
                     * s[..., None]).astype(compute_dtype)
                return jnp.einsum("...i,oi->...o", x.astype(compute_dtype), w)
        outs = [gemm(b, p, s)
                for b, p, s in zip(self.bits, self.packed, self.scales)]
        y = jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
        if self.restore_order and self.inv_perm is not None:
            y = jnp.take(y, self.inv_perm, axis=-1)
        return y
