"""`QTensor` — one typed, jit/vmap-capable mixed-precision tensor.

The Sec. III-C deploy transform of a searched linear map produces, per
weight, up to |P_W| fixed-precision channel groups (channels reordered so
each group is contiguous), packed sub-byte into uint8.  ``QTensor`` carries
exactly that:

* ``packed``   — tuple of ``(rows_b, ceil(c_in * b / 8))`` uint8 arrays, one
  per non-empty precision group, ascending bit-width;
* ``scales``   — tuple of ``(rows_b,)`` float32 per-channel dequant steps;
* ``inv_perm`` — ``(c_out,)`` int32 restoring the canonical output channel
  order; the static ``restore_order`` flag says whether ``matmul`` applies
  it (when False the consumer instead permutes the next layer's ``c_in`` —
  the paper's Fig. 2 transform, see
  :func:`repro.core.deploy.propagate_perm`);
* static aux: the ``bits`` tuple, logical ``(c_out, c_in)``, the layer-wise
  activation quantization (``act_bits``/``act_scale``) and, for convolution
  weights, the original kernel tail shape.

Because it is a **registered pytree** (arrays are leaves, geometry is aux
data), a whole deployed model is just a params tree with ``QTensor`` leaves:
it flows through ``jax.jit`` / ``jax.vmap`` / ``device_put`` unchanged, and
``matmul`` routes each precision group through the Pallas
``quant_matmul`` kernel (``backend="pallas"``) or the jnp fallback.
``conv2d`` lowers an NHWC conv to im2col patches (kernels/quant_conv.py)
and delegates to ``matmul`` — the deployed conv path never materializes a
dense float kernel (depthwise convs take a grouped per-channel fall-back).

This replaces the old offline-only ``core.deploy.DeployedLinear`` numpy
holder; the search-time, fine-tune, and serving paths now share one type.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizers as qz


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    packed: tuple                 # tuple[jnp.ndarray] uint8, per group
    scales: tuple                 # tuple[jnp.ndarray] f32,  per group
    inv_perm: Optional[jnp.ndarray]   # (c_out,) i32; None = identity
    bits: tuple                   # static: ascending bit-widths, len==len(packed)
    c_out: int
    c_in: int                     # logical contraction dim (pre-padding)
    act_bits: int = 8
    act_scale: float = 1.0
    kernel_shape: Optional[tuple] = None   # conv tail (c_in/g, kh, kw)
    restore_order: bool = True    # matmul outputs canonical channel order

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        children = (
            (jax.tree_util.GetAttrKey("packed"), self.packed),
            (jax.tree_util.GetAttrKey("scales"), self.scales),
            (jax.tree_util.GetAttrKey("inv_perm"), self.inv_perm),
        )
        aux = (self.bits, self.c_out, self.c_in, self.act_bits,
               self.act_scale, self.kernel_shape, self.restore_order)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales, inv_perm = children
        return cls(packed, scales, inv_perm, *aux)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_assignment(cls, w, bits_per_channel, alpha_w,
                        bitwidths=(2, 4, 8), align: int = 1,
                        restore_order: bool = True,
                        act_bits: int = 8, act_scale: float = 1.0
                        ) -> "QTensor":
        """Pack a float weight under an explicit per-channel assignment.

        ``w`` is ``(c_out, ...)``; trailing dims flatten into the contraction
        axis (conv kernels keep their tail shape for ``dense()``).
        """
        from repro.core import deploy as dpl   # local: avoid import cycle
        w = np.asarray(w, np.float32)
        kernel_shape = tuple(w.shape[1:]) if w.ndim > 2 else None
        w2 = w.reshape(w.shape[0], -1)
        c_out, c_in = w2.shape
        bits_per_channel = np.asarray(bits_per_channel)
        alpha = np.asarray(alpha_w, np.float32)
        if alpha.ndim == 0:
            alpha = np.broadcast_to(alpha, (c_out,)).copy()
        perm, sizes = dpl.group_channels(bits_per_channel, bitwidths,
                                         align=align)
        packed, scales, used_bits = [], [], []
        offset = 0
        for b in sorted(bitwidths):
            n = sizes[b]
            if n == 0:
                continue
            idx = perm[offset: offset + n]
            offset += n
            q, step = qz.quantize_weight_int(
                jnp.asarray(w2[idx]), jnp.asarray(alpha[idx][:, None]), b)
            q = np.asarray(q)
            f = qz.pack_factor(b)
            if c_in % f:
                q = np.pad(q, ((0, 0), (0, f - c_in % f)))
            packed.append(jnp.asarray(qz.pack_int(jnp.asarray(q), b)))
            scales.append(jnp.asarray(step).reshape(-1).astype(jnp.float32))
            used_bits.append(b)
        inv_perm = jnp.asarray(np.argsort(perm), jnp.int32)
        return cls(tuple(packed), tuple(scales), inv_perm,
                   tuple(used_bits), c_out, c_in,
                   act_bits=act_bits, act_scale=act_scale,
                   kernel_shape=kernel_shape, restore_order=restore_order)

    # -- geometry -----------------------------------------------------------
    @property
    def group_sizes(self) -> dict:
        return {b: p.shape[-2] for b, p in zip(self.bits, self.packed)}

    @property
    def perm(self) -> np.ndarray:
        """Deployed channel order (original index per deployed row)."""
        if self.inv_perm is None:
            return np.arange(self.c_out)
        return np.argsort(np.asarray(self.inv_perm))

    @property
    def memory_bits(self) -> int:
        """Deployed model-size contribution in bits (the Pareto x-axis)."""
        return sum(int(p.size) * 8 for p in self.packed)

    # -- compute ------------------------------------------------------------
    def _group_dense(self, b: int, p: jnp.ndarray, s: jnp.ndarray,
                     compute_dtype) -> jnp.ndarray:
        """Unpack + dequant ONE precision group to ``(rows_b, c_in)`` — the
        jnp fall-back's small per-group materialization (never the whole
        canonical weight)."""
        w_int = qz.unpack_int(p, b)[..., : self.c_in]
        return (w_int.astype(jnp.float32) * s[..., None]).astype(compute_dtype)

    def _concat_restore(self, outs: list) -> jnp.ndarray:
        """Concat per-precision group outputs (deployed channel order) and
        restore canonical order — the single tail shared by ``matmul`` and
        both ``conv2d`` paths so the backends/layouts cannot drift."""
        y = jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
        if self.restore_order and self.inv_perm is not None:
            y = jnp.take(y, self.inv_perm, axis=-1)
        return y

    def _dequantize_groups(self) -> jnp.ndarray:
        """Float weight stack in **deployed** (group-contiguous) order."""
        outs = [self._group_dense(b, p, s, jnp.float32)
                for b, p, s in zip(self.bits, self.packed, self.scales)]
        return jnp.concatenate(outs, axis=-2) if len(outs) > 1 else outs[0]

    def dequantize_canonical(self, dtype=jnp.float32) -> jnp.ndarray:
        """Float ``(c_out, c_in)`` in canonical channel order regardless of
        ``restore_order`` — the analysis/reference view (tests, Pareto)."""
        w = self._dequantize_groups()
        if self.inv_perm is not None:
            w = jnp.take(w, self.inv_perm, axis=-2)
        return w.astype(dtype)

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        """Float ``(c_out, c_in)`` view in the same channel order ``matmul``
        produces: canonical when ``restore_order`` (the default), deployed
        (group-contiguous) otherwise — so dense-view consumers always agree
        with the packed runtime path."""
        w = self._dequantize_groups()
        if self.restore_order and self.inv_perm is not None:
            w = jnp.take(w, self.inv_perm, axis=-2)
        return w.astype(dtype)

    def dense(self, dtype=jnp.float32) -> jnp.ndarray:
        """``dequantize`` with the conv kernel tail restored."""
        w = self.dequantize(dtype)
        if self.kernel_shape is not None:
            w = w.reshape((self.c_out,) + self.kernel_shape)
        return w

    def matmul(self, x: jnp.ndarray, compute_dtype=jnp.float32,
               backend: str = "jnp") -> jnp.ndarray:
        """``x (..., c_in) -> (..., c_out)``: per-precision sub-GEMMs whose
        outputs concatenate (the paper's parallel sub-convolutions), then the
        canonical-order restore when ``restore_order``.  ``backend="pallas"``
        runs each sub-GEMM through the fused unpack+dequant+GEMM kernel
        (kernels/quant_matmul.py); this method owns the concat/restore so the
        two backends cannot drift."""
        if x.shape[-1] != self.c_in:
            raise ValueError(
                f"x contraction dim {x.shape[-1]} != c_in {self.c_in} "
                "(both backends reject this — the Pallas kernel would "
                "otherwise zero-pad and compute silently wrong outputs)")
        if backend == "pallas":
            from repro.kernels import ops as kops

            def gemm(b, p, s):
                # compute_dtype reaches the kernel's MXU dot as well as the
                # output cast: f32 (the default) is the bit-parity path with
                # the fake-quant reference, bf16 the TPU fast path.
                return kops.quant_matmul(x, p, s, b, self.c_in,
                                         out_dtype=compute_dtype,
                                         compute_dtype=compute_dtype)
        else:
            def gemm(b, p, s):
                w = self._group_dense(b, p, s, compute_dtype)
                return jnp.einsum("...i,oi->...o", x.astype(compute_dtype), w)
        outs = [gemm(b, p, s)
                for b, p, s in zip(self.bits, self.packed, self.scales)]
        return self._concat_restore(outs)

    def conv2d(self, x: jnp.ndarray, stride=1, padding: str = "SAME",
               groups: int = 1, compute_dtype=jnp.float32,
               backend: str = "jnp") -> jnp.ndarray:
        """NHWC conv ``x (N, H, W, C) -> (N, Ho, Wo, c_out)`` fully packed.

        The deployed realization of the paper's parallel per-precision
        sub-convolutions: the input is lowered to im2col patches once
        (feature axis channel-major — the exact ``(c_out, c_in*kh*kw)``
        contraction layout this QTensor packs), then **delegates to**
        :meth:`matmul`, so the per-group sub-GEMMs, Pallas/jnp backend
        split, concat and canonical-order restore are one code path for
        linear and conv and cannot drift.  No dense float kernel is ever
        materialized.

        Depthwise weights (``groups == c_out``, kernel tail ``(1, kh, kw)``
        — DS-CNN/MobileNetV1 ``dwconv``) contract only the ``kh*kw`` taps of
        their own channel, which is not a single GEMM; they take the grouped
        fall-back below: per-precision-group gather of the channel-major
        patches + a tiny ``(rows, kh*kw)`` group unpack (the same amount the
        jnp matmul fall-back unpacks), identical for both backends.
        """
        if self.kernel_shape is None:
            raise TypeError("conv2d requires a conv QTensor "
                            "(kernel_shape is None — this is a linear map)")
        from repro.kernels import quant_conv as qc

        kh, kw = self.kernel_shape[-2:]
        if groups == 1:
            patches = qc.im2col(x, kh, kw, stride, padding)
            return self.matmul(patches, compute_dtype, backend)
        if groups != self.c_out or self.kernel_shape[0] != 1 \
                or x.shape[-1] != groups:
            raise NotImplementedError(
                f"grouped conv with groups={groups} (c_out={self.c_out}, "
                f"kernel_shape={self.kernel_shape}): only groups=1 and "
                "depthwise (groups == c_out, tail (1, kh, kw)) are packed")
        # -- depthwise fall-back: per-channel tap contraction ---------------
        patches = qc.depthwise_patches(x, kh, kw, stride, padding)
        if self.inv_perm is not None:
            # gather input channels into deployed (group-contiguous) order;
            # traced-safe (jnp.argsort, not the numpy .perm property)
            patches = jnp.take(patches, jnp.argsort(self.inv_perm), axis=-2)
        outs, offset = [], 0
        for b, p, s in zip(self.bits, self.packed, self.scales):
            rows = p.shape[-2]
            w = self._group_dense(b, p, s, compute_dtype)   # (rows, kh*kw)
            seg = patches[..., offset: offset + rows, :].astype(compute_dtype)
            outs.append(jnp.einsum("...ck,ck->...c", seg, w))
            offset += rows
        return self._concat_restore(outs)
