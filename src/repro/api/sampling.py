"""Token sampling for the serving surfaces.

One helper shared by the request-level :class:`~repro.api.scheduler.
ServingEngine` and the lockstep oracle loops over ``engine.serving_jits``
(the removed ``ServingSession`` hard-coded ``argmax`` inline, twice).
The sampling *kind*
is static — jitted serving steps specialize per :class:`SamplingParams`
exactly like they specialize per backend — so greedy decoding stays a
pure ``argmax`` with no RNG plumbed through the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

KINDS = ("greedy", "temperature", "top_k")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Static sampling configuration (hashable — usable as a jit-cache key).

    * ``greedy`` — deterministic ``argmax`` (the default; no key needed);
    * ``temperature`` — softmax sampling at ``temperature``;
    * ``top_k`` — restrict to the ``top_k`` highest logits, then
      temperature-sample within them (``top_k=1`` degenerates to greedy
      for every key — pinned by tests/test_continuous_batching.py).
    """
    kind: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown sampling kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.kind == "top_k" and self.top_k < 1:
            raise ValueError("top_k sampling needs top_k >= 1")
        if self.temperature <= 0:
            raise ValueError("temperature must be > 0")
        # inapplicable knobs raise instead of being silently ignored: a
        # trace configured with kind="temperature", top_k=5 used to sample
        # the FULL vocab and look like a model bug downstream
        if self.kind != "top_k" and self.top_k != 0:
            raise ValueError(
                f"top_k={self.top_k} is inapplicable to kind="
                f"{self.kind!r} and would be silently ignored; use "
                "kind='top_k' (or leave top_k=0)")
        if self.kind == "greedy" and self.temperature != 1.0:
            raise ValueError(
                f"temperature={self.temperature} is inapplicable to "
                "greedy sampling (argmax is temperature-invariant); use "
                "kind='temperature' (or leave temperature=1.0)")


GREEDY = SamplingParams()


def sample(logits: jnp.ndarray, params: SamplingParams = GREEDY,
           key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Sample token ids from ``logits (..., V)`` -> int32 ``(...)``.

    Leading axes are preserved (serving passes ``(B, 1, V)`` and gets the
    ``(B, 1)`` next-token batch back).  ``key`` is required for the
    stochastic kinds and ignored by ``greedy``.
    """
    if params.kind == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError(f"sampling kind {params.kind!r} needs a PRNG key")
    lg = logits.astype(jnp.float32) / params.temperature
    if params.kind == "top_k":
        # clamp: top_k is a request knob, not a vocab fact — asking for more
        # candidates than the vocab axis holds means "no restriction", while
        # the unclamped lax.top_k call is a crash inside jit.  The strict
        # `lg < kth` mask keeps ALL logits tied with the kth one.
        k = min(params.top_k, lg.shape[-1])
        kth = jax.lax.top_k(lg, k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def _dist(logits: jnp.ndarray, params: SamplingParams) -> jnp.ndarray:
    """The probability distribution ``sample`` draws from: filtered softmax
    over ``logits (..., V)`` -> f32 probs ``(..., V)``.  Shared by the
    stochastic speculative acceptance so the draft proposal q and verifier
    target p see exactly the temperature/top-k filtering the engine's
    sampling kind applies."""
    lg = logits.astype(jnp.float32) / params.temperature
    if params.kind == "top_k":
        k = min(params.top_k, lg.shape[-1])
        kth = jax.lax.top_k(lg, k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.nn.softmax(lg, axis=-1)


def speculative_accept(draft_tokens: jnp.ndarray, draft_logits: jnp.ndarray,
                       verify_logits: jnp.ndarray,
                       params: SamplingParams = GREEDY,
                       key: Optional[jax.Array] = None
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Rejection-sampling acceptance for draft/verify speculative decoding.

    ``draft_tokens (B, k)`` — the k tokens the draft proposed;
    ``draft_logits (B, k, V)`` — the draft logits each was sampled from;
    ``verify_logits (B, k+1, V)`` — the verifier's logits at the k+1
    positions of the verify launch (inputs ``[t0, d1..dk]``, so row ``j``
    is the verifier's distribution for the token AFTER accepting
    ``d1..dj``).

    Returns ``(accepted (B,), out_tokens (B, k+1))``: row ``b`` emits
    ``out_tokens[b, :accepted[b] + 1]`` — the accepted draft prefix plus
    one final token from the verifier (the corrected token at the first
    rejection, or the free bonus token when all k drafts survive).

    * ``greedy`` degenerates to **exact prefix match**: a draft token is
      accepted iff it equals the verifier argmax at its position, and every
      emitted token IS a verifier argmax — the speculative engine is
      bit-identical to the non-speculative one (the parity anchor, and it
      holds for ANY draft, however aggressive its bit-width).
    * stochastic kinds run standard rejection sampling on the filtered
      distributions (:func:`_dist`): accept ``d_j`` with prob
      ``min(1, p(d_j)/q(d_j))``; on rejection resample from the residual
      ``max(p - q, 0)`` (normalized); on full acceptance the bonus token
      samples ``p`` directly — output tokens are distributed EXACTLY as
      verifier-only sampling (Leviathan et al., arXiv:2211.17192 Thm. 1;
      the zero-padded q row makes the bonus the ``m == k`` case of the
      same residual formula).
    """
    B, k = draft_tokens.shape
    draft_tokens = draft_tokens.astype(jnp.int32)
    if params.kind == "greedy":
        vt = jnp.argmax(verify_logits, axis=-1).astype(jnp.int32)  # (B, k+1)
        match = (draft_tokens == vt[:, :k]).astype(jnp.int32)
        accepted = jnp.sum(jnp.cumprod(match, axis=1), axis=1)     # (B,)
        return accepted, vt
    if key is None:
        raise ValueError(f"sampling kind {params.kind!r} needs a PRNG key")
    p = _dist(verify_logits, params)                   # (B, k+1, V)
    q = _dist(draft_logits, params)                    # (B, k,   V)
    k_u, k_r = jax.random.split(key)
    pd = jnp.take_along_axis(p[:, :k], draft_tokens[..., None],
                             axis=-1)[..., 0]          # (B, k)
    qd = jnp.take_along_axis(q, draft_tokens[..., None], axis=-1)[..., 0]
    u = jax.random.uniform(k_u, (B, k))
    acc = (u < jnp.minimum(1.0, pd / jnp.maximum(qd, 1e-30))).astype(jnp.int32)
    accepted = jnp.sum(jnp.cumprod(acc, axis=1), axis=1)           # (B,)
    # residual at the first rejected slot; q zero-padded so the all-accept
    # bonus is just the m == k row of the same formula (residual = p_k)
    q_pad = jnp.concatenate([q, jnp.zeros_like(p[:, :1])], axis=1)
    pm = jnp.take_along_axis(p, accepted[:, None, None], axis=1)[:, 0]
    qm = jnp.take_along_axis(q_pad, accepted[:, None, None], axis=1)[:, 0]
    resid = jnp.maximum(pm - qm, 0.0)
    resid = resid / jnp.maximum(resid.sum(-1, keepdims=True), 1e-30)
    corr = jax.random.categorical(
        k_r, jnp.log(jnp.maximum(resid, 1e-30)), axis=-1).astype(jnp.int32)
    out = jnp.concatenate(
        [draft_tokens, jnp.zeros((B, 1), jnp.int32)], axis=1)
    out = out.at[jnp.arange(B), accepted].set(corr)
    return accepted, out
