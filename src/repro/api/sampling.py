"""Token sampling for the serving surfaces.

One helper shared by the request-level :class:`~repro.api.scheduler.
ServingEngine` and the lockstep oracle loops over ``engine.serving_jits``
(the removed ``ServingSession`` hard-coded ``argmax`` inline, twice).
The sampling *kind*
is static — jitted serving steps specialize per :class:`SamplingParams`
exactly like they specialize per backend — so greedy decoding stays a
pure ``argmax`` with no RNG plumbed through the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

KINDS = ("greedy", "temperature", "top_k")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Static sampling configuration (hashable — usable as a jit-cache key).

    * ``greedy`` — deterministic ``argmax`` (the default; no key needed);
    * ``temperature`` — softmax sampling at ``temperature``;
    * ``top_k`` — restrict to the ``top_k`` highest logits, then
      temperature-sample within them (``top_k=1`` degenerates to greedy
      for every key — pinned by tests/test_continuous_batching.py).
    """
    kind: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown sampling kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.kind == "top_k" and self.top_k < 1:
            raise ValueError("top_k sampling needs top_k >= 1")
        if self.temperature <= 0:
            raise ValueError("temperature must be > 0")


GREEDY = SamplingParams()


def sample(logits: jnp.ndarray, params: SamplingParams = GREEDY,
           key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Sample token ids from ``logits (..., V)`` -> int32 ``(...)``.

    Leading axes are preserved (serving passes ``(B, 1, V)`` and gets the
    ``(B, 1)`` next-token batch back).  ``key`` is required for the
    stochastic kinds and ignored by ``greedy``.
    """
    if params.kind == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError(f"sampling kind {params.kind!r} needs a PRNG key")
    lg = logits.astype(jnp.float32) / params.temperature
    if params.kind == "top_k":
        # clamp: top_k is a request knob, not a vocab fact — asking for more
        # candidates than the vocab axis holds means "no restriction", while
        # the unclamped lax.top_k call is a crash inside jit.  The strict
        # `lg < kth` mask keeps ALL logits tied with the kth one.
        k = min(params.top_k, lg.shape[-1])
        kth = jax.lax.top_k(lg, k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
