"""`Engine` — the lifecycle facade over Alg. 1 + the Sec. III-C deploy.

Examples and launchers used to hand-wire the phase transitions (warmup ->
search -> fine-tune -> offline deploy -> serving loop).  The Engine owns one
model's journey end-to-end:

    eng = Engine.for_tinyml(tinyml.TINY_CONFIGS["dae-ad"], settings)
    eng.search(data_epochs)          # Alg. 1 warmup + DNAS search
    eng.finetune(data_epochs)        # Alg. 1 fine-tune (argmax frozen)
    eng.deploy(align=1)              # every searched w -> QTensor (packed)
    logits = eng.serve(batch, backend="pallas")   # jitted deployed forward

``deploy`` rewrites the params tree in place of nothing: each NAS site's
float master weight becomes a :class:`QTensor` (reordered, packed sub-byte,
carrying the argmaxed activation quantization), everything else (biases,
folded BN) is kept verbatim.  Because QTensor is a pytree, the deployed
params tree jits/vmaps like the float one — ``serve`` is literally the same
``apply_fn`` under ``PrecisionPolicy.deployed``.

The search/finetune phases are model-agnostic (anything exposing
``(init_fn, apply_fn, specs)`` + a loss works); ``deploy`` additionally
requires a flat site-keyed params tree — ``Engine.for_tinyml`` wires the
paper's MLPerf-Tiny models, which satisfy both.  Nested scan-stacked LM
trees deploy per site via ``models.serving.deployed_from_search``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.policy import PrecisionPolicy
from repro.api.qtensor import QTensor


class Engine:
    def __init__(self, init_fn: Callable, apply_fn: Callable, specs: dict,
                 loss_fn: Callable, settings, quant_cfg, key=None):
        from repro.core.search import SearchDriver
        self.apply_fn = apply_fn
        self.loss_fn = loss_fn
        self.specs = specs
        self.settings = settings
        self.quant_cfg = quant_cfg
        key = jax.random.PRNGKey(0) if key is None else key
        params, nas = init_fn(key)
        self.driver = SearchDriver(apply_fn, loss_fn, specs, params, nas,
                                   settings)
        self.deployed_params: Optional[dict] = None
        self.draft_params: Optional[dict] = None
        self._serve_fn = None

    @classmethod
    def for_tinyml(cls, cfg, settings=None, key=None) -> "Engine":
        """Engine over one MLPerf-Tiny task (models/tinyml.py)."""
        from repro.core.search import SearchSettings
        from repro.models import tinyml
        init_fn, apply_fn, specs = tinyml.build(cfg)
        settings = settings or SearchSettings(cfg=cfg.quant)
        loss_fn = lambda pred, b: tinyml.task_loss(cfg, pred, b)
        return cls(init_fn, apply_fn, specs, loss_fn, settings, cfg.quant,
                   key=key)

    # -- phase transitions ---------------------------------------------------
    @property
    def params(self) -> dict:
        return self.driver.params

    @property
    def nas(self) -> dict:
        return self.driver.nas

    @property
    def history(self) -> list:
        return self.driver.history

    def search(self, data_epochs: Callable[[], Iterable]) -> "Engine":
        """Alg. 1 phases 1+2: QAT warmup then the DNAS search."""
        self.driver.warmup(data_epochs)
        self.driver.search(data_epochs)
        return self

    def finetune(self, data_epochs: Callable[[], Iterable],
                 epochs: Optional[int] = None) -> "Engine":
        """Alg. 1 phase 3: theta frozen (argmax), W trained."""
        self.driver.finetune(data_epochs, epochs=epochs)
        return self

    def randomize_nas(self, seed: int = 0) -> "Engine":
        """Randomize the NAS logits in place (bench / demo / test utility).

        Gives ``deploy`` genuinely mixed per-channel precision groups
        without paying for a search.  Never part of the paper's pipeline —
        Alg. 1 *learns* these logits; this exists so parity harnesses,
        benchmarks and examples exercise the multi-group deployed paths
        from one recipe (tests/test_conv_parity.py pins it).
        """
        rng = np.random.default_rng(seed)
        for site in self.nas.values():
            site["gamma"] = jnp.asarray(
                rng.standard_normal(site["gamma"].shape) * 3, jnp.float32)
            site["delta"] = jnp.asarray(
                rng.standard_normal(site["delta"].shape), jnp.float32)
        return self

    def deploy(self, align: int = 1, tile_n="auto",
               draft_bits: Optional[int] = None) -> dict:
        """Sec. III-C offline transform: searched float weights -> QTensor.

        Returns (and stores) the deployed params tree.  Channel order is
        restored after each matmul (``restore_order=True``) so downstream
        structure (BN, residuals, the next layer's c_in) is untouched.

        ``tile_n`` (default ``"auto"``) builds the tile-aligned fused
        layout so every deployed linear/conv GEMM serves as ONE
        ``pallas_call`` under ``backend="pallas"``; pass ``None`` for the
        per-group-only packing.  Depthwise sites (``dwconv*`` in the
        models/tinyml.py naming contract) always skip the fused layout —
        their per-channel tap contraction is not a GEMM and never reads it.

        ``draft_bits`` switches deploy to **dual-policy** mode: alongside
        the searched (verifier) tree, every QTensor site is additionally
        re-quantized to a uniform ``draft_bits`` channel assignment
        (api/qtensor.requantize) — the aggressive end of the channel-wise
        Pareto front, derived from the same checkpoint — and the return
        value becomes ``{"verifier": tree, "draft": tree}`` (stored as
        ``self.deployed_params`` / ``self.draft_params``).  Non-QTensor
        site leaves (biases) are shared by reference between the trees.
        The speculative ``ServingEngine`` pairs such a draft with its
        verifier (docs/serving.md).

        Operates on **flat site-keyed params trees** (models/tinyml.py
        style: ``params[site]["w"]`` with ``site in nas``).  Nested /
        scan-stacked trees (models/transformer.py) deploy through
        ``models.serving.deployed_from_search`` per site instead; passing
        one here raises rather than silently serving float weights.
        """
        from repro.core import deploy as dpl
        from repro.api.qtensor import requantize
        params, nas = self.driver.params, self.driver.nas
        sites = [n for n in params if n in nas]
        if not sites:
            raise ValueError(
                "no NAS site keys found at the top level of the params tree "
                "— Engine.deploy expects a flat site-keyed model (tinyml); "
                "nested trees must be deployed per site via "
                "models.serving.deployed_from_search")
        deployed = {}
        draft = {}
        for name, p in params.items():
            if name in nas:
                site_p = dict(p)
                qt = dpl.deploy_linear(
                    np.asarray(p["w"]), np.asarray(nas[name]["gamma"]),
                    np.asarray(p["aw"]), np.asarray(nas[name]["delta"]),
                    float(np.asarray(p["ax"])), self.quant_cfg, align=align,
                    restore_order=True,
                    tile_n=None if name.startswith("dwconv") else tile_n)
                site_p["w"] = qt
                site_p.pop("aw", None)
                site_p.pop("ax", None)
                deployed[name] = site_p
                if draft_bits is not None:
                    draft[name] = dict(site_p, w=requantize(qt, draft_bits))
            else:
                deployed[name] = p
                if draft_bits is not None:
                    draft[name] = p
        self.deployed_params = deployed
        self.draft_params = draft if draft_bits is not None else None
        self._serve_fn = None
        if draft_bits is not None:
            return {"verifier": deployed, "draft": draft}
        return deployed

    def memory_bits(self) -> int:
        """Deployed model size in bits (sum over QTensor leaves)."""
        assert self.deployed_params is not None, "deploy() first"
        total = 0
        for p in self.deployed_params.values():
            if isinstance(p, dict) and isinstance(p.get("w"), QTensor):
                total += p["w"].memory_bits
        return total

    def serve(self, batch, backend: str = "pallas"):
        """Jitted deployed forward (the Pallas quant_matmul path by default).

        ``backend`` threads through ``PrecisionPolicy.deployed`` into every
        layer: with the default tile-aligned deploy, ``"pallas"`` serves
        every linear and GEMM conv as ONE fused multi-precision kernel
        launch (``"pallas-pergroup"`` keeps the per-group reference
        kernels, ``"jnp"`` the dense fallback); convs lower to packed
        im2col patch-GEMMs (``QTensor.conv2d``) — the four MLPerf-Tiny
        models serve fully packed with no dense kernel re-materialization.
        The first call compiles; subsequent calls with same-shaped batches
        reuse the executable.
        """
        assert self.deployed_params is not None, "deploy() first"
        if self._serve_fn is None or self._serve_backend != backend:
            policy = PrecisionPolicy.deployed(backend)
            self._serve_fn = jax.jit(
                lambda dp, b: self.apply_fn(dp, None, policy, b))
            self._serve_backend = backend
        return self._serve_fn(self.deployed_params, batch)

    def result(self):
        return self.driver.result()


# ---------------------------------------------------------------------------
# Module-level jitted serving executables, keyed on (cfg id, backend): the
# prefill/decode wrappers used to be built per ServingSession instance, so
# constructing a session twice recompiled both.  The cache holds a strong
# reference to cfg so an id() is never reused while its entry is alive.
# ---------------------------------------------------------------------------

_SERVING_JITS: dict = {}


def serving_jits(cfg, backend: str, mesh=None) -> dict:
    """Shared jitted ``prefill(dp, batch[, lens])`` / ``decode(dp, tokens,
    caches, pos[, live])`` executables for one (config, backend, mesh).

    Decode donates its caches.  The lockstep drivers (launch/serve.py,
    benchmarks, the test oracles) and any ad-hoc serving loop resolve
    through this cache, so every serving surface over the same deployed
    config reuses one set of compiled executables.  (The request-level
    ``ServingEngine`` keys its own admission/step executables the same way
    in api/scheduler.py.)

    ``mesh=None`` is today's single-device path, bit-for-bit.  With a
    ``(data, model)`` mesh the executables compile with ``in_shardings`` /
    ``out_shardings`` derived from the sharding rules: the deployed params
    placed by ``ShardingRules`` (QTensor fused buffers along the N-tile
    schedule), everything else — tokens, logits, caches — replicated, and
    the body traced inside ``serving_mesh`` so the fused kernels route
    through their shard_map TP/EP forms.
    """
    key = (id(cfg), backend, mesh)
    ent = _SERVING_JITS.get(key)
    if ent is None:
        from repro.models import serving
        if mesh is None:
            ent = {
                "cfg": cfg,
                "prefill": jax.jit(
                    lambda dp, b, lens=None: serving.prefill(
                        dp, cfg, b, backend, lens=lens)),
                "decode": jax.jit(
                    lambda dp, t, c, pos, live=None: serving.decode_step(
                        dp, cfg, t, c, pos, backend, live=live),
                    donate_argnums=(2,)),
            }
        else:
            from repro.dist import sharding as shd
            ctx = shd.MeshContext(mesh)
            shapes = jax.eval_shape(
                lambda k: serving.init_deployed_model(cfg, k),
                jax.random.PRNGKey(0))
            dp_sh = ctx.rules.serving_shardings(shapes)
            rep = ctx.replicated

            # full positional arity (no defaults): in_shardings entries
            # must line up with the call-site args one to one
            def _prefill(dp, b, lens):
                with shd.serving_mesh(ctx):
                    logits, caches = serving.prefill(dp, cfg, b, backend,
                                                     lens=lens)
                    return ctx.constrain_replicated((logits, caches))

            def _decode(dp, t, c, pos, live):
                with shd.serving_mesh(ctx):
                    out = serving.decode_step(dp, cfg, t, c, pos, backend,
                                              live=live)
                    return ctx.constrain_replicated(out)

            ent = {
                "cfg": cfg,
                "mesh_ctx": ctx,
                "params_shardings": dp_sh,
                "prefill": jax.jit(_prefill,
                                   in_shardings=(dp_sh, rep, rep),
                                   out_shardings=rep),
                "decode": jax.jit(_decode,
                                  in_shardings=(dp_sh, rep, rep, rep, rep),
                                  donate_argnums=(2,),
                                  out_shardings=rep),
            }
        _SERVING_JITS[key] = ent
    return ent

# ``ServingSession`` (the lockstep serving surface deprecated in PR 5) was
# removed in PR 6: request-level serving lives in
# :class:`repro.api.ServingEngine`, and the lockstep baseline is a ~10-line
# loop over :func:`serving_jits` (see launch/serve.py run_lockstep and the
# ``_lockstep_generate`` oracle in tests/test_continuous_batching.py).
# docs/api_migration.md has the call-site mapping.
