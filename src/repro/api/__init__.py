"""`repro.api` — the typed surface of the mixed-precision system.

One import gives every call surface the paper's method passes through:

* :class:`PrecisionPolicy` / :class:`Phase` — typed phase dispatch (replaces
  the old string ``mode`` argument everywhere);
* :class:`QTensor` — the packed mixed-precision tensor pytree (replaces the
  offline-only ``DeployedLinear``); flows through jit/vmap into the Pallas
  kernels;
* ``qlinear`` / ``qconv2d`` — the single quantization-aware layer entry
  points (re-exported from models/layers.py), dispatching on the policy and
  on whether the weight leaf is a float array or a QTensor;
* :class:`Engine` — the search -> finetune -> deploy -> serve facade;
* :class:`ServingEngine` / :class:`Request` — the request-level serving
  surface (continuous batching over a paged KV cache with radix prefix
  sharing, repro.cache; the deprecated lockstep ``ServingSession`` was
  removed in PR 6 — see docs/api_migration.md);
* :class:`SamplingParams` / :func:`sample` — greedy / temperature / top-k
  token sampling shared by both serving surfaces.

See docs/api_migration.md for the old-API -> new-API mapping and
docs/serving.md for the request/slot/step lifecycle.
"""
from repro.api.engine import Engine
from repro.api.policy import Phase, PrecisionPolicy, as_policy
from repro.api.qtensor import QTensor
from repro.api.sampling import GREEDY, SamplingParams, sample
from repro.api.scheduler import Request, RequestOutput, ServingEngine


def __getattr__(name):
    # late-bound: models.layers imports repro.api.policy/qtensor, so the
    # layer entry points re-export lazily to avoid a circular import.
    if name in ("qlinear", "qconv2d"):
        from repro.models import layers as L
        return getattr(L, name)
    raise AttributeError(name)


__all__ = ["Engine", "GREEDY", "Phase", "PrecisionPolicy", "QTensor",
           "Request", "RequestOutput", "SamplingParams", "ServingEngine",
           "as_policy", "qconv2d", "qlinear", "sample"]
