"""`PrecisionPolicy` — the typed replacement for the stringly ``mode`` arg.

One value describes which of the paper's phases a forward pass runs in and
carries the phase's parameters:

* ``PrecisionPolicy.FLOAT``            — no quantization (reference path)
* ``PrecisionPolicy.QAT8``             — fixed 8-bit PACT QAT (warmup)
* ``PrecisionPolicy.search(tau)``      — DNAS mixture, Eq. 4-6; ``tau`` is the
  softmax temperature (a traced scalar — annealing does not retrace)
* ``PrecisionPolicy.FROZEN``           — argmax assignment (fine-tuning)
* ``PrecisionPolicy.deployed(backend)``— true-integer packed weights
  (:class:`repro.api.qtensor.QTensor` leaves); ``backend`` picks the jnp
  fallback (``"jnp"``), the fused single-launch Pallas kernel
  (``"pallas"``) or the per-group reference kernels
  (``"pallas-pergroup"``)

The policy is a registered pytree: the phase and backend are static aux data
(so jitted functions specialize per phase — exactly like the old string, but
typed) while ``tau`` is a leaf (so the annealed temperature flows through
``jit`` without recompilation).

``train_compute`` adds a *compute*-precision axis orthogonal to the phase:
it selects what arithmetic the training-phase matmuls (QAT8 / SEARCH /
FROZEN fake-quant paths) run in — ``"f32"`` (the legacy behavior,
byte-for-byte), ``"bf16"`` (bf16 operands, f32 accumulation), or ``"int8"``
(dynamic int8 GEMMs with a custom_vjp, ``repro.qtrain``).  It is static aux
data like the phase.  ``sr_key`` is the per-step PRNG key seeding the int8
backward passes' stochastic rounding — a traced leaf (a fresh key every
step must not retrace), ``None`` outside int8 training.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp


class Phase(enum.Enum):
    FLOAT = "float"
    QAT8 = "qat8"
    SEARCH = "search"
    FROZEN = "frozen"
    DEPLOYED = "deployed"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    phase: Phase
    tau: Optional[jnp.ndarray] = None   # SEARCH only
    backend: str = "jnp"    # DEPLOYED only: jnp | pallas | pallas-pergroup
    train_compute: str = "f32"          # training phases: f32 | bf16 | int8
    sr_key: Optional[jnp.ndarray] = None   # int8 SR seed (traced leaf)

    # Singletons FLOAT / QAT8 / FROZEN / DEPLOYED for the parameter-free
    # phases are assigned right below the class body.

    TRAIN_COMPUTES = ("f32", "bf16", "int8")

    def __post_init__(self):
        if self.train_compute not in self.TRAIN_COMPUTES:
            raise ValueError(
                f"train_compute must be one of {self.TRAIN_COMPUTES}, got "
                f"{self.train_compute!r}")

    @classmethod
    def search(cls, tau, train_compute: str = "f32",
               sr_key=None) -> "PrecisionPolicy":
        return cls(Phase.SEARCH, jnp.asarray(tau, jnp.float32),
                   train_compute=train_compute, sr_key=sr_key)

    @classmethod
    def deployed(cls, backend: str = "jnp") -> "PrecisionPolicy":
        assert backend in ("jnp", "pallas", "pallas-pergroup"), backend
        return cls(Phase.DEPLOYED, backend=backend)

    def with_train_compute(self, train_compute: str,
                           sr_key=None) -> "PrecisionPolicy":
        """Same phase, different training arithmetic (+ optional SR key)."""
        return dataclasses.replace(self, train_compute=train_compute,
                                   sr_key=sr_key)

    def with_sr_key(self, sr_key) -> "PrecisionPolicy":
        """Rebind the stochastic-rounding key (per-layer fan-out)."""
        return dataclasses.replace(self, sr_key=sr_key)

    @property
    def trains_nas(self) -> bool:
        return self.phase is Phase.SEARCH

    @property
    def needs_nas(self) -> bool:
        return self.phase in (Phase.SEARCH, Phase.FROZEN)

    def __repr__(self) -> str:
        tc = ("" if self.train_compute == "f32"
              else f"[train_compute={self.train_compute}]")
        if self.phase is Phase.SEARCH:
            return f"PrecisionPolicy.search(tau){tc}"
        if self.phase is Phase.DEPLOYED:
            return f"PrecisionPolicy.deployed({self.backend!r})"
        return f"PrecisionPolicy.{self.phase.name}{tc}"

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = tuple(c for c in (self.tau, self.sr_key) if c is not None)
        return children, (self.phase, self.tau is not None, self.backend,
                          self.train_compute, self.sr_key is not None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        phase, has_tau, backend, train_compute, has_key = aux
        it = iter(children)
        tau = next(it) if has_tau else None
        sr_key = next(it) if has_key else None
        return cls(phase, tau, backend, train_compute, sr_key)


PrecisionPolicy.FLOAT = PrecisionPolicy(Phase.FLOAT)
PrecisionPolicy.QAT8 = PrecisionPolicy(Phase.QAT8)
PrecisionPolicy.FROZEN = PrecisionPolicy(Phase.FROZEN)
PrecisionPolicy.DEPLOYED = PrecisionPolicy(Phase.DEPLOYED)


def as_policy(mode, tau=None, backend: str = "jnp") -> PrecisionPolicy:
    """Coerce a legacy string (or a policy) into a :class:`PrecisionPolicy`.

    Exists for the migration guide / downstream callers; in-repo code passes
    policies directly.  ``backend`` applies to ``"deployed"`` only.
    """
    if isinstance(mode, PrecisionPolicy):
        return mode
    phase = Phase(mode)
    if phase is Phase.SEARCH:
        if tau is None:
            raise ValueError("search policy requires tau")
        return PrecisionPolicy.search(tau)
    if phase is Phase.DEPLOYED:
        return PrecisionPolicy.deployed(backend)
    return PrecisionPolicy(phase)
