"""`PrecisionPolicy` — the typed replacement for the stringly ``mode`` arg.

One value describes which of the paper's phases a forward pass runs in and
carries the phase's parameters:

* ``PrecisionPolicy.FLOAT``            — no quantization (reference path)
* ``PrecisionPolicy.QAT8``             — fixed 8-bit PACT QAT (warmup)
* ``PrecisionPolicy.search(tau)``      — DNAS mixture, Eq. 4-6; ``tau`` is the
  softmax temperature (a traced scalar — annealing does not retrace)
* ``PrecisionPolicy.FROZEN``           — argmax assignment (fine-tuning)
* ``PrecisionPolicy.deployed(backend)``— true-integer packed weights
  (:class:`repro.api.qtensor.QTensor` leaves); ``backend`` picks the jnp
  fallback (``"jnp"``), the fused single-launch Pallas kernel
  (``"pallas"``) or the per-group reference kernels
  (``"pallas-pergroup"``)

The policy is a registered pytree: the phase and backend are static aux data
(so jitted functions specialize per phase — exactly like the old string, but
typed) while ``tau`` is a leaf (so the annealed temperature flows through
``jit`` without recompilation).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp


class Phase(enum.Enum):
    FLOAT = "float"
    QAT8 = "qat8"
    SEARCH = "search"
    FROZEN = "frozen"
    DEPLOYED = "deployed"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    phase: Phase
    tau: Optional[jnp.ndarray] = None   # SEARCH only
    backend: str = "jnp"    # DEPLOYED only: jnp | pallas | pallas-pergroup

    # Singletons FLOAT / QAT8 / FROZEN / DEPLOYED for the parameter-free
    # phases are assigned right below the class body.

    @classmethod
    def search(cls, tau) -> "PrecisionPolicy":
        return cls(Phase.SEARCH, jnp.asarray(tau, jnp.float32))

    @classmethod
    def deployed(cls, backend: str = "jnp") -> "PrecisionPolicy":
        assert backend in ("jnp", "pallas", "pallas-pergroup"), backend
        return cls(Phase.DEPLOYED, backend=backend)

    @property
    def trains_nas(self) -> bool:
        return self.phase is Phase.SEARCH

    @property
    def needs_nas(self) -> bool:
        return self.phase in (Phase.SEARCH, Phase.FROZEN)

    def __repr__(self) -> str:
        if self.phase is Phase.SEARCH:
            return "PrecisionPolicy.search(tau)"
        if self.phase is Phase.DEPLOYED:
            return f"PrecisionPolicy.deployed({self.backend!r})"
        return f"PrecisionPolicy.{self.phase.name}"

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        if self.tau is None:
            return (), (self.phase, False, self.backend)
        return (self.tau,), (self.phase, True, self.backend)

    @classmethod
    def tree_unflatten(cls, aux, children):
        phase, has_tau, backend = aux
        return cls(phase, children[0] if has_tau else None, backend)


PrecisionPolicy.FLOAT = PrecisionPolicy(Phase.FLOAT)
PrecisionPolicy.QAT8 = PrecisionPolicy(Phase.QAT8)
PrecisionPolicy.FROZEN = PrecisionPolicy(Phase.FROZEN)
PrecisionPolicy.DEPLOYED = PrecisionPolicy(Phase.DEPLOYED)


def as_policy(mode, tau=None, backend: str = "jnp") -> PrecisionPolicy:
    """Coerce a legacy string (or a policy) into a :class:`PrecisionPolicy`.

    Exists for the migration guide / downstream callers; in-repo code passes
    policies directly.  ``backend`` applies to ``"deployed"`` only.
    """
    if isinstance(mode, PrecisionPolicy):
        return mode
    phase = Phase(mode)
    if phase is Phase.SEARCH:
        if tau is None:
            raise ValueError("search policy requires tau")
        return PrecisionPolicy.search(tau)
    if phase is Phase.DEPLOYED:
        return PrecisionPolicy.deployed(backend)
    return PrecisionPolicy(phase)
