"""Optimizers from scratch (no optax in this environment).

Pure-pytree implementations of SGD+momentum and AdamW with:
  * global-norm gradient clipping,
  * decoupled weight decay with parameter masking (no decay on norms/
    clips/NAS logits),
  * optional bf16 first/second-moment storage ("optimizer-state
    compression") — halves Adam memory, which is what lets the 671B MoE
    config fit 16 GB/chip at 512-way sharding (DESIGN.md §5),
  * learning-rate schedules: constant, cosine, and WSD
    (warmup-stable-decay, MiniCPM arXiv:2404.06395 — minicpm-2b config).

Interface mirrors optax: ``init(params) -> state``,
``update(grads, state, params, step) -> (updates, state)`` where ``updates``
are *added* to params.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def wsd_schedule(lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.01) -> Callable:
    """Warmup-Stable-Decay (MiniCPM): linear warmup, flat, then exponential-ish
    (here linear-in-log) decay over the final ``decay`` steps."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        d_prog = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0, 1)
        dec = lr * jnp.exp(jnp.log(final_frac) * d_prog)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < warmup + stable, lr, dec))
        return out
    return fn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    state_dtype: jnp.dtype = jnp.float32   # set bf16 for compressed states
    decay_mask: Optional[Callable] = None  # path-aware mask fn(path, leaf)->bool

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, dtype=self.state_dtype)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(self, grads, state, params, step):
        if self.clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        lr = self.schedule(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = -lr * mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta - lr * self.weight_decay * p.astype(jnp.float32)
            return delta.astype(p.dtype), m32.astype(self.state_dtype), v32.astype(self.state_dtype)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_state = {
            "m": treedef.unflatten([o[1] for o in out]),
            "v": treedef.unflatten([o[2] for o in out]),
        }
        return updates, new_state


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Adafactor (Shazeer & Stern 2018): factored second moment, no first
    moment.  Optimizer state for a (N, K) matrix is N + K floats instead of
    2·N·K — this is the distributed-optimization trick that lets the
    671B/480B MoE configs' training state fit 16 GB/chip (DESIGN.md §5).

    Matrices with both trailing dims >= ``min_factor_dim`` store factored
    row/col second-moment statistics; everything else stores the full v.
    Update-RMS clipping replaces global-norm clipping (per the paper).
    ``state_dtype`` compresses the stored statistics (the
    ``TrainHParams.opt_state_dtype`` knob); arithmetic stays f32.
    """
    schedule: Callable
    decay_pow: float = 0.8           # beta2_t = 1 - t^-decay_pow
    eps1: float = 1e-30              # inside sqrt
    eps2: float = 1e-3               # RMS(p) floor for relative step
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_factor_dim: int = 128
    state_dtype: jnp.dtype = jnp.float32   # set bf16 for compressed states

    def _factored(self, shape) -> bool:
        return (len(shape) >= 2 and shape[-1] >= self.min_factor_dim
                and shape[-2] >= self.min_factor_dim)

    def init(self, params):
        def one(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], self.state_dtype),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        self.state_dtype)}
            return {"v": jnp.zeros(p.shape, self.state_dtype)}
        return {"f": jax.tree_util.tree_map(
            one, params, is_leaf=lambda x: hasattr(x, "shape"))}

    def update(self, grads, state, params, step):
        lr = self.schedule(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-self.decay_pow)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + self.eps1
            if "vr" in s:
                vr = beta2 * s["vr"].astype(jnp.float32) \
                    + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"].astype(jnp.float32) \
                    + (1 - beta2) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of v
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                u = g32 * jax.lax.rsqrt(vr[..., None] / denom[..., None]) \
                    * jax.lax.rsqrt(vc[..., None, :])
                new_s = {"vr": vr.astype(self.state_dtype),
                         "vc": vc.astype(self.state_dtype)}
            else:
                v = beta2 * s["v"].astype(jnp.float32) + (1 - beta2) * g2
                u = g32 * jax.lax.rsqrt(v)
                new_s = {"v": v.astype(self.state_dtype)}
            # update-RMS clipping
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            # relative step size
            rms_p = jnp.maximum(jnp.sqrt(jnp.mean(
                jnp.square(p.astype(jnp.float32)))), self.eps2)
            delta = -lr * rms_p * u
            if self.weight_decay:
                delta = delta - lr * self.weight_decay * p.astype(jnp.float32)
            return delta.astype(p.dtype), new_s

        is_slot = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_s = jax.tree_util.tree_flatten(state["f"], is_leaf=is_slot)[0]
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_f = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(grads), [o[1] for o in out])
        return updates, {"f": new_f}


@dataclasses.dataclass(frozen=True)
class SGD:
    schedule: Callable
    momentum: float = 0.9
    nesterov: bool = False
    clip_norm: Optional[float] = None

    def init(self, params):
        return {"mu": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(self, grads, state, params, step):
        del params
        if self.clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        lr = self.schedule(step)

        def upd(g, mu):
            mu2 = self.momentum * mu + g
            step_dir = g + self.momentum * mu2 if self.nesterov else mu2
            return (-lr * step_dir).astype(g.dtype), mu2

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        out = [upd(g, mu) for g, mu in zip(flat_g, flat_mu)]
        updates = treedef.unflatten([o[0] for o in out])
        return updates, {"mu": treedef.unflatten([o[1] for o in out])}


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
