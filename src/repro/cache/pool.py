"""PagePool — the host-side page manager the serving scheduler talks to.

Coordinates the :class:`~repro.cache.allocator.PageAllocator` (who owns
which physical page) with the :class:`~repro.cache.radix.RadixIndex` (which
pages cache which token prefixes) under one lifecycle:

* **admission** — ``match_prefix`` finds the request's longest cached
  full-page prompt prefix; ``acquire`` maps those pages copy-free
  (refcount bump; a radix-*resident* refcount-0 page is revived);
  ``alloc`` hands out fresh pages for the rest, evicting cold resident
  pages LRU-leaf-first under pressure; ``index_prompt`` then publishes the
  request's full prompt pages so later arrivals can share them;
* **decode** — the scheduler lazily ``alloc``-s one page whenever a slot's
  position crosses a page boundary;
* **release** — each page drops one reference; at refcount 0 an *indexed*
  page stays resident (reclaimable cache — the radix keeps serving it to
  future admissions until evicted), anything else returns to the free
  list.

``available`` counts free + resident pages: residency is closed under
descendants (a slot sharing page *j* of a prefix always also shares pages
``< j``, so a refcount-0 node can never have a referenced child), which
makes the whole resident set drainable by leaf-first eviction — the
scheduler's reservation accounting relies on that exactness.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Set

from repro.cache.allocator import (NULL_PAGE, PageAllocator, PagesExhausted)
from repro.cache.radix import RadixIndex


class PagePool:
    def __init__(self, num_pages: int, page_size: int,
                 prefix_sharing: bool = True, pad_to: int = 1):
        """``pad_to``: round ``num_pages`` up to the next multiple (mesh
        serving shards the physical-page axis across the ``data`` devices,
        which requires the extent to divide; extra pages just enlarge the
        free list)."""
        if pad_to > 1:
            num_pages += (-num_pages) % pad_to
        self.page_size = page_size
        self.allocator = PageAllocator(num_pages, reserved=(NULL_PAGE,))
        self.radix = RadixIndex(page_size) if prefix_sharing else None
        self._resident: Set[int] = set()    # refcount-0 pages kept for reuse
        self.evictions = 0

    # -- queries -------------------------------------------------------------
    @property
    def num_pages(self) -> int:
        return self.allocator.num_pages

    @property
    def capacity(self) -> int:
        return self.allocator.num_allocatable

    @property
    def available(self) -> int:
        """Pages an admission could obtain: free now or evictable."""
        return self.allocator.free_count + len(self._resident)

    @property
    def in_use(self) -> int:
        """Pages holding live data (referenced or radix-resident) — the
        resident-KV-bytes metric is ``in_use * bytes_per_page``."""
        return self.allocator.in_use

    def is_resident(self, page: int) -> bool:
        return page in self._resident

    # -- admission -----------------------------------------------------------
    def match_prefix(self, tokens: Sequence[int]) -> List[int]:
        """Longest cached full-page prompt prefix (may be empty)."""
        if self.radix is None:
            return []
        return self.radix.match(tokens)

    def acquire(self, pages: Iterable[int]) -> None:
        """Map matched pages into a slot: one reference each.  Resident
        pages leave the reclaimable set (they are live again)."""
        for p in pages:
            if p in self._resident:
                self._resident.discard(p)
                self.allocator.revive(p)
            else:
                self.allocator.retain(p)

    def alloc(self, n: int = 1) -> List[int]:
        """n fresh referenced pages, evicting cold resident pages LRU
        leaf-first when the free list runs dry."""
        out = []
        for _ in range(n):
            if self.allocator.free_count == 0:
                victim = None
                if self.radix is not None:
                    victim = self.radix.evict_lru(self._resident.__contains__)
                if victim is None:
                    raise PagesExhausted(
                        f"no free or reclaimable page "
                        f"({self.in_use}/{self.capacity} in use)")
                self._resident.discard(victim)
                self.allocator.free(victim)
                self.evictions += 1
            out.append(self.allocator.alloc())
        return out

    def index_prompt(self, tokens: Sequence[int],
                     pages: Sequence[int]) -> Set[int]:
        """Publish a request's full prompt pages for future sharing.
        Returns the subset actually indexed (paths already cached keep
        their first page)."""
        if self.radix is None:
            return set()
        return self.radix.insert(tokens, pages)

    # -- release -------------------------------------------------------------
    def release(self, pages: Iterable[int]) -> None:
        """Drop one reference per page; last release frees (or, for
        indexed pages, parks resident for reuse)."""
        for p in pages:
            if self.allocator.release(p) == 0:
                if self.radix is not None and p in self.radix:
                    self._resident.add(p)
                else:
                    self.allocator.free(p)
