"""Paged KV cache: fixed-size page allocator, radix prefix index, and the
device-side page gather/scatter helpers.

The dense ``(max_slots, max_len)`` slot pool budgets cache memory for the
worst-case request and stores identical system-prompt prefixes once *per
slot*.  This subsystem splits every slot ring into fixed-size **pages**:

* :class:`PageAllocator` — free-list + per-page refcounts over one physical
  page pool (page 0 is the reserved NULL page: never allocated, never
  written, always zero — unmapped page-table entries point at it);
* :class:`RadixIndex` — a radix tree over token sequences at page
  granularity, so admission can map a request's already-cached prompt pages
  copy-free (refcount bump, zero prefill FLOPs for the cached prefix);
* :class:`PagePool` — the host-side coordinator the scheduler talks to:
  longest-prefix match, acquire/alloc/release, and LRU reclaim of
  refcount-0 radix-resident pages under allocation pressure;
* :mod:`repro.cache.paged` — the jnp gather/scatter index plumbing that
  keeps every serving launch fixed-shape (models/attention.py threads it
  through ``gqa_decode``/``mla_decode``).

Everything in allocator/radix/pool is pure host Python — the invariants
(no double-free, refcounts zero exactly at last release, longest-prefix
matching under interleavings) are tested without a device in
tests/test_paged_cache.py.
"""
from repro.cache.allocator import (DoubleFree, NULL_PAGE, PageAllocator,
                                   PageError, PagesExhausted)
from repro.cache.paged import gather_pages, scatter_prefill, write_coords
from repro.cache.pool import PagePool
from repro.cache.radix import RadixIndex

__all__ = [
    "DoubleFree", "NULL_PAGE", "PageAllocator", "PageError",
    "PagesExhausted", "PagePool", "RadixIndex",
    "gather_pages", "scatter_prefill", "write_coords",
]
