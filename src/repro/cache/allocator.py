"""Fixed-size page allocator: free list + per-page refcounts.

Pure host-side bookkeeping over integer page ids ``[0, num_pages)``.  The
physical pages live on device as the leading axis of the paged cache pools
(models/serving.init_paged_caches); this class only decides who owns which
id.  Page ids in ``reserved`` (by default :data:`NULL_PAGE` = 0) are never
handed out: unmapped page-table entries point at the NULL page, which is
never written, so gathering through an unmapped entry reads exact zeros —
the empty-slot convention of the dense ring, preserved per page.

States of a page id:

* **free** — on the free list, refcount 0; ``alloc`` hands it out;
* **referenced** — refcount >= 1 (one count per slot mapping it; prefix
  sharing bumps it via ``retain``);
* **unreferenced** — refcount 0 but *not* on the free list: the owner
  (PagePool) decides whether to ``free`` it or keep it resident in the
  radix index for reuse (``revive`` takes it back to refcount 1).

Every transition is guarded: freeing a page twice, freeing a referenced
page, releasing below zero, or retaining a non-referenced page raises —
the invariant tests in tests/test_paged_cache.py drive these paths with
randomized interleavings.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List

NULL_PAGE = 0


class PageError(RuntimeError):
    """Base class for page-accounting violations."""


class DoubleFree(PageError):
    """A page was freed while free, or released below refcount 0."""


class PagesExhausted(PageError):
    """No free (or reclaimable) page satisfies an allocation."""


class PageAllocator:
    def __init__(self, num_pages: int, reserved: Iterable[int] = (NULL_PAGE,)):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page beyond the "
                             f"reserved NULL page (num_pages={num_pages})")
        self.num_pages = num_pages
        self._reserved = frozenset(reserved)
        for p in self._reserved:
            if not 0 <= p < num_pages:
                raise ValueError(f"reserved page {p} out of range")
        self.refcount: List[int] = [0] * num_pages
        self._free = deque(p for p in range(num_pages)
                           if p not in self._reserved)
        self._free_set = set(self._free)

    # -- queries -------------------------------------------------------------
    @property
    def num_allocatable(self) -> int:
        return self.num_pages - len(self._reserved)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Pages holding data: referenced or unreferenced-but-not-freed."""
        return self.num_allocatable - len(self._free)

    def is_free(self, page: int) -> bool:
        return page in self._free_set

    def _check(self, page: int) -> None:
        if not 0 <= page < self.num_pages or page in self._reserved:
            raise PageError(f"page {page} is not an allocatable id")

    # -- transitions ---------------------------------------------------------
    def alloc(self) -> int:
        """Pop a free page; it comes back referenced (refcount 1)."""
        if not self._free:
            raise PagesExhausted(
                f"all {self.num_allocatable} pages are in use")
        page = self._free.popleft()
        self._free_set.discard(page)
        self.refcount[page] = 1
        return page

    def retain(self, page: int) -> None:
        """Add a sharer to a referenced page (prefix-sharing refcount bump)."""
        self._check(page)
        if self.refcount[page] < 1:
            raise PageError(f"retain of non-referenced page {page}")
        self.refcount[page] += 1

    def revive(self, page: int) -> None:
        """Re-reference an unreferenced (radix-resident) page: 0 -> 1."""
        self._check(page)
        if self.refcount[page] != 0 or page in self._free_set:
            raise PageError(f"revive of page {page} in state "
                            f"refcount={self.refcount[page]} "
                            f"free={page in self._free_set}")
        self.refcount[page] = 1

    def release(self, page: int) -> int:
        """Drop one reference; returns the remaining count.  At zero the
        caller decides: ``free`` it, or keep it resident for reuse."""
        self._check(page)
        if self.refcount[page] < 1:
            raise DoubleFree(f"release of page {page} with refcount "
                             f"{self.refcount[page]}")
        self.refcount[page] -= 1
        return self.refcount[page]

    def free(self, page: int) -> None:
        """Return an unreferenced page to the free list."""
        self._check(page)
        if self.refcount[page] != 0:
            raise PageError(f"free of page {page} with refcount "
                            f"{self.refcount[page]}")
        if page in self._free_set:
            raise DoubleFree(f"page {page} freed twice")
        self._free.append(page)
        self._free_set.add(page)
