"""Radix prefix index over token sequences at page granularity.

Each node caches exactly one **full** page of ``page_size`` tokens; a
node's logical key is the concatenation of the per-node token tuples on its
root path, so walking the tree IS longest-prefix matching in units of whole
pages.  Only full pages are indexable: a partially-filled prompt tail (and
every decode-produced token) depends on content that keeps changing, so it
never enters the index — matching therefore can never return more than
``len(tokens) // page_size`` pages, and every matched page's content is
immutable prompt KV.

Insertion keeps the **first** page ever indexed for a given token path
(first-writer-wins): a duplicate prompt admitted without sharing produces a
bit-identical page, so re-pointing the node would only churn; the caller
learns which of its pages were newly indexed from the return value and
frees the rest normally at release.

Eviction is leaf-first LRU: only nodes with no children may be removed
(an interior node's token path is a dependency of every descendant), and
the owner passes an ``evictable`` predicate so only refcount-0 resident
pages are reclaimed.  Matching bumps the LRU clock of every node on the
matched path, so hot shared prefixes survive pressure.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple


class _Node:
    __slots__ = ("key", "page", "children", "siblings", "last_use")

    def __init__(self, key: Tuple[int, ...], page: int,
                 siblings: Dict[Tuple[int, ...], "_Node"], clock: int):
        self.key = key                  # this node's page_size-token tuple
        self.page = page                # physical page caching those tokens
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.siblings = siblings        # the dict this node lives in
        self.last_use = clock


class RadixIndex:
    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = page_size
        self._children: Dict[Tuple[int, ...], _Node] = {}   # root level
        self._by_page: Dict[int, _Node] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self._by_page)

    def __contains__(self, page: int) -> bool:
        return page in self._by_page

    def _keys(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        T = self.page_size
        return [tuple(int(t) for t in tokens[i * T:(i + 1) * T])
                for i in range(len(tokens) // T)]

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Pages caching the longest full-page prefix of ``tokens``.

        Returns ``[p_0, .., p_{m-1}]`` where page ``p_j`` holds tokens
        ``[j*T, (j+1)*T)``; every node on the path gets its LRU clock
        bumped.  ``m <= len(tokens) // page_size`` by construction.
        """
        self._clock += 1
        out: List[int] = []
        level = self._children
        for key in self._keys(tokens):
            node = level.get(key)
            if node is None:
                break
            node.last_use = self._clock
            out.append(node.page)
            level = node.children
        return out

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> Set[int]:
        """Index ``pages[j]`` as the cache of tokens ``[j*T, (j+1)*T)``.

        Walks the existing path; where a node already exists its page is
        kept (first-writer-wins) and ``pages[j]`` is ignored; new nodes are
        chained below.  Returns the set of pages actually indexed — the
        caller keeps those resident at release and frees the rest.
        """
        keys = self._keys(tokens)
        if len(pages) > len(keys):
            raise ValueError(f"{len(pages)} pages for "
                             f"{len(keys)} full pages of tokens")
        self._clock += 1
        indexed: Set[int] = set()
        level = self._children
        for key, page in zip(keys, pages):
            node = level.get(key)
            if node is None:
                if page in self._by_page:
                    raise ValueError(f"page {page} is already indexed")
                node = _Node(key, int(page), level, self._clock)
                level[key] = node
                self._by_page[int(page)] = node
                indexed.add(int(page))
            else:
                node.last_use = self._clock
            level = node.children
        return indexed

    def remove(self, page: int) -> None:
        """Drop a leaf node by its page id (eviction)."""
        node = self._by_page.get(page)
        if node is None:
            raise KeyError(f"page {page} is not indexed")
        if node.children:
            raise ValueError(f"page {page} backs an interior node "
                             "(evict its descendants first)")
        del node.siblings[node.key]
        del self._by_page[page]

    def evict_lru(self, evictable: Callable[[int], bool]) -> Optional[int]:
        """Remove and return the least-recently-used evictable **leaf**
        page, or None if nothing qualifies.

        Leaf-first keeps every surviving node's full token path intact;
        repeated calls drain a cold branch bottom-up.
        """
        best: Optional[_Node] = None
        for node in self._by_page.values():
            if node.children or not evictable(node.page):
                continue
            if best is None or node.last_use < best.last_use:
                best = node
        if best is None:
            return None
        self.remove(best.page)
        return best.page
