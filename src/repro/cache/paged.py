"""Device-side page index plumbing: fixed-shape gather/scatter over pools.

A paged ring leaf stores its sequence axis as ``(num_pages, page_size)``
physical pages with a stacking axis in front: ``pool (X, num_pages, *mid,
page_size, feat)`` where ``X`` is the layer/group stack and ``*mid`` is
e.g. the KV-head axis.  A slot's logical ring of ``max_len = P * T``
positions is the concatenation of the ``P`` pages its ``(B, P)`` int32
page-table row points at; entry 0 (the NULL page) is reserved, never
written, and always zero — gathering through an unmapped entry reads the
dense ring's empty-slot zeros.

Every helper here is shape-static in everything but the index *values*:
the gathered view is exactly the dense ``(B, *mid, max_len, feat)`` ring
(this is what makes the paged engine bit-identical to the dense slot pool
— masked positions contribute exact-0.0 softmax weight either way), and
the scatters use ``mode="drop"`` with out-of-bounds sentinels so dead rows
and shared (read-only) pages skip their writes with no shape change.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def gather_pages(pool: jnp.ndarray, pages: jnp.ndarray) -> jnp.ndarray:
    """Materialize per-slot rings: pool ``(NP, *mid, T, F)`` gathered by
    ``pages (B, P)`` -> ``(B, *mid, P*T, F)`` — the dense ring layout the
    decode attention math already consumes."""
    g = pool[pages]                         # (B, P, *mid, T, F)
    nm = g.ndim - 4                         # number of *mid axes
    perm = (0,) + tuple(range(2, 2 + nm)) + (1, 2 + nm, 3 + nm)
    g = jnp.transpose(g, perm)              # (B, *mid, P, T, F)
    sh = g.shape
    return g.reshape(sh[:-3] + (sh[-3] * sh[-2], sh[-1]))


def write_coords(pos: jnp.ndarray, live: Optional[jnp.ndarray],
                 pages: jnp.ndarray, page_size: int, num_pages: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-slot (physical page, in-page offset) for a decode write.

    ``pos (B,)`` positions, ``pages (B, P)`` table.  Rows that must not
    write — dead (``live=False``), position past the table, or mapped to
    the NULL page (the scheduler suppresses a write by leaving the entry
    unmapped or masking ``live``) — get page index ``num_pages``, out of
    bounds so the ``mode="drop"`` scatter skips them.  The NULL page is
    thereby never written and stays all-zero.

    ``pos`` may also be ``(B, W)`` — the multi-token verify launch of
    speculative decoding writes ``W`` consecutive entries per slot in one
    scatter; the returned coordinate arrays are then ``(B, W)`` with the
    same drop rules applied elementwise (``live`` still masks whole
    slots).  A write that lands past the slot's mapped pages is dropped,
    never unwound — the cache-rewind contract: rejected draft positions
    stay masked (``<= pos``) until later writes overwrite them.
    """
    pos = pos.astype(jnp.int32)
    P = pages.shape[1]
    S = P * page_size
    vec = pos.ndim == 1
    posw = pos[:, None] if vec else pos                    # (B, W)
    wpos = posw if live is None else jnp.where(live[:, None], posw, S)
    pidx = jnp.clip(wpos // page_size, 0, P - 1)
    phys = jnp.take_along_axis(pages, pidx, axis=1)        # (B, W)
    drop = (wpos >= S) | (phys == 0)
    phys = jnp.where(drop, num_pages, phys)
    off = wpos % page_size
    return (phys[:, 0], off[:, 0]) if vec else (phys, off)


def scatter_prefill(pool: jnp.ndarray, pf: jnp.ndarray,
                    wp_flat: jnp.ndarray) -> jnp.ndarray:
    """Scatter an admission's prefill cache into the page pool.

    ``pool (X, NP, *mid, T, F)``; ``pf (X, B, *mid, Sp, F)`` with the
    prefill width ``Sp = n_pp * T``; ``wp_flat (B * n_pp,)`` int32 maps
    slot ``b``'s prompt page ``j`` (flattened ``b * n_pp + j``) to its
    physical page — or to ``NP`` (out of bounds, dropped) for pages that
    must not be written: slots not being admitted, the junk tail past a
    short prompt, and prefix-shared pages (read-only, already holding the
    identical bits from the prefill that first produced them).
    """
    X, B = pf.shape[0], pf.shape[1]
    T, F = pool.shape[-2], pool.shape[-1]
    mid = pf.shape[2:-2]
    n_pp = pf.shape[-2] // T
    nm = len(mid)
    x = pf.reshape((X, B) + mid + (n_pp, T, F))
    perm = (0, 1, 2 + nm) + tuple(range(2, 2 + nm)) + (3 + nm, 4 + nm)
    x = jnp.transpose(x, perm).reshape((X, B * n_pp) + mid + (T, F))
    return pool.at[:, wp_flat].set(x.astype(pool.dtype), mode="drop")
