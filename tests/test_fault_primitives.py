"""Unit tests for the dist/fault.py serving primitives.

``run_supervised`` (checkpoint/restart training) is pinned end-to-end by
tests/test_fault_recovery.py; this file covers the primitives the mesh
serving engine composes for its drain-on-death path
(tests/test_mesh_serving.py has the integration side): heartbeat timeout
ordering, elastic mesh reshaping at awkward host counts, straggler window
eviction, and the slot-ownership partition.
"""
import pytest

from repro.dist import fault


# -- Heartbeat ---------------------------------------------------------------

def test_heartbeat_timeout_ordering():
    """check() declares exactly the hosts whose last beat is stale, each
    once, in sorted order — independent of beat arrival order."""
    hb = fault.Heartbeat([0, 1, 2, 3], timeout_s=2.0)
    for h in (3, 1, 0, 2):                    # scrambled arrival order
        hb.beat(h, float(h))                  # host h last beats at t=h
    # at t=4.5: hosts 0,1,2 have 4.5 - t > 2 only for t < 2.5 -> {0, 1, 2}?
    # 4.5-0=4.5>2, 4.5-1=3.5>2, 4.5-2=2.5>2, 4.5-3=1.5<=2 -> [0, 1, 2]
    assert hb.check(4.5) == [0, 1, 2]
    assert hb.alive() == [3]
    # already-dead hosts never re-report; 3 dies once its beat goes stale
    assert hb.check(5.2) == [3]
    assert hb.check(100.0) == []
    assert hb.alive() == []


def test_heartbeat_never_beaten_host_is_dead_on_first_check():
    hb = fault.Heartbeat([0, 1], timeout_s=10.0)
    hb.beat(1, 0.0)
    assert hb.check(0.5) == [0]               # t is None -> dead
    assert hb.alive() == [1]


def test_heartbeat_boundary_is_exclusive():
    """Exactly-timeout staleness is still alive (> not >=)."""
    hb = fault.Heartbeat([0], timeout_s=2.0)
    hb.beat(0, 1.0)
    assert hb.check(3.0) == []                # 3.0 - 1.0 == timeout
    assert hb.check(3.0 + 1e-9) == [0]


# -- ElasticMesh -------------------------------------------------------------

def test_elastic_mesh_non_power_of_two_hosts():
    """Shrinking fleets at awkward sizes: the model axis is pinned and the
    data axis takes the (floored) remainder of the chips."""
    em = fault.ElasticMesh(model=16, chips_per_host=4)
    assert em.shape_for(12) == (3, 16)        # 48 chips
    assert em.shape_for(9) == (2, 16)         # 36 chips -> floor 2
    assert em.shape_for(5) == (1, 16)         # 20 chips -> exactly one slice
    assert em.shape_for(4) == (1, 16)         # 16 chips, boundary
    with pytest.raises(RuntimeError):
        em.shape_for(3)                       # 12 chips < one model slice


def test_elastic_mesh_odd_chip_geometry():
    em = fault.ElasticMesh(model=6, chips_per_host=3)
    assert em.shape_for(7) == (3, 6)          # 21 chips -> floor(21/6) = 3
    with pytest.raises(RuntimeError):
        em.shape_for(1)


# -- StragglerPolicy ---------------------------------------------------------

def test_straggler_window_eviction():
    """A host that was slow but recovers is un-flagged once its slow
    samples age out of the sliding window."""
    pol = fault.StragglerPolicy(threshold=1.3, window=4, min_samples=4)
    for _ in range(4):
        pol.record(0, 1.0)
        pol.record(1, 10.0)                   # 10x the median -> straggler
    assert pol.stragglers() == [1]
    # host 1 recovers; its deque (maxlen=4) evicts all four slow samples
    for _ in range(4):
        pol.record(1, 1.0)
    assert pol.stragglers() == []


def test_straggler_min_samples_gate():
    pol = fault.StragglerPolicy(threshold=1.3, window=8, min_samples=8)
    for _ in range(7):
        pol.record(0, 1.0)
        pol.record(1, 50.0)
    assert pol.stragglers() == []             # below min_samples: no verdict
    pol.record(0, 1.0)
    pol.record(1, 50.0)
    assert pol.stragglers() == [1]


# -- owned_slots -------------------------------------------------------------

def test_owned_slots_partition():
    """Host slot ranges tile [0, n_slots) exactly, balanced within 1."""
    for n_slots, n_hosts in ((8, 2), (7, 3), (4, 4), (5, 8), (16, 5)):
        seen = []
        sizes = []
        for h in range(n_hosts):
            own = fault.owned_slots(h, n_slots, n_hosts)
            seen.extend(own)
            sizes.append(len(own))
        assert seen == list(range(n_slots)), (n_slots, n_hosts)
        assert max(sizes) - min(sizes) <= 1, (n_slots, n_hosts)


def test_owned_slots_validates_host():
    with pytest.raises(ValueError):
        fault.owned_slots(2, 8, 2)
    with pytest.raises(ValueError):
        fault.owned_slots(-1, 8, 2)
