"""Integration: Alg. 1 end-to-end on the paper's MLPerf-Tiny models, and the
EdMIPS baseline under the identical protocol (Sec. IV-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import edmips, mixedprec as mp, regularizers as reg, search
from repro.data import pipeline as pipe
from repro.models import tinyml


def _setup(task_name="dae-ad", n=64, batch=16, seed=0):
    cfg = tinyml.TINY_CONFIGS[task_name]
    init_fn, apply_fn, specs = tinyml.build(cfg)
    params, nas = init_fn(jax.random.PRNGKey(seed))
    data = pipe.SyntheticTiny(cfg, n=n, seed=seed)
    epochs = lambda: data.batches(batch, seed=seed)
    loss_fn = lambda pred, b: tinyml.task_loss(cfg, pred, b)
    return cfg, apply_fn, specs, params, nas, epochs, loss_fn


@pytest.mark.parametrize("objective", ["size", "energy"])
def test_alg1_three_phases_run(objective):
    cfg, apply_fn, specs, params, nas, epochs, loss_fn = _setup()
    settings = search.SearchSettings(
        cfg=cfg.quant, objective=objective, lam=1e-6,
        warmup_epochs=1, search_epochs=2, finetune_epochs=1,
        lut_name="mpic")
    res = search.run_search(apply_fn, loss_fn, specs, params, nas, epochs,
                            settings)
    phases = [h["phase"] for h in res.history]
    assert "warmup" in phases and "search" in phases and "finetune" in phases
    # tau annealed during the search epochs
    assert float(res.tau) < cfg.quant.tau0


def test_lambda_sweep_reduces_model_size():
    """Higher lambda must push the discrete assignment to fewer bits — the
    mechanism behind the paper's Pareto fronts (Fig. 3)."""
    sizes = {}
    for lam in (1e-9, 3e-4):
        cfg, apply_fn, specs, params, nas, epochs, loss_fn = _setup()
        settings = search.SearchSettings(
            cfg=cfg.quant, objective="size", lam=lam,
            warmup_epochs=1, search_epochs=3, finetune_epochs=0)
        res = search.run_search(apply_fn, loss_fn, specs, params, nas,
                                epochs, settings)
        flat = res.nas
        sizes[lam] = reg.discrete_size_bits(flat, specs, cfg.quant)
    assert sizes[3e-4] < sizes[1e-9]


def test_edmips_baseline_layerwise():
    """EdMIPS config: one gamma row per layer; search still runs."""
    qcfg = edmips.edmips_config()
    assert not qcfg.per_channel
    cfg = tinyml.TINY_CONFIGS["dae-ad"]
    import dataclasses
    cfg = dataclasses.replace(cfg, quant=qcfg)
    init_fn, apply_fn, specs = tinyml.build(cfg)
    params, nas = init_fn(jax.random.PRNGKey(0))
    for site, n in nas.items():
        assert n["gamma"].shape[0] == 1, site   # layer-wise
    data = pipe.SyntheticTiny(cfg, n=48)
    settings = search.SearchSettings(cfg=qcfg, objective="size", lam=1e-6,
                                     warmup_epochs=1, search_epochs=1,
                                     finetune_epochs=1)
    res = search.run_search(apply_fn,
                            lambda p, b: tinyml.task_loss(cfg, p, b),
                            specs, params, nas,
                            lambda: data.batches(16), settings)
    assert res.nas is not None


def test_channelwise_beats_edmips_in_search_space():
    """Per-channel gamma has c_out x more NAS parameters than layer-wise —
    the paper's Sec. III search-space claim, structurally."""
    cw = edmips.channelwise_config()
    lw = edmips.edmips_config()
    n_cw = mp.init_nas_params(jax.random.PRNGKey(0), 64, cw)
    n_lw = mp.init_nas_params(jax.random.PRNGKey(0), 64, lw)
    assert n_cw["gamma"].size == 64 * n_lw["gamma"].size


def test_early_stop_triggers():
    cfg, apply_fn, specs, params, nas, epochs, loss_fn = _setup(n=32, batch=16)
    settings = search.SearchSettings(
        cfg=cfg.quant, objective="size", lam=0.0,   # nothing to improve
        warmup_epochs=0, search_epochs=50, finetune_epochs=0,
        early_stop_patience=2)
    res = search.run_search(apply_fn, loss_fn, specs, params, nas, epochs,
                            settings)
    n_search = sum(1 for h in res.history if h["phase"] == "search")
    assert n_search < 50


def test_run_search_zero_batches_no_crash():
    """Regression: an epoch source yielding ZERO batches must not raise
    UnboundLocalError on the history writes (loss/lt/lr guards)."""
    cfg, apply_fn, specs, params, nas, _, loss_fn = _setup(n=32)
    settings = search.SearchSettings(
        cfg=cfg.quant, objective="size", lam=1e-6,
        warmup_epochs=1, search_epochs=2, finetune_epochs=1)
    res = search.run_search(apply_fn, loss_fn, specs, params, nas,
                            lambda: iter(()), settings)
    assert len(res.history) == 4          # entries written, no stale losses
    for h in res.history:
        assert "loss" not in h and "task_loss" not in h and \
            "reg_cost" not in h
    # tau still annealed per search epoch
    assert float(res.tau) < cfg.quant.tau0


def test_run_search_fewer_batches_than_theta_split():
    """A 1-batch epoch (< 1/theta_frac) sends everything to the theta update
    and leaves the W loop empty — must still record the search epoch."""
    cfg, apply_fn, specs, params, nas, _, loss_fn = _setup(n=16, batch=16)
    settings = search.SearchSettings(
        cfg=cfg.quant, objective="size", lam=1e-6, theta_frac=0.2,
        warmup_epochs=0, search_epochs=1, finetune_epochs=0)
    data = pipe.SyntheticTiny(cfg, n=16, seed=0)
    res = search.run_search(apply_fn, loss_fn, specs, params, nas,
                            lambda: data.batches(16), settings)
    entry = [h for h in res.history if h["phase"] == "search"][0]
    assert "task_loss" in entry and "reg_cost" in entry


def test_search_driver_phases_individually():
    """SearchDriver (the Engine's substrate) drives phases separately while
    sharing optimizer state."""
    cfg, apply_fn, specs, params, nas, epochs, loss_fn = _setup(n=32)
    settings = search.SearchSettings(
        cfg=cfg.quant, objective="size", lam=1e-6,
        warmup_epochs=1, search_epochs=1, finetune_epochs=1)
    d = search.SearchDriver(apply_fn, loss_fn, specs, params, nas, settings)
    d.warmup(epochs).search(epochs).finetune(epochs)
    res = d.result()
    assert [h["phase"] for h in res.history] == \
        ["warmup", "search", "finetune"]
