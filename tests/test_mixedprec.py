"""Tests for the paper's core: Eq. 3-6 effective tensors, Eq. 7/8
regularizers, tau annealing, argmax freezing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut as lut_mod
from repro.core import mixedprec as mp
from repro.core import regularizers as reg
from repro.core.regularizers import LayerCostSpec

CFG = mp.MixedPrecConfig()


def _nas(c_out=8, key=0):
    return mp.init_nas_params(jax.random.PRNGKey(key), c_out, CFG)


def test_softmax_tau_limits():
    """tau -> 0 turns the softmax into argmax; tau large -> uniform."""
    logits = jnp.asarray([1.0, 2.0, 0.5])
    hot = mp.softmax_tau(logits, jnp.asarray(1e-3))
    np.testing.assert_allclose(np.asarray(hot), [0, 1, 0], atol=1e-6)
    flat = mp.softmax_tau(logits, jnp.asarray(1e3))
    np.testing.assert_allclose(np.asarray(flat), [1 / 3] * 3, atol=1e-3)


def test_effective_weight_is_convex_mixture():
    """Eq. 5: effective weight lies in the convex hull of the fq copies."""
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    alpha = jnp.max(jnp.abs(w), axis=-1)
    gamma = jax.random.normal(jax.random.PRNGKey(1), (8, 3))
    eff = mp.effective_weight(w, gamma, alpha, jnp.asarray(1.0), CFG)
    bank = jnp.stack([__import__("repro.core.quantizers",
                                 fromlist=["quantize_weight"]).quantize_weight(
        w, alpha[:, None], b) for b in CFG.weight_bits])
    lo, hi = jnp.min(bank, 0), jnp.max(bank, 0)
    assert bool(jnp.all(eff >= lo - 1e-5) and jnp.all(eff <= hi + 1e-5))


def test_effective_weight_onehot_selects_single_precision():
    from repro.core import quantizers as qz
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    alpha = jnp.max(jnp.abs(w), axis=-1)
    gamma = jnp.asarray([[99., 0., 0.], [0., 99., 0.],
                         [0., 0., 99.], [0., 99., 0.]])
    eff = mp.effective_weight(w, gamma, alpha, jnp.asarray(0.01), CFG)
    for i, bits in enumerate((2, 4, 2)):  # rows 0,1,3 -> argmax bits 2,4,4
        pass
    exp0 = qz.quantize_weight(w[0:1], alpha[0:1, None], 2)
    np.testing.assert_allclose(np.asarray(eff[0:1]), np.asarray(exp0),
                               atol=1e-4)
    exp1 = qz.quantize_weight(w[1:2], alpha[1:2, None], 4)
    np.testing.assert_allclose(np.asarray(eff[1:2]), np.asarray(exp1),
                               atol=1e-4)


def test_frozen_matches_argmax_mixture():
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 16))
    alpha = jnp.max(jnp.abs(w), axis=-1)
    gamma = jax.random.normal(jax.random.PRNGKey(3), (8, 3)) * 3
    frozen = mp.frozen_weight(w, gamma, alpha, CFG)
    # manual: per-channel argmax pick
    from repro.core import quantizers as qz
    idx = np.asarray(jnp.argmax(gamma, -1))
    for i in range(8):
        exp = qz.quantize_weight(w[i:i + 1], alpha[i:i + 1, None],
                                 CFG.weight_bits[idx[i]])
        np.testing.assert_allclose(np.asarray(frozen[i:i + 1]),
                                   np.asarray(exp), atol=1e-5)


def test_anneal_tau_schedule():
    """tau(k) = tau0 * e^(-0.0045 k) — Sec. III-B."""
    tau = jnp.asarray(5.0)
    for _ in range(10):
        tau = mp.anneal_tau(tau, CFG)
    np.testing.assert_allclose(float(tau), 5.0 * np.exp(-0.045), rtol=1e-5)


# ---------------------------------------------------------------------------
# Regularizers
# ---------------------------------------------------------------------------

def _spec(c_out=8, wpc=9, ops=1000):
    return LayerCostSpec("l", c_out, wpc, ops)


def test_size_cost_uniform_logits():
    """Uniform gamma -> expected bits == mean(P_W) per channel (Eq. 7)."""
    gamma = jnp.zeros((8, 3))
    cost = reg.size_cost(gamma, jnp.asarray(1.0), _spec(), CFG)
    exp = 9 * 8 * np.mean([2, 4, 8])
    np.testing.assert_allclose(float(cost), exp, rtol=1e-5)


def test_size_cost_layerwise_equals_perchannel_when_tied():
    """EdMIPS 1-row gamma must cost the same as identical per-channel rows."""
    g1 = jnp.asarray([[1.0, 2.0, 0.3]])
    g8 = jnp.tile(g1, (8, 1))
    c1 = reg.size_cost(g1, jnp.asarray(1.0), _spec(), CFG)
    c8 = reg.size_cost(g8, jnp.asarray(1.0), _spec(), CFG)
    np.testing.assert_allclose(float(c1), float(c8), rtol=1e-6)


def test_size_cost_monotone_in_bits():
    """Pushing logits toward 8b strictly raises Eq. 7."""
    lo = reg.size_cost(jnp.asarray([[5.0, 0, 0]]), jnp.asarray(1.0),
                       _spec(), CFG)
    hi = reg.size_cost(jnp.asarray([[0, 0, 5.0]]), jnp.asarray(1.0),
                       _spec(), CFG)
    assert float(hi) > float(lo)


def test_energy_cost_lut_weighting():
    """One-hot NAS params recover exactly one LUT entry * Omega (Eq. 8)."""
    lut = lut_mod.get_lut("mpic")
    gamma = jnp.asarray([[0, 99.0, 0]] * 4)     # all channels 4b
    delta = jnp.asarray([99.0, 0, 0])           # acts 2b
    spec = _spec(c_out=4, ops=1000)
    cost = reg.energy_cost(gamma, delta, jnp.asarray(0.01), spec, CFG, lut)
    np.testing.assert_allclose(float(cost), 1000 * float(lut[0, 1]),
                               rtol=1e-4)


def test_energy_lut_properties():
    """MPIC LUT: monotone in both precisions, 8x8 normalized to 1."""
    lut = np.asarray(lut_mod.get_lut("mpic"))
    assert lut[2, 2] == 1.0
    assert (np.diff(lut, axis=0) > 0).all() and (np.diff(lut, axis=1) > 0).all()


def test_total_cost_missing_spec_raises():
    nas = {"lay": _nas()}
    with pytest.raises(KeyError):
        reg.total_cost(nas, jnp.asarray(1.0), {}, CFG, "size")


def test_discrete_size_bits():
    """Discrete (argmax) model size matches hand count."""
    nas = {"l": {"gamma": jnp.asarray([[9., 0, 0], [0, 9., 0]]),
                 "delta": jnp.zeros(3)}}
    specs = {"l": LayerCostSpec("l", 2, 10, 100)}
    bits = reg.discrete_size_bits(nas, specs, CFG)
    assert bits == 10 * (2 + 4)


def test_regularizer_gradient_direction():
    """d(Eq.7)/d gamma_8bit > 0 > d/d gamma_2bit — the force toward fewer
    bits that drives the search."""
    gamma = jnp.zeros((4, 3))
    g = jax.grad(lambda G: reg.size_cost(G, jnp.asarray(1.0), _spec(4),
                                         CFG))(gamma)
    assert bool(jnp.all(g[:, 2] > 0)) and bool(jnp.all(g[:, 0] < 0))
