"""repro.qtrain — int8 quantized-compute training.

Covers the ISSUE-10 acceptance criteria:
  * the Pallas int8 GEMM matches the jnp int8 reference **bitwise**
    (int32 accumulation is exact; the dequant epilogue multiplies in the
    same order),
  * stochastic rounding is unbiased (CLT bound over many keys),
    deterministic per key, and exact on representable values,
  * ``int8_linear``'s custom VJP: per-leg switchability, grads vs a
    manual reference, grad-weight seed dependence,
  * ``train_compute="f32"`` is *structurally* identical to the pre-axis
    path (same policy object, same qlinear branch),
  * int8 search steps on dae-ad converge alongside f32,
  * ``TrainHParams.opt_state_dtype`` regression: AdamW *and* Adafactor
    moment leaves carry the configured dtype through init and update.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.policy import PrecisionPolicy
from repro.kernels import int8_matmul as qmm
from repro.models import layers as L
from repro.optim import optimizers as opt_mod
from repro.qtrain import linear as qt


# ---------------------------------------------------------------------------
# Kernel vs jnp reference — bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 32, 16),       # tiny aligned-ish
    (7, 13, 5),        # pad in every dim
    (128, 256, 128),   # one tile
    (100, 384, 130),   # pad M and N
    (1, 8, 1),         # degenerate
])
def test_int8_mm_pallas_matches_ref_bitwise(m, k, n):
    key = jax.random.PRNGKey(m * 7 + n)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, k), jnp.float32)
    qa, sa = qmm.rowwise_quantize(a)
    qb, sb = qmm.rowwise_quantize(b)
    y_ref = qmm.scaled_int8_mm(qa, qb, sa, sb, backend="jnp")
    y_pal = qmm.scaled_int8_mm(qa, qb, sa, sb, backend="pallas")
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pal))


def test_int8_mm_int32_accumulation_exact():
    # worst-case magnitudes: every product is 127*127; K products must
    # accumulate exactly in int32
    k = 64
    qa = jnp.full((4, k), 127, jnp.int8)
    qb = jnp.full((3, k), -127, jnp.int8)
    sa = jnp.ones((4,), jnp.float32)
    sb = jnp.ones((3,), jnp.float32)
    y = qmm.scaled_int8_mm(qa, qb, sa, sb, backend="pallas")
    np.testing.assert_array_equal(np.asarray(y),
                                  np.full((4, 3), -127.0 * 127.0 * k))


def test_k_overflow_guard_constant():
    assert qmm.K_INT32_EXACT_MAX == (2 ** 31 - 1) // (127 * 127)


# ---------------------------------------------------------------------------
# Stochastic rounding
# ---------------------------------------------------------------------------

def test_sr_deterministic_per_key():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    key = jax.random.PRNGKey(7)
    q1, s1 = qmm.rowwise_quantize(x, key=key)
    q2, s2 = qmm.rowwise_quantize(x, key=key)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    q3, _ = qmm.rowwise_quantize(x, key=jax.random.PRNGKey(8))
    assert np.any(np.asarray(q1) != np.asarray(q3))


def test_sr_exact_on_representable_values():
    # values that are exact multiples of the scale must never be perturbed
    scale = 2.0 / 127.0
    grid = jnp.arange(-127, 128, dtype=jnp.float32) * scale
    x = jnp.tile(grid[None, :], (5, 1))
    for seed in range(3):
        q, s = qmm.rowwise_quantize(x, key=jax.random.PRNGKey(seed))
        deq = np.asarray(q, np.float32) * np.asarray(s)[:, None]
        np.testing.assert_allclose(deq, np.asarray(x), rtol=0, atol=1e-6)


def test_sr_unbiased_clt():
    # a value exactly halfway between two grid points must round up with
    # p=0.5; mean over N keys is within 5 sigma of the true value
    x = jnp.full((1, 8), 0.5 * (1.0 / 127.0), jnp.float32)
    # pin the scale with a sentinel so the halfway point is controlled
    x = x.at[0, 0].set(1.0)
    n = 400
    deqs = []
    for seed in range(n):
        q, s = qmm.rowwise_quantize(x, key=jax.random.PRNGKey(seed))
        deqs.append(np.asarray(q[0, 1:], np.float32) * float(s[0]))
    deqs = np.stack(deqs)            # (n, 7), each entry 0 or 1/127
    step = 1.0 / 127.0
    p_up = float(np.mean(deqs / step))      # empirical round-up probability
    sigma = 0.5 / np.sqrt(n * 7)
    assert abs(p_up - 0.5) < 5 * sigma, (p_up, sigma)
    # deterministic rounding of the same halfway input is constant
    q_det, _ = qmm.rowwise_quantize(x)
    assert np.unique(np.asarray(q_det[0, 1:])).size == 1


# ---------------------------------------------------------------------------
# int8_linear custom VJP
# ---------------------------------------------------------------------------

def _toy():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 6, 32), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (24, 32), jnp.float32)
    return x, w


def test_all_legs_off_is_plain_einsum():
    x, w = _toy()
    cfg = qt.QTrainConfig(forward=False, grad_input=False, grad_weight=False)
    y = qt.int8_linear(x, w, None, cfg)
    y_ref = jnp.einsum("...i,oi->...o", x, w)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    g = jax.grad(lambda a, b: jnp.sum(qt.int8_linear(a, b, None, cfg) ** 2),
                 argnums=(0, 1))(x, w)
    g_ref = jax.grad(
        lambda a, b: jnp.sum(jnp.einsum("...i,oi->...o", a, b) ** 2),
        argnums=(0, 1))(x, w)
    for got, want in zip(g, g_ref):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_forward_matches_manual_int8_reference():
    x, w = _toy()
    y = qt.int8_linear(x, w, None, qt.QTrainConfig(stochastic_rounding=False))
    x2 = x.reshape(-1, x.shape[-1])
    qa, sa = qmm.rowwise_quantize(x2)
    qb, sb = qmm.rowwise_quantize(w)
    y_ref = qmm.scaled_int8_mm_ref(qa, qb, sa, sb).reshape(y.shape)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_grads_close_to_f32_reference():
    x, w = _toy()
    key = jax.random.PRNGKey(3)

    def loss_q(a, b):
        return jnp.sum(qt.int8_linear(a, b, key, qt.DEFAULT) ** 2)

    def loss_f(a, b):
        return jnp.sum(jnp.einsum("...i,oi->...o", a, b) ** 2)

    gq = jax.grad(loss_q, argnums=(0, 1))(x, w)
    gf = jax.grad(loss_f, argnums=(0, 1))(x, w)
    for got, want in zip(gq, gf):
        got, want = np.asarray(got), np.asarray(want)
        rel = np.linalg.norm(got - want) / np.linalg.norm(want)
        assert rel < 0.15, rel           # int8 grads track f32 direction


def test_grad_weight_seed_dependent():
    x, w = _toy()

    def gw(key):
        return jax.grad(
            lambda b: jnp.sum(qt.int8_linear(x, b, key, qt.DEFAULT) ** 2)
        )(w)

    g1 = np.asarray(gw(jax.random.PRNGKey(0)))
    g2 = np.asarray(gw(jax.random.PRNGKey(1)))
    g1b = np.asarray(gw(jax.random.PRNGKey(0)))
    np.testing.assert_array_equal(g1, g1b)   # same key -> same grads
    assert np.any(g1 != g2)                  # different key -> SR differs


def test_per_leg_switchability():
    x, w = _toy()
    f32 = jax.grad(
        lambda a, b: jnp.sum(jnp.einsum("...i,oi->...o", a, b) ** 2),
        argnums=(0, 1))(x, w)
    # only grad_input int8: dw must be exactly the f32 dw
    cfg = qt.QTrainConfig(forward=False, grad_input=True, grad_weight=False)
    g = jax.grad(lambda a, b: jnp.sum(qt.int8_linear(a, b, None, cfg) ** 2),
                 argnums=(0, 1))(x, w)
    np.testing.assert_array_equal(np.asarray(g[1]), np.asarray(f32[1]))
    assert np.any(np.asarray(g[0]) != np.asarray(f32[0]))
    # only grad_weight int8: dx must be exactly the f32 dx
    cfg = qt.QTrainConfig(forward=False, grad_input=False, grad_weight=True)
    g = jax.grad(lambda a, b: jnp.sum(qt.int8_linear(a, b, None, cfg) ** 2),
                 argnums=(0, 1))(x, w)
    np.testing.assert_array_equal(np.asarray(g[0]), np.asarray(f32[0]))
    assert np.any(np.asarray(g[1]) != np.asarray(f32[1]))


def test_int8_linear_under_jit():
    # outer-jit fusion may reassociate the f32 epilogue multiplies, so the
    # contract here is near-equality (bitwise parity is kernel-vs-ref above)
    x, w = _toy()
    f = jax.jit(lambda a, b, k: qt.int8_linear(a, b, k, qt.DEFAULT))
    y = f(x, w, jax.random.PRNGKey(0))
    y2 = qt.int8_linear(x, w, jax.random.PRNGKey(0), qt.DEFAULT)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# PrecisionPolicy train_compute axis
# ---------------------------------------------------------------------------

def test_policy_validation_and_roundtrip():
    with pytest.raises(ValueError):
        PrecisionPolicy.search(5.0, train_compute="int4")
    pol = PrecisionPolicy.search(5.0, train_compute="int8",
                                 sr_key=jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(pol)
    pol2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert pol2.train_compute == "int8" and pol2.sr_key is not None
    assert float(pol2.tau) == 5.0


def test_f32_policy_is_base_object():
    from repro.train import steps as steps_mod
    hp = steps_mod.TrainHParams()
    assert hp.train_compute == "f32"
    base = PrecisionPolicy.search(5.0)
    assert steps_mod._train_policy(hp, base, jnp.zeros((), jnp.int32)) is base


def _qlinear_fixture():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 16), jnp.float32)
    p = {"w": jax.random.normal(jax.random.fold_in(key, 1), (8, 16),
                                jnp.float32),
         "aw": jnp.ones((8, 1)), "ax": jnp.asarray(6.0)}
    return x, p


def test_qlinear_f32_branch_unchanged():
    # the f32 train_compute path through qlinear must be bit-identical to
    # the inline fake-quantize + einsum it used before the axis existed
    from repro.core import quantizers as qz
    x, p = _qlinear_fixture()
    pol = PrecisionPolicy.QAT8
    assert pol.train_compute == "f32"
    y = L.qlinear(x, p, None, pol, None)
    xq = qz.quantize_act_any(x, p["ax"], 8, True)
    wq = qz.quantize_weight(p["w"], p["aw"].reshape(8, 1), 8)
    y_ref = jnp.einsum("...i,oi->...o", xq, wq)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    # the int8 branch really is a different path
    pol8 = pol.with_train_compute("int8", jax.random.PRNGKey(0))
    y8 = L.qlinear(x, p, None, pol8, None)
    assert y.shape == y8.shape
    assert np.any(np.asarray(y) != np.asarray(y8))


def test_qlinear_int8_sr_key_changes_grads():
    x, p = _qlinear_fixture()

    def gw(seed):
        pol = PrecisionPolicy.QAT8.with_train_compute(
            "int8", jax.random.PRNGKey(seed))
        return np.asarray(jax.grad(
            lambda q: jnp.sum(L.qlinear(x, {**p, "w": q}, None, pol,
                                        None) ** 2))(p["w"]))

    assert np.any(gw(0) != gw(1))


# ---------------------------------------------------------------------------
# Optimizer-state dtype regression (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adamw_state_dtype_persists(dtype):
    opt = opt_mod.AdamW(schedule=opt_mod.constant_schedule(1e-3),
                        state_dtype=dtype)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    grads = {"w": jnp.full((4, 4), 0.1)}
    for leaf in jax.tree_util.tree_leaves(state):
        assert leaf.dtype == dtype
    _, state = opt.update(grads, state, params, 0)
    for leaf in jax.tree_util.tree_leaves(state):
        assert leaf.dtype == dtype


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adafactor_state_dtype_persists(dtype):
    opt = opt_mod.Adafactor(schedule=opt_mod.constant_schedule(1e-3),
                            min_factor_dim=4, state_dtype=dtype)
    params = {"w": jnp.ones((8, 8)), "b": jnp.ones((8,))}   # factored + not
    state = opt.init(params)
    grads = jax.tree_util.tree_map(lambda p: p * 0.1, params)
    for leaf in jax.tree_util.tree_leaves(state):
        assert leaf.dtype == dtype
    upd, state = opt.update(grads, state, params, 0)
    for leaf in jax.tree_util.tree_leaves(state):
        assert leaf.dtype == dtype
    for leaf in jax.tree_util.tree_leaves(upd):   # updates stay param dtype
        assert leaf.dtype == jnp.float32
        assert np.all(np.isfinite(np.asarray(leaf)))


@pytest.mark.parametrize("optimizer", ["adamw", "adafactor"])
def test_trainhparams_opt_state_dtype_reaches_both_optimizers(optimizer):
    from repro.train import steps as steps_mod
    hp = steps_mod.TrainHParams(optimizer=optimizer,
                                opt_state_dtype="bfloat16")
    opt_w, opt_t = steps_mod.make_optimizers(hp)
    assert jnp.dtype(opt_w.state_dtype) == jnp.bfloat16
    assert jnp.dtype(opt_t.state_dtype) == jnp.bfloat16
    params = {"w": jnp.ones((256, 256))}
    for leaf in jax.tree_util.tree_leaves(opt_w.init(params)):
        assert leaf.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# End-to-end: dae-ad search steps, int8 vs f32
# ---------------------------------------------------------------------------

def test_dae_ad_int8_converges_with_f32():
    from repro.core import search as search_mod
    from repro.data import pipeline as pipe
    from repro.models import tinyml
    cfg = tinyml.TINY_CONFIGS["dae-ad"]
    init_fn, apply_fn, specs = tinyml.build(cfg)
    params0, nas0 = init_fn(jax.random.PRNGKey(0))
    loss_fn = lambda pred, batch: tinyml.task_loss(cfg, pred, batch)
    batch = next(iter(pipe.SyntheticTiny(cfg, n=32, seed=0).batches(16)))
    finals = {}
    for tc in ("f32", "int8"):
        s = search_mod.SearchSettings(cfg=cfg.quant, train_compute=tc)
        drv = search_mod.SearchDriver(apply_fn, loss_fn, specs,
                                      params0, nas0, s)
        losses = []
        for i in range(8):
            drv.params, drv._ow, loss = drv._w_step(
                drv.params, drv.nas, drv.tau, drv._ow,
                jnp.asarray(i), batch)
            losses.append(float(loss))
        assert losses[-1] < losses[0], (tc, losses)
        finals[tc] = losses
    drop = finals["f32"][0] - finals["f32"][-1]
    assert abs(finals["int8"][-1] - finals["f32"][-1]) < max(abs(drop), 1e-4)


def test_tiny_lm_forward_and_grad_with_int8():
    # exercises the per-layer SR key fan-out through the scanned blocks
    from repro.config import get_config
    from repro.models import transformer as tfm
    cfg = dataclasses.replace(
        get_config("qwen1.5-4b").reduced(), n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128)
    params, nas = tfm.init_model(cfg, jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    pol = PrecisionPolicy.search(5.0, train_compute="int8",
                                 sr_key=jax.random.PRNGKey(0))

    def loss(p):
        logits = tfm.forward(p, nas, cfg, {"tokens": ids}, pol, remat=False)
        return jnp.mean(logits.astype(jnp.float32) ** 2)

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    gn = opt_mod.global_norm(grads)
    assert np.isfinite(float(gn)) and float(gn) > 0
