"""Expert-axis (batched) fused GEMM parity harness (PR 4).

``models/serving.init_deployed_linear(expert_axis=E)`` stacks every QTensor
leaf with a leading expert axis and builds per-expert fused buffers under
ONE static tile schedule; ``QTensor.matmul`` then dispatches
``einsum("ecd,efd->ecf")``-shaped grouped expert GEMMs as a single
expert-batched ``pallas_call`` (kernels/quant_matmul.quant_matmul_fused_3d,
grid ``(E, M/bm, T)``).

The acceptance contract is deliberately different from the single-weight
fused path: the expert kernel dequantizes each weight tile in VMEM *before*
the MXU dot, so at f32 compute its output is **bit-exact with the dense
einsum reference** it replaced (the removed ``dq_expert_weights`` +
``jnp.einsum`` hot path) — while HBM weight traffic stays the packed
sub-byte bytes.  The per-group Pallas reference path scales the
accumulator after the dot and agrees to f32 roundoff.

Also pinned here: launch-count guards (ONE ``pallas_call`` per expert
site, counted in the traced jaxpr), the ``_init_deployed_ffn`` RNG-key
regression (``shared`` and ``dense_res`` sub-trees must differ), and the
packed-MLA-decode vs weight-absorption reference regression.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import DeploySpec, get_config
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import serving

REF_TOL = 1e-5


def _cfg(fractions=(0.25, 0.55, 0.20), align=8):
    cfg = get_config("deepseek-v3-671b").reduced()
    return dataclasses.replace(
        cfg, deploy=DeploySpec(fractions=fractions, align=align,
                               act_bits=cfg.deploy.act_bits,
                               kv_cache_bits=cfg.deploy.kv_cache_bits))


def _expert_site(seed, E, c_out, c_in, cfg, tile_n="auto"):
    dp = serving.init_deployed_linear(jax.random.PRNGKey(seed), c_in, c_out,
                                      cfg, expert_axis=E, tile_n=tile_n)
    return dp["w"]


def _x(seed, E, m, c_in):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((E, m, c_in)),
        jnp.float32)


# (name, E, c_out, c_in, fractions, tile_n)
CASES = [
    ("E1-aligned", 1, 48, 32, (0.25, 0.55, 0.20), "auto"),
    ("E4-off-tile-ff-d", 4, 50, 33, (0.25, 0.55, 0.20), "auto"),
    ("E8-low-bit-heavy", 8, 24, 20, (0.50, 0.25, 0.25), "auto"),
    ("E4-single-group-8b", 4, 40, 16, (0.0, 0.0, 1.0), "auto"),
    # explicit tile_n=16 makes the middle group (24 rows) off-tile: tile
    # padding lands *inside* the walk, forcing the batched output gather
    ("E4-output-gather", 4, 50, 33, (0.25, 0.55, 0.20), 16),
]


@pytest.mark.parametrize("name,E,c_out,c_in,fractions,tile_n", CASES,
                         ids=[c[0] for c in CASES])
def test_expert_fused_bitexact_with_dense_einsum_reference(
        name, E, c_out, c_in, fractions, tile_n):
    """Acceptance: ONE expert-batched launch == the dense einsum it
    replaced, bit for bit at f32 — for seeded bit mixes, E in {1, 4, 8}
    and off-tile ff/d shapes."""
    qt = _expert_site(11, E, c_out, c_in, _cfg(fractions), tile_n)
    assert qt.experts == E and qt.fused_packed is not None
    assert qt.fused_packed.shape[0] == E
    if name == "E4-output-gather":
        assert qt.fused_perm is not None     # really exercises the gather
    w_dense = qt.dequantize(jnp.float32)     # (E, c_out, c_in) — test-only
    assert w_dense.shape == (E, c_out, c_in)
    # m >= 2 only: XLA dispatches an M=1 contraction to a matvec whose K
    # reduction associates differently from the kernel's (M-padded) GEMM,
    # so bit-equality with the unpadded reference holds on GEMM-shaped
    # inputs.  That IS the serving contract — _deployed_moe always
    # contracts capacity >= 8 rows per expert; m=1 stays covered at f32
    # roundoff by test_expert_backends_agree.
    for m in (5, 8, 130):
        x = _x(m, E, m, c_in)
        y_fused = np.asarray(qt.matmul(x, jnp.float32, backend="pallas"))
        y_ref = np.asarray(jnp.einsum("ecd,efd->ecf", x, w_dense))
        np.testing.assert_array_equal(y_fused, y_ref,
                                      err_msg=f"{name} m={m}")
        assert y_fused.shape == (E, m, c_out)


@pytest.mark.parametrize("name,E,c_out,c_in,fractions,tile_n", CASES,
                         ids=[c[0] for c in CASES])
def test_expert_backends_agree(name, E, c_out, c_in, fractions, tile_n):
    """Fused vs per-group-per-expert Pallas vs jnp: same math, different
    scale placement — f32-roundoff agreement (per-group scales the
    accumulator after the dot, PR 3 style)."""
    qt = _expert_site(13, E, c_out, c_in, _cfg(fractions), tile_n)
    for m in (1, 6):
        x = _x(17 + m, E, m, c_in)
        y_fused = np.asarray(qt.matmul(x, jnp.float32, backend="pallas"))
        y_pg = np.asarray(qt.matmul(x, jnp.float32,
                                    backend="pallas-pergroup"))
        y_jnp = np.asarray(qt.matmul(x, jnp.float32, backend="jnp"))
        scale = max(1.0, np.abs(y_jnp).max())
        np.testing.assert_allclose(y_fused, y_pg, atol=REF_TOL * scale,
                                   rtol=REF_TOL, err_msg=f"{name} m={m}")
        np.testing.assert_allclose(y_fused, y_jnp, atol=REF_TOL * scale,
                                   rtol=REF_TOL, err_msg=f"{name} m={m}")


def test_expert_matmul_rejects_bad_leading_axis():
    qt = _expert_site(3, 4, 24, 16, _cfg())
    with pytest.raises(ValueError, match="expert"):
        qt.matmul(jnp.zeros((3, 5, 16)), backend="pallas")   # wrong E
    with pytest.raises(ValueError, match="contraction"):
        qt.matmul(jnp.zeros((4, 5, 12)), backend="pallas")   # wrong c_in


# ---------------------------------------------------------------------------
# Launch-count guards: ONE pallas_call per expert site
# ---------------------------------------------------------------------------

def test_expert_site_is_one_launch():
    """The batched grid covers E: one fused launch serves all experts of a
    site, while the per-group reference pays E launches per precision
    group."""
    E = 4
    qt = _expert_site(7, E, 50, 33, _cfg())
    x = _x(2, E, 6, 33)
    n_groups = len(qt.bits)
    assert n_groups > 1
    fused = ops.count_pallas_launches(
        lambda x: qt.matmul(x, jnp.float32, backend="pallas"), x)
    pg = ops.count_pallas_launches(
        lambda x: qt.matmul(x, jnp.float32, backend="pallas-pergroup"), x)
    assert fused == 1
    assert pg == E * n_groups
    assert ops.count_pallas_launches(
        lambda x: qt.matmul(x, jnp.float32, backend="jnp"), x) == 0


def test_deployed_moe_ffn_is_one_launch_per_site():
    """Whole deployed MoE FFN (routed experts + shared expert): exactly one
    pallas_call per QTensor site on the fused backend."""
    from repro.api.qtensor import QTensor
    cfg = get_config("deepseek-v3-671b").reduced()
    p = serving._init_deployed_ffn(jax.random.PRNGKey(0), cfg)
    sites = [t for t in jax.tree_util.tree_leaves(
        p, is_leaf=lambda t: isinstance(t, QTensor))
        if isinstance(t, QTensor)]
    assert all(qt.fused_packed is not None for qt in sites)
    assert sum(qt.experts == cfg.n_experts for qt in sites) == 3
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 8, cfg.d_model)), jnp.float32)
    n = ops.count_pallas_launches(
        lambda x: serving._deployed_ffn_full(p, cfg, x, backend="pallas"), x)
    assert n == len(sites), (n, len(sites))


# ---------------------------------------------------------------------------
# _init_deployed_ffn RNG-key regression (satellite bugfix)
# ---------------------------------------------------------------------------

def test_shared_and_dense_res_ffn_weights_differ():
    """Pre-PR4, a config with BOTH a shared expert and a dense residual MLP
    reused RNG keys ks[4..6] for the two sub-trees, deploying identical
    weights.  Pin sff == rff so the shapes match and the packed bytes must
    still differ."""
    cfg = get_config("deepseek-v3-671b").reduced()
    rff = cfg.moe_d_ff * 2
    cfg = dataclasses.replace(cfg, n_shared_experts=2, dense_residual_ff=rff)
    p = serving._init_deployed_ffn(jax.random.PRNGKey(0), cfg)
    assert "shared" in p and "dense_res" in p
    for name in ("w_gate", "w_up", "w_down"):
        qa, qb = p["shared"][name]["w"], p["dense_res"][name]["w"]
        assert qa.c_out == qb.c_out and qa.c_in == qb.c_in, name
        assert any(not np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(qa.packed, qb.packed)), \
            f"shared and dense_res {name} deployed identical weights"


# ---------------------------------------------------------------------------
# Packed MLA decode vs the removed weight-absorption reference
# ---------------------------------------------------------------------------

def _absorbed_mla_decode(p, cfg, x, cache, pos, dq_linear, dense_w):
    """The pre-PR4 decode math: wkv_b absorbed per head from a dense view.

    Absorption is an exact linear-algebra rewrite of latent expansion, so
    the packed path must reproduce it to f32 roundoff."""
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    cd = cfg.cdtype
    cq = L.rmsnorm(dq_linear(x, p["wq_a"]), p["q_norm"])
    q = dq_linear(cq, p["wq_b"]).reshape(B, 1, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv_new = dq_linear(x, p["wkv_a"])
    c_kv, k_rope_new = ckv_new[..., :kvr], ckv_new[..., kvr:]
    c_kv = L.rmsnorm(c_kv, p["kv_norm"])
    cos, sin, rot = L.rope_freqs(rope, cfg.rope_theta, pos[None], 1.0)
    q_rope = L.apply_rope(q_rope, cos, sin, rot)
    k_rope_new = L.apply_rope(k_rope_new[:, :, None, :], cos, sin, rot)[:, :, 0]
    qc, qs = attn.quant_per_token(c_kv)
    pos0 = pos.astype(jnp.int32)
    cache = {
        "ckv": jax.lax.dynamic_update_slice(cache["ckv"], qc, (0, pos0, 0)),
        "ckv_scale": jax.lax.dynamic_update_slice(cache["ckv_scale"], qs,
                                                  (0, pos0, 0)),
        "krope": jax.lax.dynamic_update_slice(
            cache["krope"], k_rope_new.astype(jnp.bfloat16), (0, pos0, 0)),
    }
    S = cache["ckv"].shape[1]
    wkv_b = dense_w("wkv_b").reshape(H, nope + vd, kvr)
    w_uk, w_uv = wkv_b[:, :nope], wkv_b[:, nope:]
    q_lat = jnp.einsum("bqhn,hnr->bqhr", q_nope.astype(cd), w_uk.astype(cd))
    ckv_f = (cache["ckv"].astype(jnp.float32) * cache["ckv_scale"]).astype(cd)
    s = jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv_f).astype(jnp.float32)
    s = s + jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(cd),
                       cache["krope"].astype(cd)).astype(jnp.float32)
    s = s / math.sqrt(nope + rope)
    valid = jnp.arange(S)[None, None, None, :] <= pos0
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(cd)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", w, ckv_f)
    o = jnp.einsum("bqhr,hvr->bqhv", o_lat, w_uv.astype(cd))
    return dq_linear(o.reshape(B, 1, H * vd), p["wo"]), cache


def test_packed_mla_decode_matches_absorption_reference():
    cfg = dataclasses.replace(get_config("deepseek-v3-671b").reduced(),
                              compute_dtype="float32")
    p = serving._init_deployed_attn(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    rng = np.random.default_rng(0)
    cache = attn.init_mla_cache(cfg, B, S)
    for i in range(4):                       # pretend 4 tokens were decoded
        ck = jnp.asarray(rng.standard_normal((B, 1, cfg.kv_lora_rank)) * .5,
                         jnp.float32)
        qc, qs = attn.quant_per_token(ck)
        cache["ckv"] = cache["ckv"].at[:, i].set(qc[:, 0])
        cache["ckv_scale"] = cache["ckv_scale"].at[:, i].set(qs[:, 0])
        cache["krope"] = cache["krope"].at[:, i].set(jnp.asarray(
            rng.standard_normal((B, cfg.qk_rope_dim)), jnp.bfloat16))
    x = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)) * .3,
                    jnp.float32)
    dq = serving._dq(cfg.cdtype, "jnp")
    pos = jnp.asarray(4)
    y_new, c_new = attn.mla_decode(p, cfg, x, cache, pos, dq)
    y_ref, c_ref = _absorbed_mla_decode(
        p, cfg, x, cache, pos, dq,
        lambda n: serving.debug_dense_view(p[n], cfg.cdtype))
    scale = max(1.0, float(jnp.abs(y_ref).max()))
    np.testing.assert_allclose(np.asarray(y_new, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=2e-5 * scale, rtol=2e-5)
    for k in c_new:                          # cache writes are identical
        np.testing.assert_array_equal(np.asarray(c_new[k]),
                                      np.asarray(c_ref[k]))
