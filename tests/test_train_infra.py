"""Training-infrastructure tests: optimizers, schedules, checkpointing
(save/restore/atomicity/GC), data pipeline determinism, fault machinery."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import pipeline as pipe
from repro.dist import fault
from repro.optim import optimizers as opt
from repro.train import checkpoint as ck


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}


@pytest.mark.parametrize("make", [
    lambda: opt.AdamW(schedule=opt.constant_schedule(0.1)),
    lambda: opt.Adafactor(schedule=opt.constant_schedule(0.5)),
    lambda: opt.SGD(schedule=opt.constant_schedule(0.1)),
])
def test_optimizers_minimize_quadratic(make):
    o = make()
    params = _quad_params()
    state = o.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for step in range(60):
        grads = jax.grad(loss)(params)
        upd, state = o.update(grads, state, params, jnp.asarray(step))
        params = opt.apply_updates(params, upd)
    assert float(loss(params)) < 0.2 * float(loss(_quad_params()))


def test_adafactor_state_is_factored():
    """Second moment of an (N, K) matrix stores N+K floats, not N*K."""
    o = opt.Adafactor(schedule=opt.constant_schedule(0.1),
                      min_factor_dim=64)
    params = {"w": jnp.zeros((256, 512)), "small": jnp.zeros((8,))}
    st = o.init(params)
    assert st["f"]["w"]["vr"].shape == (256,)
    assert st["f"]["w"]["vc"].shape == (512,)
    assert "v" in st["f"]["small"]
    n_state = sum(x.size for x in jax.tree_util.tree_leaves(st))
    assert n_state < 256 * 512  # far smaller than AdamW's 2*N*K


def test_adamw_bf16_state_compression():
    o = opt.AdamW(schedule=opt.constant_schedule(0.1),
                  state_dtype=jnp.bfloat16)
    st = o.init({"w": jnp.zeros((16, 16))})
    assert st["m"]["w"].dtype == jnp.bfloat16


def test_wsd_schedule_shape():
    s = opt.wsd_schedule(1.0, warmup=10, stable=50, decay=20)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert abs(float(s(40)) - 1.0) < 1e-6          # stable plateau
    assert float(s(75)) < 0.5                       # decaying
    assert float(s(80)) <= 0.011                    # decayed


def test_cosine_schedule_monotone_decay():
    s = opt.cosine_schedule(1.0, warmup=5, total=50)
    vals = [float(s(i)) for i in range(5, 51, 5)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))


def test_global_norm_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


# ---------------------------------------------------------------------------
# Checkpointing (fault tolerance)
# ---------------------------------------------------------------------------

def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 4))},
            "step": jnp.asarray(7, jnp.int32),
            "tau": jnp.asarray(3.3)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(7, st, meta={"data_step": 123}, block=True)
    mgr.wait()
    restored, step, meta = mgr.restore_latest(jax.eval_shape(lambda: st))
    assert step == 7 and meta["data_step"] == 123
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                               np.asarray(st["params"]["w"]))


def test_checkpoint_keeps_latest_k(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, st, block=True)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_ignores_partial_writes(tmp_path):
    """A crashed (uncommitted) save must not be offered for restore —
    atomic-rename commit protocol."""
    mgr = ck.CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(5, st, block=True)
    mgr.wait()
    # simulate a crash mid-save: stray tmp dir for step 9
    os.makedirs(os.path.join(str(tmp_path), "tmp_step_9"), exist_ok=True)
    assert mgr.latest_step() == 5


def test_checkpoint_restore_on_fresh_dir(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path / "empty"))
    restored, step, meta = mgr.restore_latest(jax.eval_shape(_state))
    assert restored is None and step is None


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_lm_deterministic():
    a = pipe.SyntheticLM(100, 16, 8, seed=3)._gen(5)
    b = pipe.SyntheticLM(100, 16, 8, seed=3)._gen(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_synthetic_lm_host_sharding():
    """Two hosts each produce half the global batch, disjoint streams."""
    h0 = pipe.SyntheticLM(100, 16, 8, host_count=2, host_id=0, seed=1)._gen(0)
    h1 = pipe.SyntheticLM(100, 16, 8, host_count=2, host_id=1, seed=1)._gen(0)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_synthetic_lm_labels_are_shifted_tokens():
    b = pipe.SyntheticLM(100, 16, 4, seed=0)._gen(0)
    # labels[t] is the next token after tokens[t] by construction
    assert b["labels"].shape == b["tokens"].shape
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_state_checkpointable():
    gen = pipe.SyntheticLM(100, 8, 4, seed=2)
    it = iter(gen)
    next(it), next(it)
    saved = gen.state.to_dict()
    b3 = next(it)
    gen2 = pipe.SyntheticLM(100, 8, 4, seed=2)
    gen2.state = pipe.PipelineState.from_dict(saved)
    b3b = next(iter(gen2))
    np.testing.assert_array_equal(b3["tokens"], b3b["tokens"])


def test_prefetcher_yields_all():
    src = ({"i": np.asarray([i])} for i in range(5))
    out = [b["i"][0] for b in pipe.Prefetcher(src, depth=2)]
    assert out == list(range(5))


# ---------------------------------------------------------------------------
# Fault machinery
# ---------------------------------------------------------------------------

def test_heartbeat_detects_dead_host():
    hb = fault.Heartbeat([0, 1, 2], timeout_s=10.0)
    for h in (0, 1, 2):
        hb.beat(h, t=100.0)
    hb.beat(0, t=120.0)
    hb.beat(1, t=120.0)
    assert hb.check(now=121.0) == [2]
    assert hb.alive() == [0, 1]


def test_elastic_mesh_shrinks_data_axis():
    em = fault.ElasticMesh(model=16, chips_per_host=4)
    assert em.shape_for(64) == (16, 16)       # 256 chips
    shape = em.shape_for(60)                  # lost 4 hosts -> 240 chips
    assert shape == (15, 16)                  # data axis shrinks
    assert shape[1] == 16                     # model axis preserved
    with pytest.raises(RuntimeError):
        em.shape_for(1)


def test_straggler_policy_flags_slow_host():
    sp = fault.StragglerPolicy(threshold=1.3, window=4, min_samples=4)
    for t in range(4):
        sp.record(0, 1.0)
        sp.record(1, 1.0)
        sp.record(2, 2.0)   # consistently 2x slower
    assert sp.stragglers() == [2]
