"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step + one serve decode on CPU; asserts output shapes
and no NaNs (assignment requirement f).

This file dominates tier-1 wall-clock, so every arch is pinned to one of
``conftest.N_SMOKE_SHARDS`` shard marks (``smoke0`` .. ``smoke3``) and CI
runs the file as a matrix dimension — one job per shard via
``pytest -m smokeN`` (.github/workflows/ci.yml).  A plain local ``pytest``
run still executes everything: marks only partition, never skip; tests
added here without a mark are auto-assigned a shard by conftest.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import N_SMOKE_SHARDS
from repro.api import PrecisionPolicy
from repro.config import ARCH_IDS, get_config
from repro.models import serving
from repro.models import transformer as tfm
from repro.train import steps as steps_mod

ALL = [pytest.param(arch,
                    marks=getattr(pytest.mark, f"smoke{i % N_SMOKE_SHARDS}"))
       for i, arch in enumerate(ARCH_IDS)]


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        b["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ALL)
def test_reduced_forward_all_policies(arch):
    cfg = get_config(arch).reduced()
    params, nas = tfm.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    for policy in (PrecisionPolicy.FLOAT, PrecisionPolicy.QAT8,
                   PrecisionPolicy.search(5.0), PrecisionPolicy.FROZEN):
        logits = tfm.forward(params, nas if policy.needs_nas else None,
                             cfg, batch, policy, remat=False)
        assert logits.shape == (2, 16, cfg.padded_vocab), policy
        assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size]))), \
            policy


@pytest.mark.parametrize("arch", ALL)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    hp = steps_mod.TrainHParams.for_arch(cfg, total_steps=4)
    state = steps_mod.init_train_state(cfg, hp, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    step = jax.jit(steps_mod.make_train_step(cfg, hp))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(state["step"]) == 1
    # one theta step too (the 20% path)
    tstep = jax.jit(steps_mod.make_theta_step(cfg, hp, 32))
    state, m2 = tstep(state, batch)
    assert np.isfinite(float(m2["reg_cost"])) and float(m2["reg_cost"]) > 0


@pytest.mark.parametrize("arch", ALL)
def test_reduced_serve_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    dparams = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    logits, _ = serving.prefill(dparams, cfg, batch)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))
    caches = serving.init_caches(cfg, 2, 32)
    lg, c2 = serving.decode_step(dparams, cfg,
                                 jnp.zeros((2, 1), jnp.int32), caches,
                                 jnp.asarray(16, jnp.int32))
    assert lg.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lg[..., :cfg.vocab_size])))
    # cache tree structure preserved (donation-compatible)
    assert jax.tree_util.tree_structure(c2) \
        == jax.tree_util.tree_structure(caches)


@pytest.mark.smoke1
def test_train_loss_decreases_dense():
    """A few steps on the learnable synthetic stream must reduce CE."""
    from repro.data import pipeline as pipe
    cfg = get_config("qwen1.5-4b").reduced()
    hp = steps_mod.TrainHParams.for_arch(cfg, lr=3e-3, total_steps=60,
                                         warmup_steps=5)
    state = steps_mod.init_train_state(cfg, hp, jax.random.PRNGKey(0))
    gen = pipe.SyntheticLM(cfg.vocab_size, 32, 8, seed=1)
    step = jax.jit(steps_mod.make_train_step(cfg, hp))
    it = iter(gen)
    losses = []
    for _ in range(60):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.05,         (losses[:3], losses[-3:])


@pytest.mark.smoke2
def test_mtp_auxiliary_head():
    cfg = get_config("deepseek-v3-671b").reduced()
    assert cfg.mtp
    params, nas = tfm.init_model(cfg, jax.random.PRNGKey(0))
    logits, mtp = tfm.forward_with_mtp(params, nas, cfg, _batch(cfg),
                                       PrecisionPolicy.search(5.0),
                                       remat=False)
    assert mtp is not None and mtp.shape == logits.shape
