"""Sec. III-C deployment transform: reorder/group/pack/split must preserve
the layer function exactly (up to integer-quantization rounding).  The
transform's output is a repro.api.QTensor (registered pytree)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deploy as dpl
from repro.core import mixedprec as mp
from repro.core import quantizers as qz
from repro.models import serving

CFG = mp.MixedPrecConfig()


def _searched_linear(key, c_out=32, c_in=48):
    w = np.asarray(jax.random.normal(key, (c_out, c_in)), np.float32)
    gamma = np.asarray(jax.random.normal(jax.random.fold_in(key, 1),
                                         (c_out, 3)) * 3, np.float32)
    alpha_w = np.abs(w).max(-1)
    return w, gamma, alpha_w


def test_group_channels_partitions():
    """Grouping is a permutation: every channel appears exactly once."""
    bits = np.asarray([2, 8, 4, 4, 2, 8, 8, 2])
    perm, sizes = dpl.group_channels(bits, (2, 4, 8), align=1)
    assert sorted(perm.tolist()) == list(range(8))
    assert sizes == {2: 3, 4: 2, 8: 3}


def test_group_channels_alignment_promotes_upward():
    """With align=4, group sizes are multiples of 4 and promotion only moves
    channels to HIGHER precision (never down)."""
    rng = np.random.default_rng(0)
    bits = rng.choice([2, 4, 8], size=37)
    perm, sizes = dpl.group_channels(bits, (2, 4, 8), align=4)
    assert sum(sizes.values()) == 37
    assert sorted(perm.tolist()) == list(range(37))
    offset = 0
    for b in (2, 4, 8):
        group = perm[offset:offset + sizes[b]]
        offset += sizes[b]
        if b != 8:  # top precision absorbs the remainder
            assert sizes[b] % 4 == 0
        for ch in group:
            assert bits[ch] <= b  # promotion upward only


def test_deploy_linear_function_preserved():
    """Deployed (reordered+packed+split) layer == frozen fake-quant layer."""
    w, gamma, alpha_w = _searched_linear(jax.random.PRNGKey(0))
    d = dpl.deploy_linear(w, gamma, alpha_w, None, 6.0, CFG, align=1)
    # reference: frozen per-channel fake-quant of the float weights
    frozen = mp.frozen_weight(jnp.asarray(w), jnp.asarray(gamma),
                              jnp.asarray(alpha_w), CFG)
    deq = dpl.dequantize_deployed(d)        # (c_out, c_in), canonical order
    np.testing.assert_allclose(deq, np.asarray(frozen), atol=1e-5)


def test_deploy_alignment_only_adds_precision():
    """align=8 deployment must be at least as accurate as align=1."""
    w, gamma, alpha_w = _searched_linear(jax.random.PRNGKey(1), 40, 32)
    d1 = dpl.deploy_linear(w, gamma, alpha_w, None, 6.0, CFG, align=1)
    d8 = dpl.deploy_linear(w, gamma, alpha_w, None, 6.0, CFG, align=8)
    e1 = np.abs(dpl.dequantize_deployed(d1) - w).sum()
    e8 = np.abs(dpl.dequantize_deployed(d8) - w).sum()
    assert e8 <= e1 + 1e-5
    assert dpl.memory_bits(d8) >= dpl.memory_bits(d1)


def test_memory_bits_counts():
    w, gamma, alpha_w = _searched_linear(jax.random.PRNGKey(2), 16, 24)
    d = dpl.deploy_linear(w, gamma, alpha_w, None, 6.0, CFG, align=1)
    # packed bytes per group: rows * ceil(24*bits/8) bytes -> 8*size bits
    exp = sum(int(p.size) * 8 for p in d.packed)
    assert dpl.memory_bits(d) == exp
    # and the total is bounded below by the ideal (unpadded) bit count
    bits = np.asarray(jnp.argmax(jnp.asarray(gamma), -1))
    ideal = sum(CFG.weight_bits[b] * 24 for b in bits)
    assert dpl.memory_bits(d) >= ideal


def test_propagate_perm_preserves_composition():
    """Reordering layer n's outputs + permuting layer n+1's inputs is a
    no-op on the composed function (the paper's Fig. 2 transform)."""
    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((8, 4)).astype(np.float32)
    w2 = rng.standard_normal((5, 8)).astype(np.float32)
    perm = rng.permutation(8)
    x = rng.standard_normal((3, 4)).astype(np.float32)
    y_ref = x @ w1.T @ w2.T
    w1p = w1[perm]
    w2p = dpl.propagate_perm(w2, perm)
    y = x @ w1p.T @ w2p.T
    np.testing.assert_allclose(y, y_ref, rtol=1e-5)


def test_deployed_from_search_matches_dq_linear():
    """serving.dq_linear on the deployed format == frozen reference matmul
    with the canonical-order restoration (inv_perm)."""
    key = jax.random.PRNGKey(4)
    w, gamma, alpha_w = _searched_linear(key, 16, 32)

    from repro.config import DeploySpec

    class QCfg:
        quant = CFG
        deploy = DeploySpec(align=1)
    dp = serving.deployed_from_search(w, gamma, alpha_w, None, 6.0, QCfg,
                                      restore_order=True)
    x = jax.random.normal(jax.random.fold_in(key, 2), (4, 32))
    y = serving.dq_linear(x, dp, compute_dtype=jnp.float32)
    frozen = mp.frozen_weight(jnp.asarray(w), jnp.asarray(gamma),
                              jnp.asarray(alpha_w), CFG)
    y_ref = x @ frozen.T
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_dq_linear_backends_agree(backend):
    key = jax.random.PRNGKey(5)
    from repro.config import ArchConfig
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64)
    dp = serving.init_deployed_linear(key, 64, 128, cfg)
    x = jax.random.normal(key, (8, 64))
    y = serving.dq_linear(x, dp, jnp.float32, backend=backend)
    y_ref = serving.dq_linear(x, dp, jnp.float32, backend="jnp")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_group_channels_align_128_promotes_upward_only():
    """MXU-lane alignment: with align=128 every non-top group size is a
    multiple of 128 and NO channel is ever demoted to fewer bits."""
    rng = np.random.default_rng(7)
    bits = rng.choice([2, 4, 8], size=500, p=[0.3, 0.5, 0.2])
    perm, sizes = dpl.group_channels(bits, (2, 4, 8), align=128)
    assert sorted(perm.tolist()) == list(range(500))
    assert sum(sizes.values()) == 500
    for b in (2, 4):                       # top group absorbs the remainder
        assert sizes[b] % 128 == 0
    offset = 0
    for b in (2, 4, 8):
        for ch in perm[offset:offset + sizes[b]]:
            assert bits[ch] <= b           # upward-only promotion
        offset += sizes[b]


def test_group_channels_align_128_small_layer_collapses_upward():
    """c_out < align: everything must end in the top-precision group (the
    only one exempt from alignment) — never dropped, never demoted."""
    bits = np.asarray([2, 4, 2, 8, 4, 4, 2, 8])
    perm, sizes = dpl.group_channels(bits, (2, 4, 8), align=128)
    assert sizes == {2: 0, 4: 0, 8: 8}
    assert sorted(perm.tolist()) == list(range(8))


# ---------------------------------------------------------------------------
# Tile-aligned deploy (the fused single-launch layout)
# ---------------------------------------------------------------------------

def test_tile_aligned_deploy_memory_bits_accounting():
    """memory_bits under tile padding: the fused buffer holds, per tile,
    tile_n rows of ceil4(c_in)*bits/8 bytes — zero-row padding and the
    K byte-alignment included, matching the schedule exactly."""
    from repro.kernels import quant_matmul as qmk
    w, gamma, alpha_w = _searched_linear(jax.random.PRNGKey(6), 22, 33)
    qt = dpl.deploy_linear(w, gamma, alpha_w, None, 6.0, CFG, align=1,
                           tile_n=8)
    assert qt.fused_packed is not None
    Kp = -(-qt.c_in // qmk.FUSED_K_ALIGN) * qmk.FUSED_K_ALIGN
    expected = sum(qmk.fused_tile_bytes(b, Kp, qt.tile_n) * 8
                   for b in qt.tile_bits)
    assert dpl.memory_bits(qt) == expected == int(qt.fused_packed.size) * 8
    # tile padding only ever adds bytes over the per-group packing...
    pergroup_bits = sum(int(p.size) * 8 for p in qt.packed)
    assert dpl.memory_bits(qt) >= sum(
        b * n for b, n in zip(qt.bits, (p.shape[0] for p in qt.packed)))
    assert dpl.memory_bits(qt) >= pergroup_bits - 8 * Kp  # same order
    # ...and the group geometry (real rows) is unchanged by the layout
    assert sum(qt.group_sizes.values()) == 22


def test_tile_aligned_deploy_perm_roundtrip_through_fused_output():
    """Perm round-trip through the fused output path: the single-launch
    result (walk order + fused_perm/identity index map) must equal the
    canonical-order dequantized reference for a genuinely mixed perm."""
    rng = np.random.default_rng(9)
    c_out, c_in = 37, 21
    w = rng.standard_normal((c_out, c_in)).astype(np.float32)
    gamma = np.asarray(rng.standard_normal((c_out, 3)) * 3, np.float32)
    qt = dpl.deploy_linear(w, gamma, np.abs(w).max(-1), None, 6.0, CFG,
                           align=1, tile_n=8)
    assert len(qt.bits) > 1 and qt.fused_perm is not None
    x = jnp.asarray(rng.standard_normal((5, c_in)), jnp.float32)
    y = qt.matmul(x, jnp.float32, backend="pallas")
    y_ref = x @ qt.dequantize_canonical(jnp.float32).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # round-trip: gathering the walk-order kernel output by fused_perm is a
    # permutation of the real columns — applying it twice recovers them
    fp = np.asarray(qt.fused_perm)
    assert sorted(fp.tolist()) == sorted(set(fp.tolist()))  # injective


def test_align_128_with_tile_128_pads_only_top_group():
    """align=128 + tile_n=128 interaction: promotion already rounds every
    non-top group to 128, so tile padding touches only the top group's
    tail and each tile carries exactly one bit-width."""
    rng = np.random.default_rng(12)
    c_out = 300
    w = rng.standard_normal((c_out, 16)).astype(np.float32)
    gamma = np.asarray(
        np.eye(3)[rng.choice(3, size=c_out, p=[0.4, 0.4, 0.2])] * 9,
        np.float32)
    qt = dpl.deploy_linear(w, gamma, np.abs(w).max(-1), None, 6.0, CFG,
                           align=128, tile_n=128)
    sizes = qt.group_sizes
    for b, n in list(sorted(sizes.items()))[:-1]:
        assert n % 128 == 0
    # tiles: one bit-width each, non-top groups contribute exactly n/128
    # tiles with NO padding rows; only the top group's tail tile pads
    from collections import Counter
    tile_counts = Counter(qt.tile_bits)
    for b, n in sizes.items():
        if n:
            assert tile_counts[b] == -(-n // 128)
    padded_rows = len(qt.tile_bits) * 128 - c_out
    top = max(b for b, n in sizes.items() if n)
    assert padded_rows == (-sizes[top]) % 128
    # function preserved through the fused path
    x = jnp.asarray(rng.standard_normal((3, 16)), jnp.float32)
    y = qt.matmul(x, jnp.float32, backend="pallas")
    y_ref = x @ qt.dequantize_canonical(jnp.float32).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_tile_aligned_deploy_restore_order_false_deployed_order():
    """restore_order=False fused serving returns deployed (group-contiguous)
    channel order, matching the per-group path + propagate_perm contract."""
    rng = np.random.default_rng(15)
    c_out, c_in = 26, 12
    w = rng.standard_normal((c_out, c_in)).astype(np.float32)
    gamma = np.asarray(rng.standard_normal((c_out, 3)) * 3, np.float32)
    qt = dpl.deploy_linear(w, gamma, np.abs(w).max(-1), None, 6.0, CFG,
                           align=1, restore_order=False, tile_n=8)
    x = jnp.asarray(rng.standard_normal((4, c_in)), jnp.float32)
    y_fused = qt.matmul(x, jnp.float32, backend="pallas")
    y_pg = qt.matmul(x, jnp.float32, backend="pallas-pergroup")
    np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_pg))
    # deployed order: inv_perm gather restores canonical
    y_canon = jnp.take(y_fused, jnp.asarray(qt.inv_perm), axis=-1)
    y_ref = x @ qt.dequantize_canonical(jnp.float32).T
    np.testing.assert_allclose(np.asarray(y_canon), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_align_128_perm_propagates_to_next_layer_c_in():
    """Full two-layer check at align=128: layer-1 deployed WITHOUT runtime
    order restore + layer-2's c_in permuted via propagate_perm == canonical
    composition (the paper's Fig. 2 pipeline on MXU-aligned groups)."""
    rng = np.random.default_rng(3)
    c1, c2 = 256, 64
    w1 = rng.standard_normal((c1, 48)).astype(np.float32)
    w2 = rng.standard_normal((c2, c1)).astype(np.float32)
    gamma = rng.standard_normal((c1, 3)).astype(np.float32) * 3
    alpha1 = np.abs(w1).max(-1)
    qt1 = dpl.deploy_linear(w1, gamma, alpha1, None, 6.0, CFG, align=128,
                            restore_order=False)
    sizes = qt1.group_sizes
    for b, n in list(sorted(sizes.items()))[:-1]:
        assert n % 128 == 0                # aligned non-top groups
    x = jnp.asarray(rng.standard_normal((4, 48)), jnp.float32)
    # deployed-order layer 1 output + perm-propagated layer 2
    h_deployed = qt1.matmul(x, jnp.float32)          # deployed channel order
    w2p = dpl.propagate_perm(w2, qt1.perm)
    y = h_deployed @ jnp.asarray(w2p).T
    # canonical reference: align-promotion changes (raises) some channels'
    # precision vs the raw argmax, so the reference is the QTensor's own
    # canonical-order dequantized weight, not the align=1 frozen weight
    w1_canon = qt1.dequantize_canonical(jnp.float32)
    h_canon = x @ w1_canon.T
    y_ref = h_canon @ jnp.asarray(w2).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    # and inv_perm undoes the deployed order exactly
    h_restored = jnp.take(h_deployed, jnp.asarray(qt1.inv_perm), axis=-1)
    np.testing.assert_allclose(np.asarray(h_restored), np.asarray(h_canon),
                               rtol=1e-4, atol=1e-4)
    # promotion is upward-only: every channel's deployed bits >= argmax bits
    argmax_bits = np.asarray(mp.argmax_weight_bits(jnp.asarray(gamma), CFG))
    offset = 0
    for b in sorted(qt1.bits):
        rows = qt1.perm[offset:offset + qt1.group_sizes[b]]
        assert (argmax_bits[rows] <= b).all()
        offset += qt1.group_sizes[b]
