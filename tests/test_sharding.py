"""Sharding-rules engine: path->spec mapping, divisibility fallback, FSDP
gating, batch specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh(1, 1)


def test_column_parallel_rule(mesh):
    r = shd.ShardingRules(mesh)
    spec = r.spec_for("blocks/attn/wq/w", (512, 256))
    assert spec == P("model", None)  # < 1M elements -> FSDP size-gated off
    # big enough for FSDP (>1M elements):
    spec = r.spec_for("blocks/attn/wq/w", (4096, 4096))
    assert spec == P("model", "data")


def test_row_parallel_rule(mesh):
    r = shd.ShardingRules(mesh)
    spec = r.spec_for("blocks/ffn/w_down/w", (4096, 16384))
    assert spec == P("data", "model")


def test_moe_expert_rule(mesh):
    r = shd.ShardingRules(mesh)
    spec = r.spec_for("blocks/ffn/we_gate/w", (61, 256, 2048, 7168))
    # leading scan axis replicated, experts on model, c_in FSDP
    assert spec == P(None, "model", None, "data")


def test_divisibility_fallback(mesh):
    """c_out not divisible by the model axis -> that axis replicates."""
    big = make_test_mesh(1, 1)
    r = shd.ShardingRules(big)
    spec = r.spec_for("lm_head/w", (51865, 4096))   # odd vocab
    # model axis size 1 divides everything; simulate via axis-size check
    # using the production mesh shape instead:
    assert r.spec_for("lm_head/w", (51865, 4096)) is not None


def test_divisibility_fallback_production():
    """On a 16-way model axis an odd vocab must fall back to replicate."""
    import numpy as np
    from jax.sharding import Mesh
    # fake a 16x16 mesh object's shape without devices: use ShardingRules'
    # axis-size logic through a 1x1 mesh but patched sizes
    mesh = make_test_mesh(1, 1)
    r = shd.ShardingRules(mesh)
    r._axis_size = lambda tok: {"M": 16, "D": 16}.get(tok, 1)
    spec = r.spec_for("lm_head/w", (51865, 4096))
    assert spec == P(None, "data")  # vocab replicated, c_in still sharded
    note = r.decisions[-1].note
    assert "replicate" in note


def test_nas_gamma_follows_channels(mesh):
    r = shd.ShardingRules(mesh)
    # gammas are small -> no rule match is fine (replicated)
    spec = r.spec_for("blocks/attn/wq/gamma", (4096, 3))
    assert spec == P(None, None)


def test_kv_cache_rule(mesh):
    r = shd.ShardingRules(mesh)
    spec = r.spec_for("caches/0/k", (61, 128, 8, 32768, 160))
    # right-aligned 4D rule with leading stack axis
    assert spec[-4:] == ("data", "model", None, None) or spec is not None


def test_batch_specs_divisible(mesh):
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    sh = shd.batch_specs(mesh, batch)
    assert sh["tokens"].spec == P("data", None)


def test_batch_specs_indivisible_falls_back():
    mesh = make_test_mesh(1, 1)
    r = shd.batch_specs(mesh, {"t": jax.ShapeDtypeStruct((1, 4), jnp.int32)})
    # B=1 divides 1 -> sharded; simulate extent>1 via a fake leaf dim
    import repro.dist.sharding as S
    # direct function check of the fallback branch:
    from jax.sharding import NamedSharding
    out = shd.batch_specs(mesh, {"t": jax.ShapeDtypeStruct((3, 4),
                                                           jnp.int32)})
    assert out["t"].spec is not None  # extent=1 always divides


def test_tree_shardings_end_to_end(mesh):
    """Whole-state sharding + device_put round-trip on the test mesh."""
    from repro.config import get_config
    from repro.train import steps as steps_mod
    cfg = get_config("qwen1.5-4b").reduced()
    hp = steps_mod.TrainHParams.for_arch(cfg, total_steps=2)
    state = steps_mod.init_train_state(cfg, hp, jax.random.PRNGKey(0))
    r = shd.ShardingRules(mesh)
    sh = r.tree_shardings(state)
    placed = jax.device_put(state, sh)
    assert float(placed["tau"]) == cfg.quant.tau0


def test_explain_reports_decisions(mesh):
    r = shd.ShardingRules(mesh)
    r.spec_for("blocks/attn/wq/w", (64, 64))
    out = r.explain()
    assert "wq/w" in out
