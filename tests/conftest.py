import os

# Smoke tests must see the single real CPU device — the 512-device flag is
# set ONLY by launch/dryrun.py (and benchmarks/roofline.py).  Guard against
# accidental inheritance from a dry-run shell.
os.environ.pop("XLA_FLAGS", None)

import jax

jax.config.update("jax_enable_x64", False)
