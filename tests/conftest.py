import os

# Smoke tests must see the single real CPU device — the 512-device flag is
# set ONLY by launch/dryrun.py (and benchmarks/roofline.py).  Guard against
# accidental inheritance from a dry-run shell.  Exception: the mesh-serving
# suite (test_mesh_serving.py) NEEDS a multi-device CPU, so its CI step
# opts in with REPRO_KEEP_XLA_FLAGS=1 and its own
# --xla_force_host_platform_device_count setting.
if not os.environ.get("REPRO_KEEP_XLA_FLAGS"):
    os.environ.pop("XLA_FLAGS", None)

import jax

jax.config.update("jax_enable_x64", False)


# Shard count for the slow per-arch smoke suite (test_models_smoke.py):
# CI runs `pytest tests/test_models_smoke.py -m smokeN` as a matrix
# dimension (one job per shard — keep .github/workflows/ci.yml's matrix
# list in sync with this).  test_models_smoke.py imports this constant.
N_SMOKE_SHARDS = 4


def pytest_configure(config):
    for i in range(N_SMOKE_SHARDS):
        config.addinivalue_line(
            "markers", f"smoke{i}: test_models_smoke CI matrix shard {i}")


def pytest_collection_modifyitems(config, items):
    # Safety net: tier-1 CI ignores test_models_smoke.py and each matrix
    # job selects one smokeN mark, so a test added there WITHOUT a shard
    # mark would never run in CI.  Assign unmarked ones deterministically.
    import zlib

    import pytest

    for item in items:
        if os.path.basename(str(item.fspath)) != "test_models_smoke.py":
            continue
        if any(m.name.startswith("smoke") for m in item.iter_markers()):
            continue
        shard = zlib.crc32(item.nodeid.encode()) % N_SMOKE_SHARDS
        item.add_marker(getattr(pytest.mark, f"smoke{shard}"))
