"""Cross-phase parity harness over the paper's four MLPerf Tiny configs.

For every config (resnet8_cifar10, dscnn_kws, mobilenetv1_vww, dae_ad) the
same network is evaluated in three ways on one batch:

  frozen            — fake-quant reference (argmax assignment, float compute)
  deployed-jnp      — packed QTensor leaves, jnp per-group sub-GEMM backend
  deployed-pallas   — packed QTensor leaves, Pallas quant_matmul kernels in
                      interpret mode, under ``jax.jit`` (the acceptance path)

and all three must agree within 1e-4 (f32 compute end-to-end: the deploy
transform is exact w.r.t. the frozen fake-quant — same integer grid, same
step — so only accumulation order differs).  Convs run as im2col
patch-GEMMs over packed groups, depthwise convs through the grouped
per-channel path; no call site re-materializes a dense kernel.

The NAS logits are randomized (no search — that is covered by
tests/test_api.py) so every model deploys with genuinely mixed per-channel
precision groups, exercising the group concat + canonical-order restore.

Also includes direct QTensor.conv2d vs dense-lax-conv unit checks (incl.
depthwise and stride/padding variants) — the backend-drift guards.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.api import Engine, PrecisionPolicy, QTensor
from repro.data import pipeline as pipe
from repro.models import tinyml

TINY = ("resnet8-cifar10", "dscnn-kws", "mobilenetv1-vww", "dae-ad")

TOL = 1e-4


def _deployed_engine(name, seed=0, batch_size=2):
    """Engine with randomized NAS logits, deployed; plus one eval batch."""
    cfg = tinyml.TINY_CONFIGS[name]
    eng = Engine.for_tinyml(cfg, key=jax.random.PRNGKey(seed))
    eng.randomize_nas(seed)
    eng.deploy(align=1)
    batch = next(iter(pipe.SyntheticTiny(cfg, n=2 * batch_size,
                                         seed=seed).batches(batch_size)))
    return cfg, eng, batch


def _per_layer_memory_bits(deployed_params):
    return {name: p["w"].memory_bits
            for name, p in deployed_params.items()
            if isinstance(p, dict) and isinstance(p.get("w"), QTensor)}


@pytest.mark.parametrize("name", TINY)
def test_frozen_vs_deployed_backends_parity(name):
    cfg, eng, batch = _deployed_engine(name)
    frozen = np.asarray(
        eng.apply_fn(eng.params, eng.nas, PrecisionPolicy.FROZEN, batch),
        np.float32)
    scale = max(1.0, np.abs(frozen).max())

    mem_before = _per_layer_memory_bits(eng.deployed_params)
    assert mem_before, name  # every model has at least one QTensor site

    served_jnp = np.asarray(eng.serve(batch, backend="jnp"), np.float32)
    served_pl = np.asarray(eng.serve(batch, backend="pallas"), np.float32)

    # frozen fake-quant ≈ deployed-jnp ≈ deployed-pallas(interpret), 1e-4
    np.testing.assert_allclose(served_jnp, frozen, atol=TOL * scale,
                               rtol=TOL, err_msg=f"{name}: jnp vs frozen")
    np.testing.assert_allclose(served_pl, frozen, atol=TOL * scale,
                               rtol=TOL, err_msg=f"{name}: pallas vs frozen")
    np.testing.assert_allclose(served_pl, served_jnp, atol=TOL * scale,
                               rtol=TOL, err_msg=f"{name}: pallas vs jnp")

    # serving through either backend must not touch the packed leaves:
    # per-layer memory_bits is a property of the deploy transform only
    assert _per_layer_memory_bits(eng.deployed_params) == mem_before, name


@pytest.mark.parametrize("name", TINY)
def test_deployed_memory_smaller_than_fp32(name):
    _, eng, _ = _deployed_engine(name)
    fp32_bits = 32 * sum(s.c_out * s.weights_per_channel
                         for s in eng.specs.values())
    assert 0 < eng.memory_bits() < fp32_bits


@pytest.mark.parametrize("name", TINY)
def test_no_dense_weight_in_deployed_conv(name, monkeypatch):
    """No DEPLOYED call site materializes a dense kernel: serving any of
    the four configs (regular, depthwise and 1x1 convs, FCs) must never
    call QTensor.dense / dequantize*."""
    def _boom(self, *a, **k):
        raise AssertionError("deployed path materialized a dense weight")
    monkeypatch.setattr(QTensor, "dense", _boom)
    monkeypatch.setattr(QTensor, "dequantize", _boom)
    monkeypatch.setattr(QTensor, "dequantize_canonical", _boom)
    monkeypatch.setattr(QTensor, "_dequantize_groups", _boom)
    _, eng, batch = _deployed_engine(name)
    out = eng.serve(batch, backend="pallas")
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# QTensor.conv2d unit checks against the dense lax conv oracle
# ---------------------------------------------------------------------------

def _conv_qtensor(key, cout, cin, kh, kw, depthwise=False):
    rng = np.random.default_rng(key)
    tail_cin = 1 if depthwise else cin
    w = rng.standard_normal((cout, tail_cin, kh, kw)).astype(np.float32)
    bits = rng.choice([2, 4, 8], size=cout)
    alpha = np.abs(w.reshape(cout, -1)).max(-1)
    return QTensor.from_assignment(w, bits, alpha)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (2, "VALID")])
def test_qtensor_conv2d_matches_dense_conv(backend, stride, padding):
    qt = _conv_qtensor(0, cout=20, cin=5, kh=3, kw=3)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 9, 7, 5)),
                    jnp.float32)
    kernel = jnp.transpose(qt.dense(), (2, 3, 1, 0))
    y_ref = lax.conv_general_dilated(
        x, kernel, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = qt.conv2d(x, stride=stride, padding=padding, backend=backend)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=TOL, rtol=TOL)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_qtensor_conv2d_rect_kernel_matches_dense(backend):
    """DS-CNN's (10, 4) stride-2 first conv shape."""
    qt = _conv_qtensor(2, cout=16, cin=1, kh=10, kw=4)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((2, 49, 10, 1)),
                    jnp.float32)
    kernel = jnp.transpose(qt.dense(), (2, 3, 1, 0))
    y_ref = lax.conv_general_dilated(
        x, kernel, (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = qt.conv2d(x, stride=2, padding="SAME", backend=backend)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=TOL, rtol=TOL)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_qtensor_depthwise_conv2d_matches_dense(backend):
    """Mixed-precision depthwise: the channel perm must gather the *input*
    channels into deployed order before the per-group tap contraction."""
    c = 12
    qt = _conv_qtensor(4, cout=c, cin=c, kh=3, kw=3, depthwise=True)
    assert len(qt.bits) > 1  # genuinely exercises the perm path
    x = jnp.asarray(np.random.default_rng(5).standard_normal((2, 8, 8, c)),
                    jnp.float32)
    kernel = jnp.transpose(qt.dense(), (2, 3, 1, 0))
    y_ref = lax.conv_general_dilated(
        x, kernel, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=c)
    y = jax.jit(lambda q, x: q.conv2d(x, groups=c, backend=backend))(qt, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=TOL, rtol=TOL)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_qtensor_matmul_rejects_mismatched_width(backend):
    """Both backends must reject a mis-sized contraction dim identically —
    the Pallas kernel would otherwise zero-pad and silently compute."""
    rng = np.random.default_rng(8)
    w = rng.standard_normal((8, 16)).astype(np.float32)
    qt = QTensor.from_assignment(w, np.full(8, 4), np.abs(w).max(-1))
    with pytest.raises(ValueError, match="contraction"):
        qt.matmul(jnp.zeros((2, 12)), backend=backend)


def test_qtensor_conv2d_rejects_linear_and_odd_groups():
    rng = np.random.default_rng(6)
    w = rng.standard_normal((8, 16)).astype(np.float32)
    lin = QTensor.from_assignment(w, np.full(8, 4), np.abs(w).max(-1))
    with pytest.raises(TypeError):
        lin.conv2d(jnp.zeros((1, 4, 4, 16)))
    qt = _conv_qtensor(7, cout=8, cin=4, kh=3, kw=3)
    with pytest.raises(NotImplementedError):
        qt.conv2d(jnp.zeros((1, 4, 4, 4)), groups=2)
