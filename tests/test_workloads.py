"""Dry-run machinery validated on the 1-device test mesh with REDUCED
configs: lower+compile every workload kind without the 512-device flag."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import get_config
from repro.launch import workloads as wk
from repro.launch.mesh import make_test_mesh
from repro.launch.shapes import SHAPES, ShapeSpec, cells


def _tiny_spec(kind):
    return ShapeSpec(f"tiny_{kind}", kind, 16, 4)


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "deepseek-v3-671b",
                                  "mamba2-780m", "zamba2-1.2b",
                                  "whisper-small", "phi-3-vision-4.2b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_lower_compile_reduced(arch, kind):
    cfg = get_config(arch).reduced()
    spec = _tiny_spec(kind)
    if kind == "train":
        wl = wk.make_train_workload(cfg, spec)
    elif kind == "prefill":
        wl = wk.make_prefill_workload(cfg, spec)
    else:
        wl = wk.make_decode_workload(cfg, spec)
    mesh = make_test_mesh()
    lowered = wk.lower(wl, mesh)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_cells_enumeration_is_40():
    cs = cells()
    assert len(cs) == 40
    runnable = [c for c in cs if c.runnable]
    skipped = [c for c in cs if not c.runnable]
    # long_500k runs only for the two sub-quadratic archs
    assert len([c for c in runnable if c.shape == "long_500k"]) == 2
    assert len(skipped) == 8
    for c in skipped:
        assert c.shape == "long_500k" and c.skip_reason


def test_batch_struct_shapes():
    cfg = get_config("whisper-small")
    spec = SHAPES["train_4k"]
    b = wk.batch_struct(cfg, spec)
    assert b["tokens"].shape == (256, 4096)
    assert b["frames"].shape == (256, 1500, 768)
    cfg = get_config("phi-3-vision-4.2b")
    b = wk.batch_struct(cfg, spec)
    assert b["prefix_embeds"].shape == (256, 576, 3072)


def test_decode_workload_donates_caches():
    cfg = get_config("qwen1.5-4b").reduced()
    wl = wk.make_decode_workload(cfg, _tiny_spec("decode"))
    assert wl.donate == (2,)
    assert wl.args[1].shape == (4, 1)     # one new token


def test_tokens_per_step_accounting():
    cfg = get_config("qwen1.5-4b").reduced()
    wl = wk.make_train_workload(cfg, ShapeSpec("s", "train", 128, 8))
    assert wl.tokens_per_step == 1024
