"""Serving-path consistency: prefill-then-decode must agree with running
prefill one token longer (the KV-cache correctness property), per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import serving

FAMS = ["qwen1.5-4b", "deepseek-v3-671b", "mamba2-780m", "zamba2-1.2b",
        "whisper-small"]


def _inputs(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        b["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_last_logits_match_longer_prefill(arch):
    """prefill(S) and prefill(S+1) agree at overlapping position: the full
    forward is causally consistent (pre-req for decode parity)."""
    cfg = get_config(arch).reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    b_long = _inputs(cfg, B, S + 1)
    b_short = {k: (v[:, :S] if k == "tokens" else v)
               for k, v in b_long.items()}
    lg_s, _ = serving.prefill(dp, cfg, b_short)
    # prefill returns last-token logits; recompute long prefill truncated
    b_trunc = dict(b_long)
    b_trunc["tokens"] = b_long["tokens"].at[:, S:].set(0)[:, :S]
    lg_s2, _ = serving.prefill(dp, cfg, b_trunc)
    np.testing.assert_allclose(np.asarray(lg_s, np.float32),
                               np.asarray(lg_s2, np.float32), atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-780m"])
def test_decode_steps_are_deterministic(arch):
    cfg = get_config(arch).reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(2))
    caches = serving.init_caches(cfg, 2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    lg1, c1 = serving.decode_step(dp, cfg, tok, caches, jnp.asarray(4))
    caches2 = serving.init_caches(cfg, 2, 16)
    lg2, c2 = serving.decode_step(dp, cfg, tok, caches2, jnp.asarray(4))
    np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))


@pytest.mark.parametrize("arch", ["qwen1.5-4b"])
def test_decode_depends_on_cache_content(arch):
    """Writing different history into the cache changes the next logits —
    the cache is actually read (guards against stale-cache bugs)."""
    cfg = get_config(arch).reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(3))
    B = 1
    c0 = serving.init_caches(cfg, B, 16)
    tok = jnp.ones((B, 1), jnp.int32)
    # two different first tokens populate different caches at pos 0
    _, ca = serving.decode_step(dp, cfg, jnp.full((B, 1), 2, jnp.int32),
                                c0, jnp.asarray(0))
    c0b = serving.init_caches(cfg, B, 16)
    _, cb = serving.decode_step(dp, cfg, jnp.full((B, 1), 9, jnp.int32),
                                c0b, jnp.asarray(0))
    la, _ = serving.decode_step(dp, cfg, tok, ca, jnp.asarray(1))
    lb, _ = serving.decode_step(dp, cfg, tok, cb, jnp.asarray(1))
    assert not np.allclose(np.asarray(la), np.asarray(lb))


def test_moe_serving_routes_tokens():
    """MoE deployed path: different tokens activate different experts and
    produce different outputs (router actually consulted)."""
    cfg = get_config("deepseek-v3-671b").reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(4))
    b1 = _inputs(cfg, 2, 8, seed=1)
    b2 = _inputs(cfg, 2, 8, seed=2)
    l1, _ = serving.prefill(dp, cfg, b1)
    l2, _ = serving.prefill(dp, cfg, b2)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


GUARD_ARCHS = [
    "qwen1.5-4b",          # dense
    "deepseek-v3-671b",    # moe + mla
    "mamba2-780m",         # ssm
    "zamba2-1.2b",         # hybrid
    "whisper-small",       # audio (enc-dec + cross-attention)
    "phi-3-vision-4.2b",   # vlm (prefix embeds)
]


def _forbid_dense(monkeypatch):
    from repro.api import QTensor

    def _boom(self, *a, **k):
        raise AssertionError(
            "deployed serving path materialized a dense weight")
    for name in ("dense", "dequantize", "dequantize_canonical",
                 "_dequantize_groups"):
        monkeypatch.setattr(QTensor, name, _boom)


@pytest.mark.parametrize("arch", GUARD_ARCHS)
def test_no_dense_weight_any_serving_family(arch, monkeypatch):
    """PR 2's conv guard extended to every LM serving family: with
    ``QTensor.dequantize`` (and friends) forbidden, prefill AND decode must
    still run — no deployed serving path materializes a full dense weight.
    MoE experts (expert-batched packed GEMMs) and MLA decode (packed latent
    expansion, no wkv_b absorption view) are the PR 4 closures."""
    _forbid_dense(monkeypatch)
    cfg = get_config(arch).reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    b = _inputs(cfg, 2, 8)
    lg, _ = serving.prefill(dp, cfg, b)
    assert bool(jnp.all(jnp.isfinite(lg[..., :cfg.vocab_size])))
    caches = serving.init_caches(cfg, 2, 16)
    lg2, _ = serving.decode_step(dp, cfg, jnp.zeros((2, 1), jnp.int32),
                                 caches, jnp.asarray(4, jnp.int32))
    assert bool(jnp.all(jnp.isfinite(lg2[..., :cfg.vocab_size])))


def test_no_dense_weight_moe_mla_decode_pallas(monkeypatch):
    """Same guard through the fused Pallas backend on the MoE + MLA family:
    decode runs entirely on packed kernels (expert-batched fused launches
    for the routed experts) with dequantization forbidden."""
    _forbid_dense(monkeypatch)
    cfg = get_config("deepseek-v3-671b").reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    caches = serving.init_caches(cfg, 1, 8)
    lg, _ = serving.decode_step(dp, cfg, jnp.zeros((1, 1), jnp.int32),
                                caches, jnp.asarray(2, jnp.int32),
                                backend="pallas")
    assert bool(jnp.all(jnp.isfinite(lg[..., :cfg.vocab_size])))


def test_int8_kv_cache_quantization_bounded_error():
    from repro.models import layers as L
    kv = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8, 16))
    q, scale = L.quantize_kv(kv)
    back = L.dequantize_kv(q, scale, jnp.float32)
    rel = np.abs(np.asarray(back - kv)) / (np.abs(np.asarray(kv)).max())
    assert rel.max() < 1 / 100  # 127-level quantization
