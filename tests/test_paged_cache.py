"""Paged KV cache + radix prefix sharing (repro.cache, PR 6).

Three layers of guards:

* **host bookkeeping** — allocator refcount/free-list invariants and the
  radix longest-prefix contract, driven with randomized interleavings
  against shadow models;
* **device helpers** — gather/scatter page arithmetic reconstructs the
  dense per-slot ring bit-exactly (the NULL page reads as zeros, drop
  sentinels never write);
* **serving level** — the paged ``ServingEngine`` is token-for-token
  bit-identical to the dense engine on the staggered traces of
  tests/test_continuous_batching.py (jnp AND pallas), never recompiles
  after warmup, admits fully-cached prompts with zero prefill launches,
  evicts under pool pressure, and keeps strictly fewer KV bytes resident
  than the dense rings.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api.scheduler import Request, ServingEngine, auto_page_size
from repro.cache import (DoubleFree, NULL_PAGE, PageAllocator, PageError,
                         PagesExhausted, PagePool, RadixIndex, gather_pages,
                         scatter_prefill, write_coords)
from test_continuous_batching import STAGGER, _setup, _stagger_trace


# ---------------------------------------------------------------------------
# PageAllocator: refcount + free-list invariants
# ---------------------------------------------------------------------------

def test_allocator_lifecycle():
    al = PageAllocator(5)                       # page 0 reserved (NULL)
    assert al.num_allocatable == 4 and al.free_count == 4 and al.in_use == 0
    pages = [al.alloc() for _ in range(4)]
    assert sorted(pages) == [1, 2, 3, 4]        # NULL is never handed out
    assert al.free_count == 0 and al.in_use == 4
    al.retain(pages[0])                         # a sharer maps it too
    assert al.release(pages[0]) == 1            # still referenced
    assert al.release(pages[0]) == 0            # last reference gone
    al.free(pages[0])
    assert al.free_count == 1 and al.is_free(pages[0])
    assert al.alloc() == pages[0]               # recycled


def test_allocator_guards():
    al = PageAllocator(3)
    with pytest.raises(PagesExhausted):
        for _ in range(3):
            al.alloc()
    p = 1
    assert al.refcount[p] == 1
    with pytest.raises(PageError):
        al.free(p)                              # still referenced
    al.release(p)
    with pytest.raises(DoubleFree):
        al.release(p)                           # below zero
    with pytest.raises(PageError):
        al.retain(p)                            # unreferenced
    al.free(p)
    with pytest.raises(DoubleFree):
        al.free(p)                              # already free
    with pytest.raises(PageError):
        al.revive(p)                            # free, not resident
    with pytest.raises(PageError):
        al.retain(NULL_PAGE)                    # reserved id
    with pytest.raises(ValueError):
        PageAllocator(1)                        # nothing allocatable


def test_allocator_randomized_shadow_model():
    """Random alloc/retain/release/free/revive interleavings against a
    plain dict shadow; the guarded transitions must agree with the shadow
    at every step and the count invariant must hold throughout."""
    rng = np.random.default_rng(0)
    al = PageAllocator(9)
    ref = {}                                    # page -> refcount (held pages)
    parked = set()                              # refcount-0, not freed
    for _ in range(2000):
        op = rng.integers(0, 5)
        if op == 0:                             # alloc
            if al.free_count:
                p = al.alloc()
                assert p not in ref and p not in parked
                ref[p] = 1
            else:
                with pytest.raises(PagesExhausted):
                    al.alloc()
        elif op == 1 and ref:                   # retain
            p = int(rng.choice(list(ref)))
            al.retain(p)
            ref[p] += 1
        elif op == 2 and ref:                   # release
            p = int(rng.choice(list(ref)))
            assert al.release(p) == ref[p] - 1
            ref[p] -= 1
            if ref[p] == 0:
                del ref[p]
                parked.add(p)
        elif op == 3 and parked:                # free a parked page
            p = int(rng.choice(list(parked)))
            parked.discard(p)
            al.free(p)
        elif op == 4 and parked:                # revive a parked page
            p = int(rng.choice(list(parked)))
            parked.discard(p)
            al.revive(p)
            ref[p] = 1
        assert al.in_use == len(ref) + len(parked)
        assert al.free_count + al.in_use == al.num_allocatable
        for p, c in ref.items():
            assert al.refcount[p] == c and not al.is_free(p)


# ---------------------------------------------------------------------------
# RadixIndex: longest full-page prefix
# ---------------------------------------------------------------------------

def test_radix_longest_prefix_full_pages_only():
    ix = RadixIndex(4)
    toks = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]      # 2 full pages + tail of 2
    assert ix.insert(toks, [7, 8]) == {7, 8}
    assert ix.match(toks) == [7, 8]             # tail never matches
    assert ix.match(toks[:8]) == [7, 8]
    assert ix.match(toks[:7]) == [7]            # second page incomplete
    assert ix.match([1, 2, 3, 4, 0, 0, 0, 0]) == [7]
    assert ix.match([9, 9, 9, 9]) == []
    assert len(ix) == 2 and 7 in ix and 8 not in RadixIndex(4)


def test_radix_first_writer_wins():
    ix = RadixIndex(2)
    assert ix.insert([1, 2, 3, 4], [5, 6]) == {5, 6}
    # duplicate path: existing pages kept, nothing newly indexed
    assert ix.insert([1, 2, 3, 4], [9, 9]) == set()
    assert ix.match([1, 2, 3, 4]) == [5, 6]
    # diverging second page chains a sibling under the shared first node
    assert ix.insert([1, 2, 7, 7], [9, 10]) == {10}
    assert ix.match([1, 2, 7, 7]) == [5, 10]
    with pytest.raises(ValueError):
        ix.insert([8, 8], [10])                 # page already indexed
    with pytest.raises(ValueError):
        ix.insert([8, 8], [11, 12])             # more pages than full keys


def test_radix_evict_lru_leaf_first():
    ix = RadixIndex(1)
    ix.insert([1, 2, 3], [4, 5, 6])             # chain 4 -> 5 -> 6
    with pytest.raises(ValueError):
        ix.remove(4)                            # interior node
    assert ix.evict_lru(lambda p: True) == 6    # leaf first
    ix.insert([9], [7])
    ix.match([1, 2])                            # bump the 4 -> 5 branch
    assert ix.evict_lru(lambda p: True) == 7    # LRU leaf
    assert ix.evict_lru(lambda p: p != 5) is None   # nothing evictable
    assert ix.evict_lru(lambda p: True) == 5


def test_radix_randomized_interleavings_match_shadow():
    """Random inserts/matches over a tiny alphabet (so prefixes collide
    constantly) must agree with a shadow dict keyed on full-page paths."""
    rng = np.random.default_rng(1)
    T = 2
    ix = RadixIndex(T)
    shadow = {}                                 # path tuple -> page
    next_page = 1
    for _ in range(400):
        toks = rng.integers(0, 3, rng.integers(0, 9)).tolist()
        keys = [tuple(toks[i * T:(i + 1) * T]) for i in range(len(toks) // T)]
        if rng.random() < 0.5:                  # insert
            pages = list(range(next_page, next_page + len(keys)))
            got = ix.insert(toks, pages)
            want = set()
            for j, k in enumerate(keys):
                path = tuple(keys[:j + 1])
                if path not in shadow:
                    shadow[path] = pages[j]
                    want.add(pages[j])
            assert got == want
            next_page += len(keys)
        else:                                   # match == shadow walk
            want = []
            for j, k in enumerate(keys):
                page = shadow.get(tuple(keys[:j + 1]))
                if page is None:
                    break
                want.append(page)
            assert ix.match(toks) == want
    assert len(ix) == len(shadow)


# ---------------------------------------------------------------------------
# PagePool: admission / release / eviction lifecycle
# ---------------------------------------------------------------------------

def test_pool_share_release_revive_cycle():
    pool = PagePool(6, page_size=2)             # 5 allocatable
    toks = [1, 2, 3, 4]
    pages = pool.alloc(2)
    pool.index_prompt(toks, pages)
    assert pool.match_prefix(toks + [9]) == pages
    pool.acquire(pages)                         # a second slot shares them
    pool.release(pages)                         # first slot finishes
    assert pool.in_use == 2 and not pool.is_resident(pages[0])
    pool.release(pages)                         # last reference: parked
    assert all(pool.is_resident(p) for p in pages)
    assert pool.available == 5                  # free + resident is exact
    pool.acquire(pool.match_prefix(toks))       # revived copy-free
    assert not pool.is_resident(pages[0])
    pool.release(pages)


def test_pool_unindexed_pages_free_on_release():
    pool = PagePool(4, page_size=2)
    pages = pool.alloc(3)
    pool.release(pages)
    assert pool.allocator.free_count == 3 and pool.in_use == 0


def test_pool_alloc_evicts_cold_resident_pages():
    pool = PagePool(4, page_size=1)             # 3 allocatable
    for toks in ([1], [2], [3]):
        pg = pool.alloc(1)
        pool.index_prompt(toks, pg)
        pool.release(pg)
        pool.match_prefix([1])                  # keep [1] hottest
    assert pool.available == 3 and pool.allocator.free_count == 0
    pool.alloc(2)                               # must evict two cold pages
    assert pool.evictions == 2
    assert pool.match_prefix([1]) != []         # the hot page survived
    with pytest.raises(PagesExhausted):
        pool.alloc(2)                           # 1 resident left, need 2


# ---------------------------------------------------------------------------
# Device helpers: paged gather/scatter == the dense ring
# ---------------------------------------------------------------------------

def test_gather_pages_reconstructs_dense_ring():
    rng = np.random.default_rng(2)
    NP, KV, T, F = 6, 2, 4, 3
    pool = jnp.asarray(rng.standard_normal((NP, KV, T, F)), jnp.float32)
    pool = pool.at[NULL_PAGE].set(0.0)          # the NULL-page convention
    pages = jnp.asarray([[3, 1, NULL_PAGE], [2, 5, 4]], jnp.int32)
    got = np.asarray(gather_pages(pool, pages))
    assert got.shape == (2, KV, 3 * T, F)
    for b in range(2):
        want = np.concatenate([np.asarray(pool[int(p)])
                               for p in pages[b]], axis=1)
        np.testing.assert_array_equal(got[b], want)
    # unmapped tail reads exact zeros — the dense empty-slot convention
    assert not got[0, :, 2 * T:].any()


def test_write_coords_targets_and_drop_sentinels():
    pages = jnp.asarray([[2, 3], [4, NULL_PAGE], [5, 6]], jnp.int32)
    pos = jnp.asarray([5, 6, 9], jnp.int32)     # page 1 off 1 / pg 1 / OOB
    live = jnp.asarray([True, True, True])
    phys, off = write_coords(pos, live, pages, page_size=4, num_pages=7)
    # row 0 writes page 3 offset 1; row 1's page is NULL -> dropped;
    # row 2's position is past the table -> dropped
    np.testing.assert_array_equal(np.asarray(phys), [3, 7, 7])
    np.testing.assert_array_equal(np.asarray(off)[:1], [1])
    phys, _ = write_coords(pos, jnp.asarray([False, True, True]), pages,
                           page_size=4, num_pages=7)
    assert int(phys[0]) == 7                    # dead rows drop too


def test_scatter_prefill_writes_owned_pages_only():
    rng = np.random.default_rng(3)
    X, B, T, F, NP = 2, 2, 2, 3, 5
    pool = jnp.zeros((X, NP, T, F), jnp.float32)
    pf = jnp.asarray(rng.standard_normal((X, B, 2 * T, F)), jnp.float32)
    # slot 0 owns pages (1, 2); slot 1 owns page 3, second entry dropped
    wp = np.asarray([1, 2, 3, NP], np.int32)
    out = np.asarray(scatter_prefill(pool, pf, jnp.asarray(wp)))
    np.testing.assert_array_equal(out[:, 1], np.asarray(pf[:, 0, :T]))
    np.testing.assert_array_equal(out[:, 2], np.asarray(pf[:, 0, T:]))
    np.testing.assert_array_equal(out[:, 3], np.asarray(pf[:, 1, :T]))
    assert not out[:, NULL_PAGE].any() and not out[:, 4].any()


# ---------------------------------------------------------------------------
# Serving level: paged engine == dense engine, bit for bit
# ---------------------------------------------------------------------------

def _run_trace(cfg, dp, backend, page_size, seed, **kw):
    eng = ServingEngine(cfg, dp, backend=backend, max_slots=STAGGER["B"],
                        max_len=STAGGER["M"], prefill_len=STAGGER["P"],
                        page_size=page_size, **kw)
    outs = eng.run(_stagger_trace(cfg, seed), STAGGER["arrivals"])
    return eng, outs


PARITY_CASES = [
    ("qwen1.5-4b", "jnp"),
    ("deepseek-v3-671b", "jnp"),
    ("qwen1.5-4b", "pallas"),
]


@pytest.mark.parametrize("arch,backend", PARITY_CASES)
def test_paged_engine_bit_identical_to_dense(arch, backend):
    """The tentpole contract: page tables change memory layout only.  The
    gather reconstructs each slot's dense ring exactly, so every launch
    sees operand-identical attention inputs and the token streams match
    bit for bit — on the jnp fallback AND through the Pallas kernels."""
    over = ({"capacity_factor": 64.0} if arch == "deepseek-v3-671b" else {})
    cfg, dp = _setup(arch, **over)
    dense_eng, dense = _run_trace(cfg, dp, backend, None, seed=11)
    paged_eng, paged = _run_trace(cfg, dp, backend, "auto", seed=11)
    assert paged_eng.page_size is not None      # really exercised paging
    for i in sorted(dense):
        np.testing.assert_array_equal(paged[i].tokens, dense[i].tokens)
        assert paged[i].finish_reason == dense[i].finish_reason
    # identical schedule, launch for launch
    for k in ("prefill_launches", "decode_launches", "useful_tokens"):
        assert paged_eng.stats[k] == dense_eng.stats[k]


def test_paged_zero_recompiles_after_warmup():
    cfg, dp = _setup("qwen1.5-4b")
    eng, _ = _run_trace(cfg, dp, "jnp", "auto", seed=12)
    warm = eng.compile_counts()
    assert warm == {"admit": 1, "step": 1}
    eng2, _ = _run_trace(cfg, dp, "jnp", "auto", seed=13)
    assert eng2.stats["prefill_launches"] >= 2  # slots really were refilled
    assert eng2.compile_counts() == warm, \
        "paged serving recompiled after warmup"


def test_full_prefix_hit_admits_with_zero_prefill_launches():
    """A prompt whose full-page prefix is entirely cached admits copy-free:
    no prefill launch, pages mapped by refcount bump, and the generated
    stream matches the uncached run of the same request."""
    cfg, dp = _setup("qwen1.5-4b")
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=2, max_len=24,
                        prefill_len=8)          # auto page_size 8
    toks = np.random.default_rng(14).integers(
        0, cfg.vocab_size, 8).astype(np.int32)
    first = eng.run([Request(toks, max_tokens=5)])
    pre = eng.stats["prefill_launches"]
    again = eng.run([Request(toks, max_tokens=5)])
    assert eng.stats["prefill_launches"] == pre             # zero prefills
    assert eng.stats["zero_prefill_admits"] == 1
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["cached_tokens"] == 8
    np.testing.assert_array_equal(again[0].tokens, first[0].tokens)


def test_partial_prefix_hit_matches_unshared_engine():
    """Sharing only the first page of a longer prompt must not change a
    token: shared pages hold bit-identical KV to what the request's own
    prefill would have written (row-independent prefill, same weights)."""
    cfg, dp = _setup("qwen1.5-4b")
    rng = np.random.default_rng(15)
    a = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    b = np.concatenate([a[:8],
                        rng.integers(0, cfg.vocab_size, 8).astype(np.int32)])
    mk = lambda share: ServingEngine(cfg, dp, backend="jnp", max_slots=2,
                                     max_len=24, prefill_len=16,
                                     prefix_sharing=share)
    eng = mk(True)
    assert eng.page_size == 8                   # gcd(24, 16): b shares page 0
    eng.run([Request(a, max_tokens=3)])
    base_hits = eng.stats["prefix_hits"]
    shared = eng.run([Request(b, max_tokens=6)])
    assert eng.stats["prefix_hits"] == base_hits + 1
    assert eng.stats["cached_tokens"] >= eng.page_size
    ref = mk(False).run([Request(b, max_tokens=6)])
    np.testing.assert_array_equal(shared[0].tokens, ref[0].tokens)


def test_eviction_under_pool_pressure():
    """With a pool too small to keep every finished prompt resident, cold
    prefix pages are evicted LRU-first and serving still completes; the
    free+resident accounting returns to capacity when all slots drain."""
    cfg, dp = _setup("qwen1.5-4b")
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=1, max_len=24,
                        prefill_len=8, num_pages=4)         # 3 allocatable
    rng = np.random.default_rng(16)
    for _ in range(4):
        toks = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        outs = eng.run([Request(toks, max_tokens=9)])
        assert outs[0].finish_reason == "length"
    assert eng.stats["evictions"] >= 1
    assert eng.pool.available == eng.pool.capacity          # all reclaimed
    assert eng.stats["pages_peak"] <= eng.pool.capacity


def test_deferred_admission_preserves_outputs():
    """When the pool cannot reserve worst-case pages for both requests at
    once, the second is deferred (not dropped) and both token streams still
    match the roomy dense engine."""
    cfg, dp = _setup("qwen1.5-4b")
    reqs = lambda: [Request(np.full(8, 3 + i, np.int32), max_tokens=9)
                    for i in range(2)]
    dense = ServingEngine(cfg, dp, backend="jnp", max_slots=2, max_len=24,
                          prefill_len=8, page_size=None).run(reqs())
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=2, max_len=24,
                        prefill_len=8, num_pages=4)         # one at a time
    outs = eng.run(reqs())
    assert eng.stats["deferred_admissions"] >= 1
    for i in sorted(dense):
        np.testing.assert_array_equal(outs[i].tokens, dense[i].tokens)


def test_kv_bytes_resident_below_dense():
    cfg, dp = _setup("qwen1.5-4b")
    eng, _ = _run_trace(cfg, dp, "jnp", "auto", seed=17)
    assert eng.kv_bytes_resident() < eng.kv_bytes_dense()
    dense_eng, _ = _run_trace(cfg, dp, "jnp", None, seed=17)
    assert dense_eng.kv_bytes_resident() == dense_eng.kv_bytes_dense()


def test_paged_mode_validation():
    cfg, dp = _setup("qwen1.5-4b")
    scfg, sdp = _setup("mamba2-780m")
    # ssm has no ring axis: auto falls back to dense, explicit raises
    assert auto_page_size(scfg, 24, 8) is None
    eng = ServingEngine(scfg, sdp, backend="jnp", max_slots=2, max_len=24,
                        prefill_len=8)
    assert eng.pool is None
    with pytest.raises(ValueError, match="no ring axis"):
        ServingEngine(scfg, sdp, max_slots=2, max_len=24, prefill_len=8,
                      page_size=4)
    with pytest.raises(ValueError, match="paged cache"):
        ServingEngine(cfg, dp, max_slots=2, max_len=24, prefill_len=8,
                      page_size=None, prefix_sharing=True)
    with pytest.raises(ValueError, match="must divide"):
        ServingEngine(cfg, dp, max_slots=2, max_len=24, prefill_len=8,
                      page_size=5)


def test_submit_overflow_names_request_and_page_budget():
    """Satellite: the overflow error says which request and what the page
    budget actually is."""
    cfg, dp = _setup("qwen1.5-4b")
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=1, max_len=24,
                        prefill_len=8, num_pages=3)         # capacity 2
    with pytest.raises(ValueError, match=r"request 0:.*needs 3 pages of 8 "
                                         r"tokens, pages free 2/2"):
        eng.submit(Request(np.zeros(8, np.int32), max_tokens=17))
    rid = eng.submit(Request(np.zeros(8, np.int32), max_tokens=9))
    assert rid == 0                             # rejected submit burns no id
