"""Unit + property tests for core/quantizers.py (Eq. 1, PACT, packing).

Property-style sweeps use seeded numpy RNGs (deterministic, no external
dependencies); the pack/unpack round-trips are exhaustive over the value
range of every sub-byte width.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizers as qz

BITS = (2, 4, 8)


# ---------------------------------------------------------------------------
# Fake-quantization properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
def test_act_quant_levels(bits):
    """Quantized activations take at most 2^bits distinct values in [0, a]."""
    x = jnp.linspace(-1.0, 8.0, 1001)
    y = qz.quantize_act(x, jnp.asarray(6.0), bits)
    vals = np.unique(np.asarray(y))
    assert len(vals) <= (1 << bits)
    assert vals.min() >= 0.0 and vals.max() <= 6.0 + 1e-6


@pytest.mark.parametrize("bits", BITS)
def test_weight_quant_symmetric(bits):
    """Signed weight quantization: symmetric levels, zero representable."""
    w = jnp.linspace(-2.0, 2.0, 1001)
    y = qz.quantize_weight(w, jnp.asarray(1.5), bits)
    vals = np.unique(np.asarray(y))
    assert len(vals) <= (1 << bits) - 1 or bits == 8
    np.testing.assert_allclose(vals, -vals[::-1], atol=1e-6)  # symmetric
    assert 0.0 in np.round(vals, 6)


def test_8bit_quant_near_identity():
    x = jnp.linspace(0.01, 5.99, 100)
    y = qz.quantize_act(x, jnp.asarray(6.0), 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=6 / 255)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("bits", BITS)
def test_quant_error_bounded(seed, bits):
    """|fq(x) - clip(x)| <= step/2 — the core quantization invariant."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64) * 3, jnp.float32)
    alpha = 2.0
    y = qz.quantize_act(x, jnp.asarray(alpha), bits)
    clipped = np.clip(np.asarray(x), 0, alpha)
    step = alpha / ((1 << bits) - 1)
    assert np.max(np.abs(np.asarray(y) - clipped)) <= step / 2 + 1e-6


def test_ste_gradient_passthrough():
    """d/dx fq(x) == 1 inside the clip range, 0 outside."""
    g = jax.grad(lambda x: qz.quantize_act(x, jnp.asarray(6.0), 4))
    assert g(jnp.asarray(3.0)) == 1.0
    assert g(jnp.asarray(7.0)) == 0.0
    assert g(jnp.asarray(-1.0)) == 0.0


def test_pact_alpha_gradient():
    """PACT: d fq/d alpha == 1 for saturated inputs, ~0 for interior."""
    g = jax.grad(lambda a: qz.quantize_act(jnp.asarray(10.0), a, 4))
    assert abs(float(g(jnp.asarray(6.0))) - 1.0) < 1e-5
    g_in = jax.grad(lambda a: qz.quantize_act(jnp.asarray(1.5), a, 8))
    assert abs(float(g_in(jnp.asarray(6.0)))) < 0.1


# ---------------------------------------------------------------------------
# Integer quantization + sub-byte packing roundtrips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("k", [8, 16, 64, 256])
def test_pack_unpack_roundtrip(seed, bits, k):
    rng = np.random.default_rng(seed * 97 + bits)
    half = (1 << (bits - 1)) - 1
    q = jnp.asarray(rng.integers(-half, half + 1, (4, k)), jnp.int8)
    packed = qz.pack_int(q, bits)
    assert packed.shape == (4, k * bits // 8)
    assert packed.dtype == jnp.uint8
    out = qz.unpack_int(packed, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(q))


@pytest.mark.parametrize("bits", (2, 4))
def test_pack_unpack_exhaustive_value_range(bits):
    """Every representable signed value round-trips — including the most
    negative two's-complement code (-2^(bits-1)), which the symmetric
    quantizer never emits but the packing layer must still carry."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    f = qz.pack_factor(bits)
    vals = np.arange(lo, hi + 1, dtype=np.int8)
    # all values in every lane position of a byte
    tiled = np.tile(vals, f)[None, :]                  # (1, n_vals * f)
    q = jnp.asarray(tiled)
    out = qz.unpack_int(qz.pack_int(q, bits), bits)
    np.testing.assert_array_equal(np.asarray(out), tiled)


def test_pack_unpack_int8_negative_values():
    q = jnp.asarray([[-128, -1, 0, 1, 127]], jnp.int8)
    packed = qz.pack_int(q, 8)
    assert packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(qz.unpack_int(packed, 8)),
                                  np.asarray(q))


@pytest.mark.parametrize("bits", BITS)
def test_pack_unpack_non_contiguous_input(bits):
    """Transposed / sliced (non-contiguous) inputs pack identically to their
    contiguous copies."""
    rng = np.random.default_rng(3)
    half = (1 << (bits - 1)) - 1
    base = rng.integers(-half, half + 1, (32, 64)).astype(np.int8)
    view = base.T[::2]                                  # (32, 32), strided
    assert not view.flags["C_CONTIGUOUS"]
    p_view = qz.pack_int(jnp.asarray(view), bits)
    p_copy = qz.pack_int(jnp.asarray(np.ascontiguousarray(view)), bits)
    np.testing.assert_array_equal(np.asarray(p_view), np.asarray(p_copy))
    np.testing.assert_array_equal(
        np.asarray(qz.unpack_int(p_view, bits)), view)


@pytest.mark.parametrize("bits", (2, 4))
def test_pack_unpack_higher_rank(bits):
    """Leading batch/expert dims pass through packing untouched."""
    rng = np.random.default_rng(11)
    half = (1 << (bits - 1)) - 1
    q = jnp.asarray(rng.integers(-half, half + 1, (3, 5, 16)), jnp.int8)
    packed = qz.pack_int(q, bits)
    assert packed.shape == (3, 5, 16 * bits // 8)
    np.testing.assert_array_equal(np.asarray(qz.unpack_int(packed, bits)),
                                  np.asarray(q))


@pytest.mark.parametrize("bits", BITS)
def test_int_quant_dequant_error(bits):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    alpha = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    q, scale = qz.quantize_weight_int(w, alpha, bits)
    back = np.asarray(q, np.float32) * np.asarray(scale)
    step = np.asarray(alpha) / ((1 << (bits - 1)) - 1)
    assert np.max(np.abs(back - np.asarray(w)) / step) <= 0.5 + 1e-5


def test_weight_bank_shapes():
    w = jnp.ones((8, 4))
    bank = qz.weight_bank(w, jnp.ones((8, 1)))
    assert bank.shape == (3, 8, 4)
