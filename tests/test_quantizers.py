"""Unit + property tests for core/quantizers.py (Eq. 1, PACT, packing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantizers as qz

BITS = (2, 4, 8)


# ---------------------------------------------------------------------------
# Fake-quantization properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
def test_act_quant_levels(bits):
    """Quantized activations take at most 2^bits distinct values in [0, a]."""
    x = jnp.linspace(-1.0, 8.0, 1001)
    y = qz.quantize_act(x, jnp.asarray(6.0), bits)
    vals = np.unique(np.asarray(y))
    assert len(vals) <= (1 << bits)
    assert vals.min() >= 0.0 and vals.max() <= 6.0 + 1e-6


@pytest.mark.parametrize("bits", BITS)
def test_weight_quant_symmetric(bits):
    """Signed weight quantization: symmetric levels, zero representable."""
    w = jnp.linspace(-2.0, 2.0, 1001)
    y = qz.quantize_weight(w, jnp.asarray(1.5), bits)
    vals = np.unique(np.asarray(y))
    assert len(vals) <= (1 << bits) - 1 or bits == 8
    np.testing.assert_allclose(vals, -vals[::-1], atol=1e-6)  # symmetric
    assert 0.0 in np.round(vals, 6)


def test_8bit_quant_near_identity():
    x = jnp.linspace(0.01, 5.99, 100)
    y = qz.quantize_act(x, jnp.asarray(6.0), 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=6 / 255)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(BITS))
@settings(max_examples=25, deadline=None)
def test_quant_error_bounded(seed, bits):
    """|fq(x) - clip(x)| <= step/2 — the core quantization invariant."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64) * 3, jnp.float32)
    alpha = 2.0
    y = qz.quantize_act(x, jnp.asarray(alpha), bits)
    clipped = np.clip(np.asarray(x), 0, alpha)
    step = alpha / ((1 << bits) - 1)
    assert np.max(np.abs(np.asarray(y) - clipped)) <= step / 2 + 1e-6


def test_ste_gradient_passthrough():
    """d/dx fq(x) == 1 inside the clip range, 0 outside."""
    g = jax.grad(lambda x: qz.quantize_act(x, jnp.asarray(6.0), 4))
    assert g(jnp.asarray(3.0)) == 1.0
    assert g(jnp.asarray(7.0)) == 0.0
    assert g(jnp.asarray(-1.0)) == 0.0


def test_pact_alpha_gradient():
    """PACT: d fq/d alpha == 1 for saturated inputs, ~0 for interior."""
    g = jax.grad(lambda a: qz.quantize_act(jnp.asarray(10.0), a, 4))
    assert abs(float(g(jnp.asarray(6.0))) - 1.0) < 1e-5
    g_in = jax.grad(lambda a: qz.quantize_act(jnp.asarray(1.5), a, 8))
    assert abs(float(g_in(jnp.asarray(6.0)))) < 0.1


# ---------------------------------------------------------------------------
# Integer quantization + sub-byte packing roundtrips
# ---------------------------------------------------------------------------

@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(BITS),
       st.sampled_from([8, 16, 64, 256]))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(seed, bits, k):
    rng = np.random.default_rng(seed)
    half = (1 << (bits - 1)) - 1
    q = jnp.asarray(rng.integers(-half, half + 1, (4, k)), jnp.int8)
    packed = qz.pack_int(q, bits)
    assert packed.shape == (4, k * bits // 8)
    out = qz.unpack_int(packed, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(q))


@pytest.mark.parametrize("bits", BITS)
def test_int_quant_dequant_error(bits):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    alpha = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    q, scale = qz.quantize_weight_int(w, alpha, bits)
    back = np.asarray(q, np.float32) * np.asarray(scale)
    step = np.asarray(alpha) / ((1 << (bits - 1)) - 1)
    assert np.max(np.abs(back - np.asarray(w)) / step) <= 0.5 + 1e-5


def test_weight_bank_shapes():
    w = jnp.ones((8, 4))
    bank = qz.weight_bank(w, jnp.ones((8, 1)))
    assert bank.shape == (3, 8, 4)
