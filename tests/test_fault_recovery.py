"""Fault-injection integration test: training survives injected host
failures via checkpoint/restart supervision and produces the SAME final
state as an uninterrupted run (bit-exact restart semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.data import pipeline as pipe
from repro.dist import fault
from repro.train import checkpoint as ck
from repro.train import steps as steps_mod


def _run(tmp_path, fail_at=(), total=12, ckpt_every=4, permanent=False):
    cfg = get_config("qwen1.5-4b").reduced()
    hp = steps_mod.TrainHParams.for_arch(cfg, total_steps=total, lr=1e-3)
    train = jax.jit(steps_mod.make_train_step(cfg, hp))
    mgr = ck.CheckpointManager(str(tmp_path), keep=2)
    fails = set(fail_at)

    def make_state(_):
        return steps_mod.init_train_state(cfg, hp, jax.random.PRNGKey(0))

    def run_steps(state, start, stop):
        gen = pipe.SyntheticLM(cfg.vocab_size, 16, 4, seed=1)
        for s in range(start, stop):
            if s in fails:
                if not permanent:
                    fails.discard(s)       # fail once then recover
                raise fault.HostFailure(0)
            state, _ = train(state, gen._gen(s))
        return state, stop

    def save(step, state):
        mgr.save(step, state, meta={}, block=True)

    def restore():
        st, step, _ = mgr.restore_latest(jax.eval_shape(lambda:
                                                        make_state(0)))
        return (step, st) if st is not None else (None, None)

    state, step, restarts = fault.run_supervised(
        total, make_state, run_steps, save, restore, ckpt_every=ckpt_every)
    return state, step, restarts


def test_training_survives_failures(tmp_path):
    clean, _, r0 = _run(tmp_path / "clean")
    assert r0 == 0
    faulty, step, r1 = _run(tmp_path / "faulty", fail_at=(6, 9))
    assert r1 == 2 and step == 12
    # identical final params: restart replays from the checkpoint with the
    # deterministic pipeline, so the trajectories coincide
    for a, b in zip(jax.tree_util.tree_leaves(clean["params"]),
                    jax.tree_util.tree_leaves(faulty["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    with pytest.raises(fault.HostFailure):
        _run(tmp_path, fail_at=(2,), total=8, permanent=True)
