"""Roofline-term extraction: HLO shape parsing, collective accounting, and
an end-to-end check against a real (tiny-mesh) compiled module."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha


def test_shape_bytes_simple():
    assert ha.shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert ha.shape_bytes("bf16[16]") == 32
    assert ha.shape_bytes("u8[4,4]") == 16
    assert ha.shape_bytes("pred[]") == 1


def test_shape_bytes_tuple():
    s = "(f32[8,8], bf16[4])"
    assert ha.shape_bytes(s) == 8 * 8 * 4 + 4 * 2


SAMPLE_HLO = """
HloModule jit_f
ENTRY %main {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %dot.1 = f32[16,1024]{1,0} dot(%p0, %p0)
  %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%dot.1), replica_groups={}
  %ag.in = bf16[8,64]{1,0} copy(%p0)
  %all-gather.3 = bf16[8,1024]{1,0} all-gather(%ag.in), dimensions={1}
  ROOT %t = (f32[16,1024]{1,0}) tuple(%all-reduce.1)
}
"""


def test_parse_collectives_counts_and_bytes():
    st = ha.parse_collectives(SAMPLE_HLO)
    assert st.counts == {"all-reduce": 1, "all-gather": 1}
    ar = 2 * 16 * 1024 * 4            # ring all-reduce moves 2x operand
    ag = 8 * 1024 * 2                 # result-sized
    assert st.bytes_moved == ar + ag


def test_roofline_bottleneck_pick():
    r = ha.Roofline(flops=1e12, hbm_bytes=1e9, collective_bytes=0,
                    compute_s=1e12 / ha.PEAK_FLOPS_BF16,
                    memory_s=1e9 / ha.HBM_BW, collective_s=0.0,
                    bottleneck="compute", collective_counts={})
    assert r.compute_s > r.memory_s


def test_end_to_end_tiny_mesh():
    """Real lowering on the 1-device test mesh: cost analysis plumbs through
    (no collectives expected on a 1x1 mesh)."""
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh()

    def f(x, w):
        return jnp.tanh(x @ w)

    with mesh:
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 16), jnp.float32)).compile()
    roof = ha.roofline_terms(c)
    assert roof.flops >= 2 * 32 * 64 * 16
    assert roof.collective_bytes == 0
    assert roof.bottleneck in ("compute", "memory")


def test_model_flops_per_step():
    assert ha.model_flops_per_step(1000, 10, "train") == 6e4
    assert ha.model_flops_per_step(1000, 10, "serve") == 2e4
