"""Ragged-vs-lockstep parity for the request-level serving engine.

The continuous-batching redesign (api/scheduler.py) must not change a
single token: with equal-length synchronized requests ``ServingEngine.run``
is operand-for-operand a lockstep prefill+decode loop over the shared
``engine.serving_jits`` executables, so its tokens must be
**bit-identical**; on staggered traces every request must decode as if it
were alone in the pool (per-slot positions + live masks isolate slots), so
each output must match a per-request lockstep generate token-for-token and
be independent of co-scheduled slot contents.  The engines here run the
default **paged** KV cache (PR 6) where the family supports it — the
dense-vs-paged bit-parity guards live in tests/test_paged_cache.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api.engine as engine_mod
from repro.api.engine import serving_jits
from repro.api.sampling import GREEDY, SamplingParams, sample
from repro.api.scheduler import Request, ServingEngine
from repro.config import get_config
from repro.models import serving

_CFG_CACHE = {}


def _setup(arch, seed=0, **overrides):
    """Config + deployed params, cached so every test (and the module-level
    serving jit caches keyed on cfg id) shares one instance per arch."""
    key = (arch, seed, tuple(sorted(overrides.items())))
    if key not in _CFG_CACHE:
        cfg = get_config(arch).reduced()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(seed))
        _CFG_CACHE[key] = (cfg, dp)
    return _CFG_CACHE[key]


def _lockstep_generate(cfg, dp, batch, gen, max_len, backend="jnp",
                       sampling=GREEDY, key=None):
    """Lockstep oracle: one shared prefill, then ``gen`` synchronized
    decode steps over the module-cached ``serving_jits`` executables —
    the ~10-line loop that replaced the removed ``ServingSession``.
    Returns tokens (B, gen+1) including the prefill-sampled one."""
    fns = serving_jits(cfg, backend)
    B, S = batch["tokens"].shape
    if sampling.kind != "greedy" and key is None:
        key = jax.random.PRNGKey(0)
    logits, pf = fns["prefill"](dp, batch)
    caches = serving.embed_caches(pf, serving.init_caches(cfg, B, max_len))
    if key is not None:
        key, k0 = jax.random.split(key)
    tokens = sample(logits[:, -1:], sampling, None if key is None else k0)
    out = [tokens]
    for i in range(gen):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits, caches = fns["decode"](dp, tokens, caches, pos)
        if key is not None:
            key, ki = jax.random.split(key)
        tokens = sample(logits[:, -1:], sampling,
                        None if key is None else ki)
        out.append(tokens)
    return jnp.concatenate(out, axis=1)


def _prompts(cfg, shape, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, shape).astype(np.int32)


# ---------------------------------------------------------------------------
# Equal-length synchronized requests: bit-identical to the lockstep loop
# ---------------------------------------------------------------------------

SYNC_CASES = [
    ("qwen1.5-4b", "jnp"),          # dense
    ("deepseek-v3-671b", "jnp"),    # moe + mla
    ("mamba2-780m", "jnp"),         # ssm
    ("qwen1.5-4b", "pallas"),       # dense through the fused kernels
]


@pytest.mark.parametrize("arch,backend", SYNC_CASES)
def test_sync_requests_bit_identical_to_lockstep(arch, backend):
    cfg, dp = _setup(arch)
    B, S, G = (2, 4, 3) if backend == "pallas" else (2, 8, 6)
    toks = _prompts(cfg, (B, S), seed=1)
    ref = _lockstep_generate(cfg, dp, {"tokens": jnp.asarray(toks)},
                             gen=G - 1, max_len=S + G, backend=backend)
    eng = ServingEngine(cfg, dp, backend=backend, max_slots=B,
                        max_len=S + G, prefill_len=S)
    outs = eng.run([Request(toks[i], max_tokens=G) for i in range(B)])
    assert eng.stats["prefill_launches"] == 1   # one shared admission
    for i in range(B):
        np.testing.assert_array_equal(outs[i].tokens, np.asarray(ref[i]))


# ---------------------------------------------------------------------------
# Staggered arrivals: every request matches its own per-request generate
# ---------------------------------------------------------------------------

STAGGER = dict(lens=(8, 6, 7, 5), mts=(10, 3, 6, 4), arrivals=(0, 0, 2, 5),
               P=8, M=24, B=2)


def _stagger_trace(cfg, seed):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32),
                    max_tokens=m)
            for l, m in zip(STAGGER["lens"], STAGGER["mts"])]


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-780m",
                                  "deepseek-v3-671b"])
def test_staggered_matches_per_request_generate(arch):
    # MoE couples co-batched rows only through expert-capacity overflow
    # drops; a large capacity_factor removes drops (capacity == tokens), so
    # routing stays per-token and the slot-isolation contract is testable.
    over = ({"capacity_factor": 64.0} if arch == "deepseek-v3-671b" else {})
    cfg, dp = _setup(arch, **over)
    reqs = _stagger_trace(cfg, seed=2)
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=STAGGER["B"],
                        max_len=STAGGER["M"], prefill_len=STAGGER["P"])
    outs = eng.run(reqs, STAGGER["arrivals"])
    for i, r in enumerate(reqs):
        ref = _lockstep_generate(cfg, dp,
                                 {"tokens": jnp.asarray(r.tokens)[None]},
                                 gen=r.max_tokens - 1, max_len=STAGGER["M"])
        np.testing.assert_array_equal(
            outs[i].tokens, np.asarray(ref[0]),
            err_msg=f"request {i} diverged from its per-request lockstep "
                    "generate")
        assert outs[i].finish_reason == "length"


def test_staggered_outputs_independent_of_coscheduled_slots():
    """The same request must produce the same tokens no matter what shares
    the pool with it: different co-requests, arrival patterns and queueing
    pressure may not leak into a slot (per-slot masks + page tables)."""
    cfg, dp = _setup("qwen1.5-4b")
    probe = Request(_prompts(cfg, (7,), seed=3), max_tokens=8)

    def run_with(others, arrivals):
        eng = ServingEngine(cfg, dp, backend="jnp", max_slots=2,
                            max_len=24, prefill_len=8)
        outs = eng.run([probe] + others, arrivals)
        return outs[0].tokens

    alone = run_with([], [0])
    rng = np.random.default_rng(4)
    for seed, arrivals in ((5, [0, 0, 1]), (6, [0, 2, 3])):
        others = [Request(rng.integers(0, cfg.vocab_size,
                                       (int(rng.integers(1, 9)),)
                                       ).astype(np.int32),
                          max_tokens=int(rng.integers(2, 10)))
                  for _ in range(2)]
        np.testing.assert_array_equal(alone, run_with(others, arrivals))


def test_eos_frees_slot_early():
    cfg, dp = _setup("qwen1.5-4b")
    reqs = _stagger_trace(cfg, seed=2)
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=2,
                        max_len=24, prefill_len=8)
    base = eng.run(reqs, STAGGER["arrivals"])[0].tokens
    assert len(base) >= 4
    eos = int(base[3])
    reqs = _stagger_trace(cfg, seed=2)
    reqs[0] = dataclasses.replace(reqs[0], eos_id=eos)
    eng2 = ServingEngine(cfg, dp, backend="jnp", max_slots=2,
                         max_len=24, prefill_len=8)
    outs = eng2.run(reqs, STAGGER["arrivals"])
    np.testing.assert_array_equal(outs[0].tokens, base[:4])
    assert outs[0].finish_reason == "eos"
    # the freed slot really was reclaimed early
    assert eng2.stats["decode_launches"] <= eng.stats["decode_launches"]


# ---------------------------------------------------------------------------
# Launch/compile counters: slot reuse must never re-jit
# ---------------------------------------------------------------------------

def test_zero_recompiles_after_warmup():
    cfg, dp = _setup("qwen1.5-4b")
    mk = lambda: ServingEngine(cfg, dp, backend="jnp", max_slots=2,
                               max_len=24, prefill_len=8)
    eng = mk()
    eng.run(_stagger_trace(cfg, seed=7), STAGGER["arrivals"])
    warm = eng.compile_counts()
    eng2 = mk()                                  # fresh engine, same shapes
    eng2.run(_stagger_trace(cfg, seed=8), [0, 1, 4, 6])
    assert eng2.stats["decode_launches"] > 0
    assert eng2.stats["prefill_launches"] >= 2   # slots really were refilled
    assert eng2.compile_counts() == warm, \
        "slot-pool serving recompiled after warmup"


def test_engine_construction_reuses_module_jits():
    """Satellite: serving executables are module-cached — constructing a
    second engine (or calling serving_jits twice) must reuse the same
    compiled wrappers, never rebuild them per instance."""
    cfg, dp = _setup("qwen1.5-4b")
    assert serving_jits(cfg, "jnp")["prefill"] \
        is serving_jits(cfg, "jnp")["prefill"]
    mk = lambda: ServingEngine(cfg, dp, backend="jnp", max_slots=2,
                               max_len=24, prefill_len=8)
    e1, e2 = mk(), mk()
    assert e1._admit_fn is e2._admit_fn and e1._step_fn is e2._step_fn


def test_serving_session_is_removed():
    """Satellite: the deprecated lockstep ServingSession (PR 5) is gone —
    request-level serving goes through ServingEngine, lockstep baselines
    through serving_jits loops."""
    assert not hasattr(engine_mod, "ServingSession")


# ---------------------------------------------------------------------------
# Per-slot decode mechanics (serving-level)
# ---------------------------------------------------------------------------

def test_scalar_pos_broadcasts_to_vector():
    cfg, dp = _setup("qwen1.5-4b")
    tok = jnp.ones((2, 1), jnp.int32)
    lg_s, c_s = serving.decode_step(dp, cfg, tok,
                                    serving.init_caches(cfg, 2, 16),
                                    jnp.asarray(4, jnp.int32))
    lg_v, c_v = serving.decode_step(dp, cfg, tok,
                                    serving.init_caches(cfg, 2, 16),
                                    jnp.full((2,), 4, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
    for a, b in zip(jax.tree_util.tree_leaves(c_s),
                    jax.tree_util.tree_leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "deepseek-v3-671b",
                                  "mamba2-780m"])
def test_dead_slots_leave_caches_untouched(arch):
    """live=False rows must drop every cache write: attention/MLA ring
    scatters and SSM state updates alike."""
    cfg, dp = _setup(arch)
    caches = serving.init_caches(cfg, 2, 16)
    # populate both rows, then step again with row 1 dead
    _, c1 = serving.decode_step(dp, cfg, jnp.ones((2, 1), jnp.int32), caches,
                                jnp.full((2,), 3, jnp.int32))
    _, c2 = serving.decode_step(dp, cfg, jnp.full((2, 1), 5, jnp.int32), c1,
                                jnp.full((2,), 4, jnp.int32),
                                live=jnp.asarray([True, False]))
    changed = dead_same = True
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(c2)):
        a, b = np.asarray(a), np.asarray(b)
        dead_same &= np.array_equal(a[:, 1], b[:, 1])
        changed &= not np.array_equal(a[:, 0], b[:, 0])
    assert dead_same, "dead slot's cache was written"
    assert changed, "live slot's cache did not advance"


def test_ragged_positions_decode_each_row_at_its_own_depth():
    """Two slots at different positions attend to different history depths:
    zeroing cache entries above a row's pos must not change that row."""
    cfg, dp = _setup("qwen1.5-4b")
    caches = serving.init_caches(cfg, 2, 16)
    pos = jnp.asarray([2, 7], jnp.int32)
    tok = jnp.ones((2, 1), jnp.int32)
    lg, _ = serving.decode_step(dp, cfg, tok, caches, pos)
    # wipe ring entries 8.. (above both rows): logits must be unchanged
    wiped = jax.tree_util.tree_map(
        lambda t: t.at[:, :, :, 8:].set(0) if t.ndim == 5 and t.shape[3] == 16
        else t, caches)
    lg2, _ = serving.decode_step(dp, cfg, tok, wiped, pos)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg2))


# ---------------------------------------------------------------------------
# Sampling helper (satellite)
# ---------------------------------------------------------------------------

def test_sampling_greedy_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((3, 1, 17)))
    np.testing.assert_array_equal(np.asarray(sample(logits, GREEDY)),
                                  np.argmax(np.asarray(logits), axis=-1))


def test_sampling_top1_equals_greedy_for_any_key():
    logits = jnp.asarray(np.random.default_rng(1).standard_normal((4, 9)))
    p = SamplingParams(kind="top_k", top_k=1, temperature=0.7)
    for seed in range(3):
        np.testing.assert_array_equal(
            np.asarray(sample(logits, p, jax.random.PRNGKey(seed))),
            np.argmax(np.asarray(logits), axis=-1))


def test_sampling_top_k_restricts_support():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((64, 11)))
    p = SamplingParams(kind="top_k", top_k=3)
    ids = np.asarray(sample(logits, p, jax.random.PRNGKey(0)))
    top3 = np.argsort(np.asarray(logits), axis=-1)[:, -3:]
    assert all(ids[i] in top3[i] for i in range(ids.shape[0]))


def test_sampling_top_k_clamps_to_vocab():
    """Regression: ``top_k > vocab_size`` used to crash inside
    ``jax.lax.top_k``; it must mean "no restriction" instead, and the
    clamped kind must stay usable under jit (the serving steps jit it)."""
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.standard_normal((8, 11)))
    big = SamplingParams(kind="top_k", top_k=999, temperature=0.8)
    full = SamplingParams(kind="top_k", top_k=11, temperature=0.8)
    key = jax.random.PRNGKey(3)
    a = np.asarray(sample(logits, big, key))            # must not raise
    np.testing.assert_array_equal(a, np.asarray(sample(logits, full, key)))
    jitted = jax.jit(lambda lg, k: sample(lg, big, k))
    np.testing.assert_array_equal(np.asarray(jitted(logits, key)), a)


def test_sampling_top_k_keeps_kth_ties():
    """Tie pinning: every logit EQUAL to the kth-largest stays in the
    support (the strict ``lg < kth`` mask) — top_k=1 over an all-tied row
    can therefore sample any index."""
    logits = jnp.zeros((256, 5))
    p = SamplingParams(kind="top_k", top_k=1)
    ids = np.asarray(sample(logits, p, jax.random.PRNGKey(0)))
    assert len(np.unique(ids)) > 1                     # ties all reachable


def test_sampling_temperature_deterministic_per_key():
    logits = jnp.asarray(np.random.default_rng(3).standard_normal((5, 13)))
    p = SamplingParams(kind="temperature", temperature=1.3)
    a = sample(logits, p, jax.random.PRNGKey(7))
    b = sample(logits, p, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.asarray(a).max() < 13 and np.asarray(a).min() >= 0


def test_sampling_validation():
    with pytest.raises(ValueError):
        SamplingParams(kind="nucleus")
    with pytest.raises(ValueError):
        SamplingParams(kind="top_k", top_k=0)
    with pytest.raises(ValueError):
        sample(jnp.zeros((2, 4)), SamplingParams(kind="temperature"))


def test_lockstep_generate_with_sampling_params():
    """The lockstep oracle consumes the shared helper too (satellite):
    stochastic generation is deterministic per key and shaped like
    greedy."""
    cfg, dp = _setup("qwen1.5-4b")
    batch = {"tokens": jnp.asarray(_prompts(cfg, (2, 8), seed=9))}
    p = SamplingParams(kind="top_k", top_k=4, temperature=0.9)
    t1 = _lockstep_generate(cfg, dp, batch, gen=3, max_len=12,
                            key=jax.random.PRNGKey(0), sampling=p)
    t2 = _lockstep_generate(cfg, dp, batch, gen=3, max_len=12,
                            key=jax.random.PRNGKey(0), sampling=p)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 4)


# ---------------------------------------------------------------------------
# Submit validation
# ---------------------------------------------------------------------------

def test_submit_rejects_overflow():
    cfg, dp = _setup("qwen1.5-4b")
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=2, max_len=16,
                        prefill_len=8)
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(Request(np.zeros(9, np.int32)))
    with pytest.raises(ValueError, match="overflows"):
        eng.submit(Request(np.zeros(8, np.int32), max_tokens=10))
    rid = eng.submit(Request(np.zeros(8, np.int32), max_tokens=9))
    assert isinstance(rid, int)
