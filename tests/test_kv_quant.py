"""Channel-wise packed KV cache (models/kv_quant + kernels/decode_attention).

Four layers of guards:

* **quantizer properties** — round-trip error bounds per channel group at
  every bit-width, the all-zero-row scale floor, GQA / MLA-latent layouts,
  and the 8-bit single-group case being BIT-identical to the legacy
  ``attn.quant_per_token`` int8 scheme;
* **page composition** — packing is feature-axis only, so packed rows pass
  through the page-pool scatter/gather byte-for-byte and reconstruct the
  dense ring exactly regardless of how channel groups align with
  ``page_size``;
* **fused kernel** — the Pallas decode-attention kernel (in-VMEM
  unpack+scale) is bitwise-equal to the jitted jnp dequant reference;
* **serving level** — at ``kv_bits=8`` the packed engines (jnp AND pallas,
  dense + moe+mla + audio) are token-for-token identical to the legacy
  int8 engine on the staggered paged trace with zero recompiles after
  warmup, and 4-bit packing keeps strictly fewer KV bytes resident.
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api.scheduler import Request, ServingEngine
from repro.cache import paged
from repro.core import quantizers as qz
from repro.kernels import decode_attention as datt
from repro.models import attention as attn
from repro.models import kv_quant as kvq
from repro.models import serving
from test_continuous_batching import STAGGER, _setup, _stagger_trace

BITS_CASES = [8, 4, 2, (2, 4, 8), (4, 8)]


def _rand(shape, seed, scale=2.0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape) * scale,
        jnp.bfloat16)


# ---------------------------------------------------------------------------
# Spec resolution
# ---------------------------------------------------------------------------

def test_spec_for_uniform_and_grouped():
    s = kvq.spec_for(8, 16)
    assert s.bits == (8,) and s.sizes == (16,)
    assert s.feat == 16 and s.n_groups == 1 and s.packed_bytes == 16
    s = kvq.spec_for(4, 16)
    assert s.packed_bytes == 8
    s = kvq.spec_for((2, 4, 8), 16)
    assert s.sizes == (4, 4, 8) and sum(s.sizes) == 16
    assert s.packed_bytes == 4 // 4 + 4 // 2 + 8  # 1 + 2 + 8
    assert kvq.spec_for(None, 16) is None


def test_spec_for_rejects_unpackable():
    with pytest.raises(ValueError):
        kvq.spec_for(2, 14)                      # 14 % 4 != 0
    with pytest.raises(ValueError):
        kvq.spec_for((2, 4, 8), 8)               # too narrow for 3 groups
    with pytest.raises(ValueError):
        kvq.KVQuantSpec((3,), (16,))             # bit not in alphabet
    with pytest.raises(ValueError):
        kvq.KVQuantSpec((2,), (6,))              # 6 % pack_factor(2) != 0


def test_kv_specs_family_routing():
    cfg, _ = _setup("qwen1.5-4b")
    g, m = serving.kv_specs(cfg, 8)
    assert g is not None and m is None and g.feat == cfg.head_dim
    mcfg, _ = _setup("deepseek-v3-671b", capacity_factor=64.0)
    g, m = serving.kv_specs(mcfg, 8)
    assert g is None and m is not None and m.feat == mcfg.kv_lora_rank
    scfg, _ = _setup("mamba2-780m")
    assert serving.kv_specs(scfg, 8) == (None, None)   # no ring to pack
    assert serving.kv_specs(cfg, None) == (None, None)


# ---------------------------------------------------------------------------
# Round-trip properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", BITS_CASES)
@pytest.mark.parametrize("shape", [(2, 2, 9, 16),   # GQA (B, KV, S, hd)
                                   (2, 9, 16)])     # MLA latent (B, S, kvr)
def test_roundtrip_error_bound(kv_bits, shape):
    """|t - dequant(quant(t))| <= scale/2 per element: symmetric rounding
    never loses more than half a step, for every group at its own bits."""
    spec = kvq.spec_for(kv_bits, shape[-1])
    t = _rand(shape, seed=hash((kv_bits, shape)) % 1000)
    packed, scales = kvq.quant_channelwise(t, spec)
    assert packed.dtype == jnp.uint8
    assert packed.shape == shape[:-1] + (spec.packed_bytes,)
    assert scales.dtype == jnp.float32
    assert scales.shape == shape[:-1] + (spec.n_groups,)
    deq = kvq.dequant_channelwise(packed, scales, spec, jnp.float32)
    lo = 0
    for g, n in enumerate(spec.sizes):
        err = np.abs(np.asarray(t[..., lo:lo + n], np.float32)
                     - np.asarray(deq[..., lo:lo + n]))
        bound = np.asarray(scales[..., g:g + 1]) * 0.5 + 1e-6
        # bf16 inputs are exactly representable in f32, so the only error
        # is the quantization step itself
        assert (err <= bound).all(), (kv_bits, g, err.max())
        lo += n


@pytest.mark.parametrize("kv_bits", BITS_CASES)
def test_zero_rows_floor_scale_and_roundtrip_exact(kv_bits):
    spec = kvq.spec_for(kv_bits, 16)
    t = jnp.zeros((3, 5, 16), jnp.bfloat16)
    packed, scales = kvq.quant_channelwise(t, spec)
    assert not np.asarray(packed).any()              # zero codes
    halves = [float((1 << (b - 1)) - 1) for b in spec.bits]
    np.testing.assert_allclose(
        np.asarray(scales),
        np.stack([np.full((3, 5), 1e-6 / h) for h in halves], -1),
        rtol=1e-6)
    deq = np.asarray(kvq.dequant_channelwise(packed, scales, spec))
    assert (deq == 0.0).all()                        # exact zeros back


@pytest.mark.parametrize("kv_bits", BITS_CASES)
def test_zero_codes_zero_scales_dequantize_to_exact_zero(kv_bits):
    """The audio cross-cache decode-only stand-in ships all-zero packed
    bytes AND all-zero scales; the packed path must keep it exactly 0.0."""
    spec = kvq.spec_for(kv_bits, 16)
    packed = jnp.zeros((2, 4, 6, spec.packed_bytes), jnp.uint8)
    scales = jnp.zeros((2, 4, 6, spec.n_groups), jnp.float32)
    deq = np.asarray(kvq.dequant_channelwise(packed, scales, spec))
    assert (deq == 0.0).all()


def test_8bit_single_group_is_bitwise_quant_per_token():
    """kv_bits=8 reproduces the legacy int8-per-token scheme exactly: same
    amax/127 scale with the same 1e-6 floor, same clip; 8-bit "packing" is
    a pure int8<->uint8 bitcast.  This equivalence is what pins the packed
    engine token-for-token against the legacy engine below."""
    spec = kvq.spec_for(8, 16)
    t = _rand((2, 3, 7, 16), seed=11)
    t = t.at[0, 0, 0].set(0)                         # exercise the floor
    packed, scales = kvq.quant_channelwise(t, spec)
    q8, s8 = attn.quant_per_token(t)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(q8.view(jnp.uint8)))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(s8))
    legacy = (q8.astype(jnp.float32) * s8).astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(kvq.dequant_channelwise(packed, scales, spec)).view(np.uint16),
        np.asarray(legacy).view(np.uint16))


# ---------------------------------------------------------------------------
# Page composition: packed rows stream through the pool byte-for-byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits,page_size", [(8, 4), (4, 3), ((2, 4, 8), 2)])
def test_packed_rows_survive_page_scatter_gather(kv_bits, page_size):
    """Packing is feature-axis only — a page boundary never splits a byte —
    so scatter_prefill + gather_pages reconstruct the packed dense ring
    bitwise for ANY (group sizes, page_size) combination."""
    B, KV, n_pp = 2, 2, 3
    S = n_pp * page_size
    spec = kvq.spec_for(kv_bits, 16)
    t = _rand((B, KV, S, 16), seed=7)
    packed, scales = kvq.quant_channelwise(t, spec)
    NP = 1 + B * n_pp                                # + NULL page
    pages = jnp.arange(1, NP, dtype=jnp.int32).reshape(B, n_pp)
    wp_flat = pages.reshape(-1)
    for leaf in (packed, scales):
        pool = jnp.zeros((1, NP, KV, page_size, leaf.shape[-1]), leaf.dtype)
        pool = paged.scatter_prefill(pool, leaf[None], wp_flat)
        ring = paged.gather_pages(pool[0], pages)
        np.testing.assert_array_equal(np.asarray(ring), np.asarray(leaf))


# ---------------------------------------------------------------------------
# Fused Pallas kernel vs jnp dequant reference (bitwise, jit vs jit)
# ---------------------------------------------------------------------------

def _jnp_reference(q, kp, ks, vp, vs, pos, spec):
    """The legacy einsum formulation of gqa_decode's attention math over
    the channel-wise dequantized ring — what the packed jnp path runs."""
    B, KV, rep, hd = q.shape
    S = kp.shape[2]
    kf = kvq.dequant_channelwise(kp, ks, spec, jnp.bfloat16)
    vf = kvq.dequant_channelwise(vp, vs, spec, jnp.bfloat16)
    qh = q.reshape(B, KV * rep, 1, hd)
    kfe = jnp.repeat(kf, rep, axis=1) if rep > 1 else kf
    vfe = jnp.repeat(vf, rep, axis=1) if rep > 1 else vf
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kfe).astype(jnp.float32)
    s = s / math.sqrt(hd)
    valid = jnp.arange(S)[None, None, None, :] <= pos[:, None, None, None]
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(jnp.bfloat16)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, vfe)
    return o.reshape(B, KV, rep, hd)


@pytest.mark.parametrize("kv_bits", [8, 4, (2, 4, 8)])
@pytest.mark.parametrize("qdtype", [jnp.bfloat16, jnp.float32])
def test_fused_kernel_bitwise_matches_jnp_reference(kv_bits, qdtype):
    """Both query dtypes matter: post-RoPE queries arrive f32 (the score
    dot must promote like the einsum, not round to bf16 first), while
    rope-free sites pass bf16."""
    B, KV, rep, hd, S = 2, 2, 3, 16, 12
    spec = kvq.spec_for(kv_bits, hd)
    k = _rand((B, KV, S, hd), seed=3)
    v = _rand((B, KV, S, hd), seed=4)
    q = _rand((B, KV, rep, hd), seed=5, scale=1.0).astype(qdtype)
    kp, ks = kvq.quant_channelwise(k, spec)
    vp, vs = kvq.quant_channelwise(v, spec)
    pos = jnp.asarray([5, S - 1], jnp.int32)
    ref = jax.jit(lambda *a: _jnp_reference(*a, spec))(q, kp, ks, vp, vs, pos)
    out = datt.decode_attention(q, kp, ks, vp, vs, pos,
                                spec.bits, spec.sizes)
    # compare bit patterns: both paths are jitted, and the per-block dot
    # rounds bf16 identically to the batched einsum under jit
    np.testing.assert_array_equal(np.asarray(out).view(np.uint16),
                                  np.asarray(ref).view(np.uint16))


# ---------------------------------------------------------------------------
# Serving level: packed engines vs the legacy int8 engine
# ---------------------------------------------------------------------------

def _run_stagger(arch, **ekw):
    over = ({"capacity_factor": 64.0} if arch == "deepseek-v3-671b" else {})
    cfg, dp = _setup(arch, **over)
    reqs = _stagger_trace(cfg, seed=2)
    if cfg.family == "audio":
        rng = np.random.default_rng(5)
        for r in reqs:
            r.extras["frames"] = (rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)) * 0.1).astype(np.float32)
    eng = ServingEngine(cfg, dp, max_slots=STAGGER["B"],
                        max_len=STAGGER["M"], prefill_len=STAGGER["P"],
                        **ekw)
    outs = eng.run(reqs, STAGGER["arrivals"])
    return [outs[i].tokens.tolist() for i in range(len(reqs))], eng


@pytest.mark.parametrize("arch", ["qwen1.5-4b",        # dense GQA
                                  "deepseek-v3-671b",  # moe + mla latent
                                  "whisper-small"])    # audio self + cross
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_packed_8bit_token_identical_to_int8_engine(arch, backend):
    """The acceptance pin: at kv_bits=8 the packed paged engine (fused
    Pallas AND jnp dequant) emits token-for-token the legacy int8 engine's
    staggered trace, and never recompiles after its warmup launches.  The
    baseline runs on the SAME backend — backends may legitimately differ
    from each other in low bf16 bits (the linears), but within a backend
    the packed cache must change nothing."""
    base, _ = _run_stagger(arch, backend=backend)
    got, eng = _run_stagger(arch, kv_bits=8, backend=backend)
    assert got == base
    counts = eng.compile_counts()
    assert counts == {"admit": 1, "step": 1}, counts
    # steady state: another trace through the same engine adds no entries
    cfg = eng.cfg
    reqs = _stagger_trace(cfg, seed=3)
    if cfg.family == "audio":
        rng = np.random.default_rng(6)
        for r in reqs:
            r.extras["frames"] = (rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)) * 0.1).astype(np.float32)
    eng.run(reqs, STAGGER["arrivals"])
    assert eng.compile_counts() == counts


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "deepseek-v3-671b"])
def test_4bit_resident_bytes_strictly_below_int8(arch):
    def mid_resident(**ekw):
        over = ({"capacity_factor": 64.0}
                if arch == "deepseek-v3-671b" else {})
        cfg, dp = _setup(arch, **over)
        eng = ServingEngine(cfg, dp, max_slots=STAGGER["B"],
                            max_len=STAGGER["M"], prefill_len=STAGGER["P"],
                            **ekw)
        for r in _stagger_trace(cfg, seed=2)[:2]:
            eng.submit(r)
        for _ in range(6):
            eng.step()
        assert eng.live_slots > 0                    # measured mid-flight
        return eng.kv_bytes_resident(), eng.kv_bytes_dense()

    r4, d4 = mid_resident(kv_bits=4)
    r8, d8 = mid_resident()
    assert r4 < r8 and d4 < d8


def test_mixed_bits_engine_runs_and_prices_between():
    """A channel-wise (2, 8) policy serves end to end; its cache bytes sit
    strictly between uniform 2-bit and the int8 baseline.  (At the reduced
    head_dim the per-group f32 scales are a large fraction of a row, so a
    milder mix like (4, 8) lands exactly ON the int8 figure — the byte
    ordering that must hold for ANY mix is packed values + scales,
    monotone in the assigned bits.)"""
    cfg, dp = _setup("qwen1.5-4b")
    _, eng_m = _run_stagger("qwen1.5-4b", kv_bits=(2, 8))
    assert eng_m.compile_counts() == {"admit": 1, "step": 1}
    d = {b: ServingEngine(cfg, dp, max_slots=2, max_len=16, prefill_len=8,
                          kv_bits=b).kv_bytes_dense()
         for b in (2, (2, 8), None)}
    assert d[2] < d[(2, 8)] < d[None]


def test_engine_rejects_unpackable_policy_eagerly():
    cfg, dp = _setup("qwen1.5-4b")
    with pytest.raises(ValueError):
        ServingEngine(cfg, dp, kv_bits=3, max_slots=2, max_len=16,
                      prefill_len=8)
