"""Speculative decoding: draft/verify rounds over the serving engine.

Three layers of guards:

* **acceptance rule** (api/sampling.speculative_accept) — greedy
  acceptance is exact argmax-prefix match; stochastic acceptance with
  ``q == p`` keeps every proposal; with a DIVERGENT draft the emitted
  first token is still distributed as verifier-only sampling (the
  rejection-sampling guarantee, checked empirically on fixed keys);
* **engine parity** (the anchor) — under greedy sampling the speculative
  ``ServingEngine`` emits token-for-token the non-speculative engine's
  staggered trace on the SAME backend (dense + paged, jnp + pallas,
  dense + moe configs), for the self-draft AND for an aggressively
  re-quantized 2-bit draft whose proposals are mostly rejected.  The
  full-prefix-hit boot path (suppressed first write in a shared radix
  page) goes through the one-tick baseline fallback and stays exact.
  PR 7's caveat restated: parity is per backend — backends may differ
  from each other in low bf16 bits of the linears;
* **serving-surface regressions** — ``run()`` no longer KeyErrors on
  requests submitted before it (they come back under ``"rid:<n>"``
  keys), ``submit()`` rejects non-1-D / non-integer prompts, and
  ``SamplingParams`` rejects inapplicable knob combinations instead of
  silently ignoring them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.sampling import (GREEDY, SamplingParams, _dist,
                                speculative_accept)
from repro.api.scheduler import Request, ServingEngine
from repro.models import serving
from test_continuous_batching import STAGGER, _setup, _stagger_trace


# ---------------------------------------------------------------------------
# Acceptance rule
# ---------------------------------------------------------------------------

def _onehot_logits(ids, V, lo=-4.0, hi=4.0):
    """Logit rows whose argmax is ``ids`` — (len(ids), V)."""
    lg = np.full((len(ids), V), lo, np.float32)
    lg[np.arange(len(ids)), ids] = hi
    return jnp.asarray(lg)


def test_greedy_accept_is_argmax_prefix_match():
    V = 11
    verify = jnp.stack([_onehot_logits([5, 3, 7], V),
                        _onehot_logits([2, 2, 2], V)])      # (B=2, k+1, V)
    draft_lg = verify[:, :2]                                # unused by greedy
    drafts = jnp.asarray([[5, 9],     # first matches, second rejected
                          [4, 2]])    # first rejected (match after it moot)
    accepted, out = speculative_accept(drafts, draft_lg, verify, GREEDY)
    np.testing.assert_array_equal(np.asarray(accepted), [1, 0])
    # every emitted token is a verifier argmax: row b emits out[:acc+1]
    np.testing.assert_array_equal(np.asarray(out), [[5, 3, 7], [2, 2, 2]])


def test_stochastic_accepts_everything_when_q_equals_p():
    rng = np.random.default_rng(0)
    B, k, V = 64, 3, 7
    lg = jnp.asarray(rng.standard_normal((B, k + 1, V)), jnp.float32)
    params = SamplingParams(kind="temperature", temperature=0.8)
    # draft tokens genuinely sampled from q = p's filtered distribution
    key = jax.random.PRNGKey(1)
    kq, ka = jax.random.split(key)
    drafts = jax.random.categorical(kq, lg[:, :k] / 0.8, axis=-1)
    accepted, out = speculative_accept(drafts, lg[:, :k], lg, params, key=ka)
    # q(d)/p(d) == 1 -> accept prob min(1, 1) beats every uniform draw
    np.testing.assert_array_equal(np.asarray(accepted), np.full(B, k))
    np.testing.assert_array_equal(np.asarray(out[:, :k]),
                                  np.asarray(drafts, np.int32))


def test_stochastic_first_token_matches_verifier_distribution():
    """Rejection sampling with a DIVERGENT draft: the marginal of the
    first emitted token equals the verifier's filtered softmax (Leviathan
    et al. Thm. 1), checked empirically over many independent rows."""
    rng = np.random.default_rng(3)
    B, k, V = 4000, 2, 8
    p_row = jnp.asarray(rng.standard_normal((k + 1, V)) * 1.5, jnp.float32)
    q_row = jnp.asarray(rng.standard_normal((k, V)) * 1.5, jnp.float32)
    verify = jnp.broadcast_to(p_row, (B, k + 1, V))
    draft_lg = jnp.broadcast_to(q_row, (B, k, V))
    params = SamplingParams(kind="temperature", temperature=1.0)
    kq, ka = jax.random.split(jax.random.PRNGKey(4))
    drafts = jax.random.categorical(kq, draft_lg, axis=-1)   # per-row iid
    _, out = speculative_accept(drafts, draft_lg, verify, params, key=ka)
    first = np.asarray(out[:, 0])
    emp = np.bincount(first, minlength=V) / B
    target = np.asarray(_dist(p_row[0], params))
    # ~6 sigma at B=4000 for per-bin std sqrt(p(1-p)/B) <= 0.008
    np.testing.assert_allclose(emp, target, atol=0.05)
    # and the draft really diverges (otherwise this test proves nothing)
    assert not np.allclose(np.asarray(_dist(q_row[0], params)), target,
                           atol=0.05)


def test_stochastic_accept_requires_key():
    lg = jnp.zeros((1, 3, 4))
    with pytest.raises(ValueError, match="needs a PRNG key"):
        speculative_accept(jnp.zeros((1, 2), jnp.int32), lg[:, :2], lg,
                           SamplingParams(kind="temperature",
                                          temperature=0.5))


def test_sampling_params_reject_inapplicable_knobs():
    """Regression: inapplicable knobs used to be silently ignored —
    kind="temperature" with top_k=5 sampled the FULL vocab."""
    with pytest.raises(ValueError, match="top_k=5 is inapplicable"):
        SamplingParams(kind="temperature", temperature=0.7, top_k=5)
    with pytest.raises(ValueError, match="inapplicable"):
        SamplingParams(kind="greedy", top_k=3)
    with pytest.raises(ValueError, match="temperature=0.5 is inapplicable"):
        SamplingParams(kind="greedy", temperature=0.5)
    # the applicable combinations still construct
    SamplingParams(kind="top_k", top_k=5, temperature=0.7)
    SamplingParams(kind="temperature", temperature=0.7)


# ---------------------------------------------------------------------------
# Engine parity: greedy speculative == baseline, token for token
# ---------------------------------------------------------------------------

def _run(arch, k=0, draft=None, page_size="auto", backend="jnp",
         trace_seed=2, **ekw):
    over = ({"capacity_factor": 64.0} if arch == "deepseek-v3-671b" else {})
    cfg, dp = _setup(arch, **over)
    reqs = _stagger_trace(cfg, seed=trace_seed)
    eng = ServingEngine(cfg, dp, backend=backend, max_slots=STAGGER["B"],
                        max_len=STAGGER["M"], prefill_len=STAGGER["P"],
                        page_size=page_size, speculate_k=k,
                        draft_dparams=draft, **ekw)
    outs = eng.run(reqs, STAGGER["arrivals"])
    return [outs[i].tokens.tolist() for i in range(len(reqs))], eng


@pytest.mark.parametrize("arch,page_size,backend", [
    ("qwen1.5-4b", "auto", "jnp"),          # dense family, paged
    ("qwen1.5-4b", None, "jnp"),            # dense family, dense rings
    ("qwen1.5-4b", "auto", "pallas"),       # fused kernels end to end
    ("deepseek-v3-671b", "auto", "jnp"),    # moe + mla multi-token verify
])
def test_greedy_self_draft_parity_and_full_acceptance(arch, page_size,
                                                      backend):
    """Self-draft (draft == verifier): greedy acceptance keeps every
    proposal, every round, and the emitted staggered trace is
    token-for-token the non-speculative engine's on the same backend."""
    base, _ = _run(arch, k=0, page_size=page_size, backend=backend)
    got, eng = _run(arch, k=2, page_size=page_size, backend=backend)
    assert got == base
    st = eng.stats
    assert st["verify_launches"] > 0
    # >= 1 live slot per round, k accepted per live slot
    assert st["accepted_tokens"] >= 2 * st["verify_launches"]


def test_low_bit_draft_still_greedy_exact():
    """The parity anchor holds for ANY draft: a 2-bit re-quantized draft
    (serving.draft_model) proposes mostly-rejected tokens, yet every
    emitted token is a verifier argmax — the output stream is unchanged."""
    cfg, dp = _setup("qwen1.5-4b")
    draft = serving.draft_model(dp, cfg, 2)
    base, _ = _run("qwen1.5-4b", k=0)
    got, eng = _run("qwen1.5-4b", k=2, draft=draft)
    assert got == base
    # with random reduced-config weights a 2-bit requant is a genuinely
    # different model: some round must reject (else this test is the
    # self-draft one again)
    st = eng.stats
    assert st["accepted_tokens"] < 2 * st["verify_launches"]


def test_full_prefix_hit_boot_stays_exact_under_speculation():
    """Duplicate prompts: later admissions are full prefix hits whose
    first write position sits in a SHARED radix page — the speculative
    scheduler must route that tick through the suppressed-write baseline
    fallback (then catch the draft up) without changing a token."""
    cfg, dp = _setup("qwen1.5-4b")
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, (STAGGER["P"],)).astype(np.int32)
    reqs = lambda: [Request(prompt.copy(), max_tokens=m)
                    for m in (6, 5, 4, 5)]
    arrivals = (0, 0, 3, 6)

    def run(k):
        eng = ServingEngine(cfg, dp, backend="jnp",
                            max_slots=STAGGER["B"], max_len=STAGGER["M"],
                            prefill_len=STAGGER["P"], speculate_k=k)
        outs = eng.run(reqs(), arrivals)
        return [outs[i].tokens.tolist() for i in range(4)], eng

    base, beng = run(0)
    got, eng = run(2)
    assert got == base
    assert eng.stats["zero_prefill_admits"] > 0     # the path was exercised
    assert eng.stats["decode_launches"] > 0         # fallback tick(s) ran
    assert eng.stats["verify_launches"] > 0         # and real rounds too


def test_speculative_engine_zero_recompiles_after_warmup():
    _, eng = _run("qwen1.5-4b", k=2)
    counts = eng.compile_counts()
    # (absolute counts can exceed 1: the module-level jit entries are
    # shared across tests, and a re-quantized draft has different avals)
    assert set(counts) == {"admit", "step", "draft", "verify"}
    assert counts["admit"] >= 1 and counts["draft"] >= 1
    assert counts["verify"] >= 1
    # steady state: a fresh trace through the same engine adds no entries
    cfg = eng.cfg
    eng.run(_stagger_trace(cfg, seed=3), STAGGER["arrivals"])
    assert eng.compile_counts() == counts


def test_deterministic_stochastic_speculative_run():
    """Stochastic speculative serving is reproducible per engine seed and
    actually finishes the trace (acceptance, rewind, catch-up and the
    residual correction all jitted into the verify launch)."""
    params = SamplingParams(kind="top_k", top_k=5, temperature=0.8)
    a, ea = _run("qwen1.5-4b", k=2, sampling=params, seed=7)
    b, _ = _run("qwen1.5-4b", k=2, sampling=params, seed=7)
    assert a == b
    assert ea.stats["verify_launches"] > 0
    assert [len(t) for t in a] == list(STAGGER["mts"])


def test_unsupported_families_reject_speculation_eagerly():
    cfg, dp = _setup("mamba2-780m")
    with pytest.raises(ValueError, match="cannot serve speculatively"):
        ServingEngine(cfg, dp, max_slots=2, max_len=16, prefill_len=8,
                      page_size=None, speculate_k=2)
    with pytest.raises(ValueError, match="cannot draft"):
        serving.draft_model(dp, cfg, 2)
    # and the model layer refuses a multi-token window outright: recurrent
    # state cannot rewind to the accepted length
    caches = serving.init_caches(cfg, 2, 16)
    with pytest.raises(ValueError, match="multi-token verify"):
        serving.decode_step(dp, cfg, jnp.zeros((2, 2), jnp.int32), caches,
                            jnp.asarray([4, 4], jnp.int32))


# ---------------------------------------------------------------------------
# Serving-surface regressions
# ---------------------------------------------------------------------------

def test_run_returns_presubmitted_requests_under_rid_keys():
    """Regression: a request submitted before ``run()`` used to KeyError
    the collection loop (its rid has no index in ``requests``); it now
    finishes under the ``"rid:<n>"`` key alongside the positional ones."""
    cfg, dp = _setup("qwen1.5-4b")
    eng = ServingEngine(cfg, dp, max_slots=2, max_len=16, prefill_len=8)
    toks = np.arange(1, 7, dtype=np.int32)
    rid = eng.submit(Request(toks, max_tokens=4))
    outs = eng.run([Request(toks + 1, max_tokens=3)])
    assert set(outs) == {0, f"rid:{rid}"}
    assert len(outs[f"rid:{rid}"].tokens) == 4
    assert len(outs[0].tokens) == 3


def test_submit_rejects_malformed_prompts():
    """Regression: only axis 0 used to be checked — a ``(L, 2)`` array or
    a float prompt passed validation and corrupted the admission batch."""
    cfg, dp = _setup("qwen1.5-4b")
    eng = ServingEngine(cfg, dp, max_slots=2, max_len=16, prefill_len=8)
    with pytest.raises(ValueError, match="must be a 1-D array"):
        eng.submit(Request(np.ones((4, 2), np.int32)))
    with pytest.raises(ValueError, match="must be a 1-D array"):
        eng.submit(Request(np.int32(3)))                 # 0-D scalar
    with pytest.raises(ValueError, match="not an integer type"):
        eng.submit(Request(np.asarray([0.5, 1.2, 3.0])))
    rid = eng.submit(Request(np.asarray([1, 2, 3], np.int64),
                             max_tokens=4))                  # ints OK
    assert rid == 0
