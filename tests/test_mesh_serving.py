"""Mesh-aware serving: token identity, sharded kernels, host drain.

The mesh serving contract (PR 9) is **token identity**: a ServingEngine
constructed with a ``(data, model)`` mesh must emit exactly the tokens the
single-device engine emits on the same trace — ``mesh=None``, a trivial
``(1, 1)`` mesh and an 8-way ``(2, 4)`` mesh are all interchangeable, on
both the jnp and pallas backends.  The placement that makes this possible
(``ShardingRules.serving_shardings``): only operands whose sharded compute
is bitwise-exact may shard — a QTensor's fused buffers through the
shard_map integer kernels (whole N-tiles / whole experts per device),
caches along their slot/page axis — while every float GEMM weight
replicates (CPU f32 matmuls are not shard-invariant).

The 8-device cases need ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
**and** ``REPRO_KEEP_XLA_FLAGS=1`` (tests/conftest.py otherwise strips the
flag); without them they skip and the 1-device subset still runs.

Also here: the heartbeat-driven host-drain path (a dead data-axis host's
slots requeue and every request still completes with the exact baseline
tokens) and the ``count_pallas_launches`` shard_map/pjit walk guard.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.api.qtensor import QTensor
from repro.api.scheduler import Request, ServingEngine
from repro.config import get_config
from repro.dist import sharding as shd
from repro.kernels import ops
from repro.kernels import quant_matmul as qmk
from repro.models import serving

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs 8 CPU devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8 + REPRO_KEEP_XLA_FLAGS=1)")

_CFG_CACHE = {}


def _setup(arch, seed=0):
    if arch not in _CFG_CACHE:
        cfg = get_config(arch).reduced()
        dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(seed))
        _CFG_CACHE[arch] = (cfg, dp)
    return _CFG_CACHE[arch]


def _mesh(data, model):
    n = data * model
    return Mesh(np.asarray(jax.devices()[:n]).reshape(data, model),
                ("data", "model"))


def _trace(cfg, lens, mts, seed=2):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32),
                    max_tokens=m) for l, m in zip(lens, mts)]


STAG = dict(lens=(8, 6, 7, 5), mts=(10, 3, 6, 4), arrivals=(0, 0, 2, 5),
            P=8, M=24, B=2)
STAG_SMALL = dict(lens=(6, 4, 5), mts=(4, 2, 3), arrivals=(0, 0, 2),
                  P=8, M=16, B=2)


def _run(cfg, dp, backend, spec, mesh=None, max_slots=None):
    eng = ServingEngine(cfg, dp, backend=backend,
                        max_slots=max_slots or spec["B"],
                        max_len=spec["M"], prefill_len=spec["P"], mesh=mesh)
    outs = eng.run(_trace(cfg, spec["lens"], spec["mts"]), spec["arrivals"])
    return eng, {i: np.asarray(outs[i].tokens)
                 for i in range(len(spec["lens"]))}


# ---------------------------------------------------------------------------
# Token identity: mesh=(1,1) (runs on any host) and 8-way (2,4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen1.5-4b", "deepseek-v3-671b"])
def test_mesh1_token_identical(arch):
    """A trivial (1, 1) mesh engine is bit-for-bit the meshless engine."""
    cfg, dp = _setup(arch)
    _, base = _run(cfg, dp, "jnp", STAG)
    _, m1 = _run(cfg, dp, "jnp", STAG, mesh=_mesh(1, 1))
    for i in base:
        np.testing.assert_array_equal(base[i], m1[i],
                                      err_msg=f"{arch} request {i}")


@needs8
@pytest.mark.parametrize("arch", ["qwen1.5-4b", "deepseek-v3-671b"])
def test_mesh8_token_identical_jnp(arch):
    """8-way (data=2, model=4) jnp engine == single-device engine on a
    staggered trace (dense and moe+mla)."""
    cfg, dp = _setup(arch)
    _, base = _run(cfg, dp, "jnp", STAG)
    _, m8 = _run(cfg, dp, "jnp", STAG, mesh=_mesh(2, 4))
    for i in base:
        np.testing.assert_array_equal(base[i], m8[i],
                                      err_msg=f"{arch} request {i}")


@needs8
@pytest.mark.parametrize("arch", ["qwen1.5-4b", "deepseek-v3-671b"])
def test_mesh8_token_identical_pallas(arch):
    """Same 8-way identity through the fused Pallas kernels — deepseek's
    expert stacks route through the shard_map EP kernel (E=4 over
    model=4), qwen's non-periodic tile schedules fall back to the
    replicated fused launch (both must land on the same tokens)."""
    cfg, dp = _setup(arch)
    _, base = _run(cfg, dp, "pallas", STAG_SMALL)
    _, m8 = _run(cfg, dp, "pallas", STAG_SMALL, mesh=_mesh(2, 4))
    for i in base:
        np.testing.assert_array_equal(base[i], m8[i],
                                      err_msg=f"{arch} request {i}")


@needs8
def test_mesh8_zero_recompiles_after_warmup():
    """The mesh engine keeps the fixed-shape launch contract: after the
    first trace warms the jit caches, serving more requests must not grow
    them."""
    cfg, dp = _setup("qwen1.5-4b")
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=STAG["B"],
                        max_len=STAG["M"], prefill_len=STAG["P"],
                        mesh=_mesh(2, 4))
    eng.run(_trace(cfg, STAG["lens"], STAG["mts"]), STAG["arrivals"])
    warm = eng.compile_counts()
    eng.run(_trace(cfg, STAG["lens"], STAG["mts"], seed=7),
            STAG["arrivals"])
    assert eng.compile_counts() == warm


# ---------------------------------------------------------------------------
# Host failure: heartbeat-declared death drains slots, trace completes
# ---------------------------------------------------------------------------

@needs8
def test_host_drain_mid_trace_token_identical():
    """Killing one data-axis host mid-trace drains its slots back into the
    admission queue; every request still completes, with tokens exactly
    equal to the unfailed baseline (greedy replay determinism)."""
    cfg, dp = _setup("qwen1.5-4b")
    lens, mts = (8, 6, 7, 5), (10, 8, 9, 7)
    reqs = _trace(cfg, lens, mts)

    def drive(mesh=None, fail_host=None):
        eng = ServingEngine(cfg, dp, backend="jnp", max_slots=4,
                            max_len=24, prefill_len=8, mesh=mesh)
        rids = [eng.submit(r) for r in reqs]
        outs, t = {}, 0
        while eng.has_work():
            if fail_host is not None and t == 1:
                eng.fail_host(fail_host)
            eng.step()
            for o in eng.collect():
                outs[o.rid] = o
            t += 1
        return eng, {r: np.asarray(outs[r].tokens) for r in rids}

    _, base = drive()
    eng, failed = drive(mesh=_mesh(2, 4), fail_host=1)
    assert eng.stats["host_drains"] == 1
    assert eng.stats["drained_requests"] > 0
    assert len(failed) == len(reqs)          # every request completed
    # host 1's slots are retired from admission
    from repro.dist import fault
    for s in fault.owned_slots(1, 4, 2):
        assert eng._dead_slots[s] and eng._slots[s] is None
    for r in base:
        np.testing.assert_array_equal(base[r], failed[r],
                                      err_msg=f"request {r} diverged "
                                              "after host drain")


def test_fail_host_validates_range():
    cfg, dp = _setup("qwen1.5-4b")
    eng = ServingEngine(cfg, dp, backend="jnp", max_slots=2, max_len=16,
                        prefill_len=8)
    with pytest.raises(ValueError):
        eng.fail_host(1)                     # meshless fleet has 1 host


# ---------------------------------------------------------------------------
# Sharded fused kernels: bitwise identity with the unsharded launch
# ---------------------------------------------------------------------------

def _uniform_qtensor(seed, c_out, c_in, bits, tile_n):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((c_out, c_in)).astype(np.float32)
    alpha = np.abs(w).max(-1)
    return QTensor.from_assignment(w, np.full(c_out, bits), alpha,
                                   tile_n=tile_n)


def test_tp_chunk_periodicity():
    """Shard gate: a schedule splits iff it is ``parts`` identical chunks."""
    assert qmk.tp_chunk((8, 8, 8, 8), 4) == (8,)
    assert qmk.tp_chunk((8, 4, 8, 4), 2) == (8, 4)
    assert qmk.tp_chunk((8, 8, 4, 4), 2) is None      # sorted, not periodic
    assert qmk.tp_chunk((8, 4, 8), 2) is None         # odd tile count
    assert qmk.tp_chunk((8, 4), 1) is None            # no model parallelism
    assert qmk.tp_chunk(None, 4) is None


@needs8
def test_fused_tp_bitwise_identical():
    """shard_map TP fused GEMM == the unsharded single launch, bit for bit
    (each device runs the same int kernel over its own whole tiles)."""
    qt = _uniform_qtensor(3, 64, 32, 8, tile_n=16)    # schedule (8,8,8,8)
    mesh = _mesh(1, 4)
    chunk = qmk.tp_chunk(qt.tile_bits, 4)
    assert chunk is not None
    x = jnp.asarray(np.random.default_rng(5).standard_normal((9, 32)),
                    jnp.float32)
    y_ref = ops.quant_matmul_fused(
        x, qt.fused_packed, qt.fused_scales, qt.fused_perm, qt.tile_bits,
        qt.tile_n, qt.c_in, qt.c_out)
    y_tp = ops.quant_matmul_fused_tp(
        x, qt.fused_packed, qt.fused_scales, qt.fused_perm, qt.tile_bits,
        chunk, qt.tile_n, qt.c_in, qt.c_out, mesh)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_tp))
    # and QTensor.matmul routes there by itself inside a serving context
    ctx = shd.MeshContext(mesh)
    with shd.serving_mesh(ctx):
        y_auto = qt.matmul(x, jnp.float32, backend="pallas")
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_auto))


@needs8
def test_fused_ep_bitwise_identical():
    """shard_map EP expert-batched GEMM == the unsharded 3-D launch."""
    E, c_out, c_in = 4, 40, 16
    cfg = get_config("deepseek-v3-671b").reduced()
    dp = serving.init_deployed_linear(jax.random.PRNGKey(7), c_in, c_out,
                                      cfg, expert_axis=E)
    qt = dp["w"]
    assert qt.experts == E
    x = jnp.asarray(np.random.default_rng(9).standard_normal((E, 8, c_in)),
                    jnp.float32)
    y_ref = np.asarray(qt.matmul(x, jnp.float32, backend="pallas"))
    ctx = shd.MeshContext(_mesh(2, 4))
    with shd.serving_mesh(ctx):                       # E=4 % model=4 == 0
        y_ep = np.asarray(qt.matmul(x, jnp.float32, backend="pallas"))
    np.testing.assert_array_equal(y_ref, y_ep)


# ---------------------------------------------------------------------------
# Placement rules
# ---------------------------------------------------------------------------

def test_serving_shardings_replicate_everything_but_fused():
    """Serving placement: QTensor fused buffers may shard (tile schedule /
    expert axis permitting), every other leaf — norm scales, biases,
    embeddings, dequant buckets — replicates, and the decision log says
    why."""
    cfg, dp = _setup("qwen1.5-4b")
    rules = shd.ShardingRules(_mesh(1, 1))
    sh = rules.serving_shardings(dp)
    flat, _ = jax.tree_util.tree_flatten_with_path(dp)
    specs = jax.tree_util.tree_leaves(
        sh, is_leaf=lambda s: hasattr(s, "spec"))
    assert len(flat) == len(specs)
    for (path, _), s in zip(flat, specs):
        name = jax.tree_util.keystr(path)
        if "fused_packed" not in name and "fused_scales" not in name:
            assert all(a is None for a in s.spec), (name, s.spec)
    notes = " ".join(d.note for d in rules.decisions)
    assert "serving token-identity" in notes
    assert "qtensor" in notes or "fused" in notes


def test_mesh_context_validates_axes():
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    with pytest.raises(ValueError):
        shd.MeshContext(Mesh(devs, ("x", "y")))
    assert not shd.MeshContext(None).is_active
    assert shd.MeshContext(None).data == 1 and shd.MeshContext(None).model == 1


@needs8
def test_cache_shardings_slot_axis():
    """Cache leaves shard axis 1 (slot/page) on data when it divides;
    non-divisible extents and low-rank leaves replicate."""
    ctx = shd.MeshContext(_mesh(2, 4))
    tree = {"k": jnp.zeros((2, 4, 8)), "odd": jnp.zeros((2, 5, 8)),
            "pos": jnp.zeros((3,))}
    sh = ctx.cache_shardings(tree)
    assert sh["k"].spec == P(None, "data")
    assert sh["odd"].spec == P()             # 5 % data=2 != 0
    assert sh["pos"].spec == P()
    # a 1-wide data axis never bothers sharding
    assert shd.MeshContext(_mesh(1, 1)).cache_shardings(tree)["k"].spec == P()


# ---------------------------------------------------------------------------
# count_pallas_launches walks into shard_map / pjit bodies
# ---------------------------------------------------------------------------

def test_count_launches_through_shard_map_and_pjit():
    """The launch counter must see kernels hidden under shard_map and
    nested-jit (pjit) sub-jaxprs — one program-level count each."""
    from jax.experimental.shard_map import shard_map

    qt = _uniform_qtensor(11, 32, 16, 8, tile_n=16)
    x = jnp.zeros((4, 16), jnp.float32)

    def fused(xv):
        return ops.quant_matmul_fused(
            xv, qt.fused_packed, qt.fused_scales, qt.fused_perm,
            qt.tile_bits, qt.tile_n, qt.c_in, qt.c_out)

    assert ops.count_pallas_launches(fused, x) == 1
    # under an explicit nested jit (pjit eqn in the outer jaxpr)
    assert ops.count_pallas_launches(jax.jit(fused), x) == 1

    mesh = _mesh(1, 1)

    def sharded(xv):
        return shard_map(fused, mesh=mesh, in_specs=(P(),), out_specs=P(),
                         check_rep=False)(xv)

    assert ops.count_pallas_launches(sharded, x) == 1
    # two launches under one shard_map still count as two
    def sharded2(xv):
        return shard_map(lambda v: fused(v) + fused(v), mesh=mesh,
                         in_specs=(P(),), out_specs=P(),
                         check_rep=False)(xv)

    assert ops.count_pallas_launches(sharded2, x) == 2
