"""Differential parity harness for the fused single-launch GEMM kernel.

Four implementations of the same deployed mixed-precision linear map are
run against each other:

  fused             — ONE pallas_call over the tile-aligned ragged buffer
                      (kernels/quant_matmul.quant_matmul_fused_2d),
                      ``backend="pallas"``
  per-group         — one pallas_call per precision group + concat +
                      order restore, ``backend="pallas-pergroup"``
  jnp               — per-group dense fallback, ``backend="jnp"``
  frozen reference  — fake-quant float weights (the fine-tune phase's view
                      of the same integer grid), plain einsum

At ``compute_dtype=f32`` the fused and per-group paths reduce K in a
single dot of identical length (kernels/quant_matmul.pick_bk — the
bit-exactness contract), so they must agree **bit-exactly**; the jnp and
frozen references differ only in where the per-channel scale is applied
(before vs after the dot), so they agree to f32 roundoff.

Sweeps are seeded-numpy parametrized (no ``hypothesis``): bit mixes over
{2,4,8}, off-tile N/K, single-group, all-8-bit, and one-channel-group
edge cases, plus the four MLPerf-Tiny configs end-to-end and the
launch-count guards (exactly one pallas_call per deployed linear/conv).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Engine, PrecisionPolicy, QTensor
from repro.data import pipeline as pipe
from repro.kernels import ops
from repro.models import tinyml

REF_TOL = 1e-5          # vs jnp / frozen fake-quant (scale-placement ulps)


def _mk_qtensor(seed, c_out, c_in, bits_per_channel, tile_n, align=1,
                restore_order=True):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((c_out, c_in)).astype(np.float32)
    alpha = np.abs(w).max(-1)
    return w, QTensor.from_assignment(w, bits_per_channel, alpha, align=align,
                                      restore_order=restore_order,
                                      tile_n=tile_n)


def _x(seed, m, c_in):
    return jnp.asarray(np.random.default_rng(seed).standard_normal((m, c_in)),
                       jnp.float32)


CASES = [
    # (name, c_out, c_in, tile_n, bits_fn)
    ("mixed-248", 40, 64, 16, lambda rng, n: rng.choice([2, 4, 8], size=n)),
    ("off-tile-N-K", 50, 33, 16, lambda rng, n: rng.choice([2, 4, 8], size=n)),
    ("single-group-4b", 24, 32, 8, lambda rng, n: np.full(n, 4)),
    ("all-8-bit", 20, 48, 16, lambda rng, n: np.full(n, 8)),
    ("one-channel-group", 17, 20, 8,
     lambda rng, n: np.asarray([2] + [8] * (n - 1))),
    ("two-bit-heavy-tiny-K", 9, 5, 4,
     lambda rng, n: rng.choice([2, 4], size=n, p=[0.8, 0.2])),
]


@pytest.mark.parametrize("name,c_out,c_in,tile_n,bits_fn", CASES,
                         ids=[c[0] for c in CASES])
def test_fused_vs_pergroup_bitexact(name, c_out, c_in, tile_n, bits_fn):
    """Fused single-launch == per-group Pallas, bit for bit (f32 compute)."""
    rng = np.random.default_rng(sum(ord(c) for c in name))
    bits = bits_fn(rng, c_out)
    _, qt = _mk_qtensor(11, c_out, c_in, bits, tile_n)
    assert qt.fused_packed is not None and qt.tile_n == tile_n
    for m in (1, 5, 130):
        x = _x(m, m, c_in)
        y_fused = np.asarray(qt.matmul(x, jnp.float32, backend="pallas"))
        y_pg = np.asarray(qt.matmul(x, jnp.float32,
                                    backend="pallas-pergroup"))
        np.testing.assert_array_equal(y_fused, y_pg, err_msg=f"{name} m={m}")
        assert y_fused.shape == (m, c_out)


@pytest.mark.parametrize("name,c_out,c_in,tile_n,bits_fn", CASES,
                         ids=[c[0] for c in CASES])
def test_fused_vs_jnp_and_frozen_reference(name, c_out, c_in, tile_n,
                                           bits_fn):
    """Fused vs the jnp backend and the fake-quant float reference."""
    rng = np.random.default_rng(sum(ord(c) for c in name) + 1)
    bits = bits_fn(rng, c_out)
    w, qt = _mk_qtensor(13, c_out, c_in, bits, tile_n)
    x = _x(17, 6, c_in)
    y_fused = np.asarray(qt.matmul(x, jnp.float32, backend="pallas"))
    y_jnp = np.asarray(qt.matmul(x, jnp.float32, backend="jnp"))
    # fake-quant reference: same integer grid, canonical order, scale
    # applied to the weight before the dot
    w_ref = qt.dequantize_canonical(jnp.float32)
    y_ref = np.asarray(x @ w_ref.T)
    scale = max(1.0, np.abs(y_ref).max())
    np.testing.assert_allclose(y_fused, y_jnp, atol=REF_TOL * scale,
                               rtol=REF_TOL, err_msg=name)
    np.testing.assert_allclose(y_fused, y_ref, atol=REF_TOL * scale,
                               rtol=REF_TOL, err_msg=name)


def test_fused_parity_seeded_sweep():
    """Seeded-numpy randomized sweep (the no-hypothesis property test)."""
    for seed in range(8):
        rng = np.random.default_rng(1000 + seed)
        c_out = int(rng.integers(3, 70))
        c_in = int(rng.integers(3, 90))
        tile_n = int(2 ** rng.integers(2, 6))
        bits = rng.choice([2, 4, 8], size=c_out)
        _, qt = _mk_qtensor(seed, c_out, c_in, bits, tile_n)
        x = _x(seed + 99, int(rng.integers(1, 40)), c_in)
        y_fused = np.asarray(qt.matmul(x, jnp.float32, backend="pallas"))
        y_pg = np.asarray(qt.matmul(x, jnp.float32,
                                    backend="pallas-pergroup"))
        y_jnp = np.asarray(qt.matmul(x, jnp.float32, backend="jnp"))
        np.testing.assert_array_equal(y_fused, y_pg, err_msg=f"seed={seed}")
        scale = max(1.0, np.abs(y_jnp).max())
        np.testing.assert_allclose(y_fused, y_jnp, atol=REF_TOL * scale,
                                   rtol=REF_TOL, err_msg=f"seed={seed}")


def test_fused_perm_folds_into_walk_order_for_single_group():
    """Single-precision weights need no output gather at all: the restore
    is the kernel's identity output index map (tile walk order)."""
    for bits_val, c_out, tile_n in [(4, 20, 8), (8, 129, 128), (2, 8, 8)]:
        _, qt = _mk_qtensor(3, c_out, 16, np.full(c_out, bits_val), tile_n)
        assert qt.fused_perm is None, (bits_val, c_out, tile_n)
        x = _x(5, 4, 16)
        np.testing.assert_array_equal(
            np.asarray(qt.matmul(x, jnp.float32, backend="pallas")),
            np.asarray(qt.matmul(x, jnp.float32, backend="pallas-pergroup")))


def test_fused_layout_skipped_for_deep_contractions():
    """K beyond the single-step budget keeps the per-group layout (the
    fused kernel reduces K in one dot) — backend="pallas" still works."""
    from repro.kernels import quant_matmul as qmk
    c_in = qmk.K_SINGLE_STEP_MAX + 4
    rng = np.random.default_rng(0)
    w = rng.standard_normal((8, c_in)).astype(np.float32)
    qt = QTensor.from_assignment(w, np.full(8, 8), np.abs(w).max(-1),
                                 tile_n=8)
    assert qt.fused_packed is None and qt.tile_n is None
    x = _x(1, 2, c_in)
    y = np.asarray(qt.matmul(x, jnp.float32, backend="pallas"))
    y_jnp = np.asarray(qt.matmul(x, jnp.float32, backend="jnp"))
    scale = max(1.0, np.abs(y_jnp).max())
    np.testing.assert_allclose(y, y_jnp, atol=1e-4 * scale, rtol=1e-4)


# ---------------------------------------------------------------------------
# End-to-end over the paper's four MLPerf-Tiny configs
# ---------------------------------------------------------------------------

TINY = ("resnet8-cifar10", "dscnn-kws", "mobilenetv1-vww", "dae-ad")


def _deployed_engine(name, seed=0, batch_size=2):
    cfg = tinyml.TINY_CONFIGS[name]
    eng = Engine.for_tinyml(cfg, key=jax.random.PRNGKey(seed))
    eng.randomize_nas(seed)
    eng.deploy(align=1)                  # tile_n="auto": fused layout
    batch = next(iter(pipe.SyntheticTiny(cfg, n=2 * batch_size,
                                         seed=seed).batches(batch_size)))
    return cfg, eng, batch


@pytest.mark.parametrize("name", TINY)
def test_tinyml_fused_bitexact_with_pergroup_and_matches_frozen(name):
    """Acceptance: fused single-launch serve == per-group serve bit-exactly
    and matches the frozen fake-quant reference on every MLPerf-Tiny
    config (depthwise sites take the identical grouped fall-back on both
    backends, so e2e equality covers every layer kind)."""
    _, eng, batch = _deployed_engine(name)
    frozen = np.asarray(
        eng.apply_fn(eng.params, eng.nas, PrecisionPolicy.FROZEN, batch),
        np.float32)
    out_fused = np.asarray(eng.serve(batch, backend="pallas"), np.float32)
    out_pg = np.asarray(eng.serve(batch, backend="pallas-pergroup"),
                        np.float32)
    np.testing.assert_array_equal(out_fused, out_pg,
                                  err_msg=f"{name}: fused vs per-group")
    scale = max(1.0, np.abs(frozen).max())
    np.testing.assert_allclose(out_fused, frozen, atol=1e-4 * scale,
                               rtol=1e-4, err_msg=f"{name}: fused vs frozen")


# ---------------------------------------------------------------------------
# Launch-count guards: exactly ONE pallas_call per deployed linear/conv
# ---------------------------------------------------------------------------

def _qtensor_sites(deployed_params):
    return {name: p["w"] for name, p in deployed_params.items()
            if isinstance(p, dict) and isinstance(p.get("w"), QTensor)}


def test_resnet8_serve_is_one_launch_per_layer():
    """The guard against silently falling back to the per-group loop: a
    deployed resnet8 forward must issue exactly one pallas_call per
    qlinear/qconv site (counted in the traced jaxpr — robust against jit
    caching), while the per-group backend issues one per precision group."""
    _, eng, batch = _deployed_engine("resnet8-cifar10")
    sites = _qtensor_sites(eng.deployed_params)
    n_sites = len(sites)
    n_groups = sum(len(qt.bits) for qt in sites.values())
    assert n_sites == 10                    # 8 backbone convs + shortcut...
    assert n_groups > n_sites               # randomized NAS => real mix

    def fwd(backend):
        pol = PrecisionPolicy.deployed(backend)
        return lambda dp, b: eng.apply_fn(dp, None, pol, b)

    assert ops.count_pallas_launches(fwd("pallas"), eng.deployed_params,
                                     batch) == n_sites
    assert ops.count_pallas_launches(fwd("pallas-pergroup"),
                                     eng.deployed_params, batch) == n_groups
    assert ops.count_pallas_launches(fwd("jnp"), eng.deployed_params,
                                     batch) == 0


def test_fused_matmul_is_single_pallas_call(monkeypatch):
    """Same guard at the QTensor level via a counting wrapper around
    ``pl.pallas_call`` (caches cleared so the trace really runs)."""
    from jax.experimental import pallas as pl

    _, qt = _mk_qtensor(7, 24, 32, np.asarray([2] * 8 + [4] * 8 + [8] * 8),
                        8)
    assert len(qt.bits) == 3
    x = _x(2, 4, 32)
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    ops.quant_matmul_fused.clear_cache()
    ops.quant_matmul.clear_cache()
    monkeypatch.setattr(pl, "pallas_call", counting)
    qt.matmul(x, jnp.float32, backend="pallas")
    assert len(calls) == 1                   # one launch, three precisions
    calls.clear()
    qt.matmul(x, jnp.float32, backend="pallas-pergroup")
    assert len(calls) == 3


# ---------------------------------------------------------------------------
# out_dtype default unification (ops.qtensor_matmul vs ops.qtensor_conv2d)
# ---------------------------------------------------------------------------

def test_qtensor_ops_default_out_dtype_is_f32():
    """Regression: qtensor_matmul defaulted to bf16 while qtensor_conv2d
    defaulted to f32 — both are f32 now (the bit-parity compute path)."""
    rng = np.random.default_rng(21)
    w = rng.standard_normal((12, 16)).astype(np.float32)
    qt = QTensor.from_assignment(w, rng.choice([2, 4, 8], size=12),
                                 np.abs(w).max(-1), tile_n=8)
    y = ops.qtensor_matmul(_x(1, 3, 16), qt)
    assert y.dtype == jnp.float32

    wc = rng.standard_normal((10, 4, 3, 3)).astype(np.float32)
    qtc = QTensor.from_assignment(wc, rng.choice([2, 4, 8], size=10),
                                  np.abs(wc.reshape(10, -1)).max(-1),
                                  tile_n=8)
    xc = jnp.asarray(rng.standard_normal((1, 6, 6, 4)), jnp.float32)
    yc = ops.qtensor_conv2d(xc, qtc)
    assert yc.dtype == jnp.float32
    # and both defaults agree numerically with the explicit f32 call
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(qt.matmul(_x(1, 3, 16), jnp.float32,
                                            backend="pallas")))
