"""The repro.api surface: QTensor pytree semantics, PrecisionPolicy
dispatch, and the Engine facade's search -> finetune -> deploy -> serve
lifecycle (acceptance: deployed model under jit through the Pallas
quant_matmul path == frozen fake-quant reference)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Engine, Phase, PrecisionPolicy, QTensor, as_policy
from repro.core import mixedprec as mp
from repro.core import search
from repro.data import pipeline as pipe
from repro.models import layers as L
from repro.models import tinyml

CFG = mp.MixedPrecConfig()


def _qtensor(key=0, c_out=24, c_in=32, align=1):
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(key),
                                     (c_out, c_in)), np.float32)
    rng = np.random.default_rng(key)
    bits = rng.choice([2, 4, 8], size=c_out)
    alpha = np.abs(w).max(-1)
    qt = QTensor.from_assignment(w, bits, alpha, align=align)
    return w, bits, alpha, qt


# ---------------------------------------------------------------------------
# QTensor
# ---------------------------------------------------------------------------

def test_qtensor_is_registered_pytree():
    _, _, _, qt = _qtensor()
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert all(hasattr(l, "shape") for l in leaves)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert qt2.bits == qt.bits and qt2.c_out == qt.c_out
    np.testing.assert_array_equal(np.asarray(qt2.inv_perm),
                                  np.asarray(qt.inv_perm))


def test_qtensor_flows_through_jit_and_vmap():
    w, _, _, qt = _qtensor()
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))

    y_jit = jax.jit(lambda q, x: q.matmul(x))(qt, x)
    np.testing.assert_allclose(np.asarray(y_jit),
                               np.asarray(qt.matmul(x)), atol=1e-6)

    # vmap over a stacked QTensor (leading axis on every leaf)
    stacked = jax.tree_util.tree_map(
        lambda t: jnp.stack([t, t]), qt)
    xb = jnp.stack([x, x])
    yb = jax.vmap(lambda q, x: q.matmul(x))(stacked, xb)
    assert yb.shape == (2, 4, qt.c_out)
    np.testing.assert_allclose(np.asarray(yb[0]), np.asarray(y_jit),
                               atol=1e-6)


def test_qtensor_dequantize_matches_frozen_reference():
    w, bits, alpha, qt = _qtensor()
    gamma = np.zeros((w.shape[0], 3), np.float32)
    for i, b in enumerate(bits):
        gamma[i, {2: 0, 4: 1, 8: 2}[b]] = 9.0
    frozen = mp.frozen_weight(jnp.asarray(w), jnp.asarray(gamma),
                              jnp.asarray(alpha), CFG)
    np.testing.assert_allclose(np.asarray(qt.dequantize()),
                               np.asarray(frozen), atol=1e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_qtensor_matmul_backends_agree(backend):
    _, _, _, qt = _qtensor(c_out=40, c_in=64)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 64))
    y = qt.matmul(x, jnp.float32, backend)
    y_ref = x @ qt.dequantize().T
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_qtensor_memory_and_group_sizes():
    _, bits, _, qt = _qtensor()
    assert sum(qt.group_sizes.values()) == qt.c_out
    for b, n in qt.group_sizes.items():
        assert n == int(np.sum(bits == b))
    assert qt.memory_bits == sum(int(p.size) * 8 for p in qt.packed)


def test_qtensor_conv_kernel_shape_roundtrip():
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (8, 3, 3, 3)),
                   np.float32)
    alpha = np.abs(w.reshape(8, -1)).max(-1)
    qt = QTensor.from_assignment(w, np.full(8, 8), alpha)
    assert qt.kernel_shape == (3, 3, 3)
    dense = qt.dense()
    assert dense.shape == w.shape
    np.testing.assert_allclose(np.asarray(dense), w, atol=1e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# PrecisionPolicy
# ---------------------------------------------------------------------------

def test_policy_singletons_and_pytree():
    assert PrecisionPolicy.FLOAT.phase is Phase.FLOAT
    assert not PrecisionPolicy.FLOAT.needs_nas
    assert PrecisionPolicy.FROZEN.needs_nas
    pol = PrecisionPolicy.search(3.3)
    leaves, treedef = jax.tree_util.tree_flatten(pol)
    assert len(leaves) == 1 and float(leaves[0]) == pytest.approx(3.3)
    pol2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert pol2.phase is Phase.SEARCH

    # tau is a LEAF: annealing it must not change the treedef (no retrace)
    _, td1 = jax.tree_util.tree_flatten(PrecisionPolicy.search(5.0))
    _, td2 = jax.tree_util.tree_flatten(PrecisionPolicy.search(4.9))
    assert td1 == td2


def test_as_policy_coercion():
    assert as_policy("float") is not None
    assert as_policy("qat8").phase is Phase.QAT8
    assert as_policy("search", tau=2.0).phase is Phase.SEARCH
    with pytest.raises(ValueError):
        as_policy("search")
    with pytest.raises(ValueError):
        as_policy("int3")
    p = PrecisionPolicy.FROZEN
    assert as_policy(p) is p


def test_qlinear_dispatches_on_policy_and_leaf_type():
    key = jax.random.PRNGKey(0)
    p = L.linear_init(key, 16, 8)
    nas = L.nas_init(key, 8, CFG)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16))
    y_float = L.qlinear(x, p, None, PrecisionPolicy.FLOAT, CFG)
    y_frozen = L.qlinear(x, p, nas, PrecisionPolicy.FROZEN, CFG)
    assert y_float.shape == y_frozen.shape == (4, 8)
    assert not np.allclose(np.asarray(y_float), np.asarray(y_frozen))
    # DEPLOYED policy over a float leaf is a type error, not silent fallback
    with pytest.raises(TypeError):
        L.qlinear(x, p, None, PrecisionPolicy.DEPLOYED, CFG)


# ---------------------------------------------------------------------------
# Engine facade (acceptance criterion)
# ---------------------------------------------------------------------------

def _engine(task="dae-ad", n=48, seed=0):
    cfg = tinyml.TINY_CONFIGS[task]
    settings = search.SearchSettings(
        cfg=cfg.quant, objective="size", lam=1e-6,
        warmup_epochs=1, search_epochs=1, finetune_epochs=1)
    eng = Engine.for_tinyml(cfg, settings, key=jax.random.PRNGKey(seed))
    data = pipe.SyntheticTiny(cfg, n=n, seed=seed)
    return cfg, eng, data


def test_engine_deployed_serve_matches_frozen_reference():
    """engine.deploy output runs under jax.jit end-to-end through the Pallas
    quant_matmul path and matches the frozen fake-quant reference."""
    cfg, eng, data = _engine()
    epochs = lambda: data.batches(16)
    eng.search(epochs).finetune(epochs)
    eng.deploy(align=1)
    batch = next(iter(data.batches(16, seed=5)))
    served = eng.serve(batch, backend="pallas")
    frozen = eng.apply_fn(eng.params, eng.nas, PrecisionPolicy.FROZEN, batch)
    np.testing.assert_allclose(np.asarray(served), np.asarray(frozen),
                               rtol=1e-3, atol=1e-3)
    # deployed leaves really are QTensors; packed model is smaller than f32
    site = sorted(eng.nas)[0]
    assert isinstance(eng.deployed_params[site]["w"], QTensor)
    assert eng.memory_bits() < 32 * sum(
        s.c_out * s.weights_per_channel for s in eng.specs.values())


def test_engine_deploy_alignment_promotion():
    """align=128 deployment still matches (promotion only adds precision)."""
    cfg, eng, data = _engine(n=32)
    epochs = lambda: data.batches(16)
    eng.search(epochs)
    eng.deploy(align=128)
    batch = next(iter(data.batches(16, seed=5)))
    served = eng.serve(batch, backend="jnp")
    assert bool(jnp.all(jnp.isfinite(served)))
    for name in eng.nas:
        qt = eng.deployed_params[name]["w"]
        sizes = qt.group_sizes
        for b, nrows in list(sizes.items())[:-1]:
            assert nrows % min(128, qt.c_out) == 0


def test_engine_history_phases():
    cfg, eng, data = _engine(n=32)
    epochs = lambda: data.batches(16)
    eng.search(epochs).finetune(epochs)
    phases = [h["phase"] for h in eng.history]
    assert "warmup" in phases and "search" in phases and "finetune" in phases
