"""Per-kernel shape/dtype sweeps against the pure-jnp ref.py oracles
(interpret mode executes the Pallas kernel body in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizers as qz
from repro.kernels import ops, ref

BITS = (2, 4, 8)


def _mk_packed(key, n, k, bits):
    w = jax.random.normal(key, (n, k), jnp.float32)
    alpha = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    q, scale = qz.quantize_weight_int(w, alpha, bits)
    return qz.pack_int(q, bits), scale[:, 0], w


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("m,k,n", [
    (8, 32, 16),          # tiny, unaligned-ish
    (64, 256, 192),       # mid
    (128, 512, 128),      # exactly one tile
    (100, 384, 130),      # pad in every dim
])
def test_quant_matmul_matches_ref(bits, m, k, n):
    key = jax.random.PRNGKey(bits * 1000 + m + n)
    packed, scale, _ = _mk_packed(key, n, k, bits)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k), jnp.float32)
    # pre-round x to bf16 so kernel (bf16 inputs, f32 accum) and the f32
    # oracle see bit-identical inputs; int weights <= 127 are bf16-exact
    x = x.astype(jnp.bfloat16).astype(jnp.float32)
    y = ops.quant_matmul(x, packed, scale, bits, k,
                         out_dtype=jnp.float32)
    y_ref = ref.quant_matmul_ref(x, packed, scale, bits, k)
    err = np.abs(np.asarray(y, np.float32) - np.asarray(y_ref, np.float32))
    # identical inputs; only f32 accumulation order differs (chunked K loop)
    assert err.max() <= 1e-4 * np.abs(np.asarray(y_ref)).max()


@pytest.mark.parametrize("bits", BITS)
def test_quant_matmul_batched_leading_dims(bits):
    key = jax.random.PRNGKey(7)
    packed, scale, _ = _mk_packed(key, 64, 128, bits)
    x = jax.random.normal(key, (2, 3, 128), jnp.float32)
    x = x.astype(jnp.bfloat16).astype(jnp.float32)
    y = ops.quant_matmul(x, packed, scale, bits, 128,
                         out_dtype=jnp.float32)
    assert y.shape == (2, 3, 64)
    y_ref = ref.quant_matmul_ref(x, packed, scale, bits, 128)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_out_dtype(out_dtype):
    key = jax.random.PRNGKey(3)
    packed, scale, _ = _mk_packed(key, 32, 64, 4)
    x = jax.random.normal(key, (16, 64), jnp.float32)
    y = ops.quant_matmul(x, packed, scale, 4, 64, out_dtype=out_dtype)
    assert y.dtype == out_dtype


@pytest.mark.parametrize("n,k", [(16, 32), (256, 512), (200, 300), (8, 128)])
def test_fused_mix_matches_ref(n, k):
    key = jax.random.PRNGKey(n + k)
    w = jax.random.normal(key, (n, k), jnp.float32)
    gamma_hat = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 1), (n, 3)), axis=-1)
    alpha = jnp.max(jnp.abs(w), axis=-1)
    y = ops.fused_mix(w, gamma_hat, alpha)
    y_ref = ref.fused_mix_ref(w, gamma_hat, alpha)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_mix_onehot_equals_single_fq():
    """One-hot gamma through the kernel == plain fake-quant at that bits."""
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    alpha = jnp.max(jnp.abs(w), axis=-1)
    for i, bits in enumerate(BITS):
        gh = jnp.zeros((32, 3)).at[:, i].set(1.0)
        y = ops.fused_mix(w, gh, alpha)
        exp = qz.quantize_weight(w, alpha[:, None], bits)
        np.testing.assert_allclose(np.asarray(y), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)


def test_quant_matmul_zero_weight_rows():
    """All-zero packed weights -> exactly zero output (scale irrelevant)."""
    packed = jnp.zeros((16, 32), jnp.uint8)
    scale = jnp.ones((16,), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    y = ops.quant_matmul(x, packed, scale, 2, 128,
                         out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), 0.0)
