"""Per-kernel shape/dtype sweeps against the pure-jnp ref.py oracles
(interpret mode executes the Pallas kernel body in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizers as qz
from repro.kernels import ops, ref

BITS = (2, 4, 8)


def _mk_packed(key, n, k, bits):
    w = jax.random.normal(key, (n, k), jnp.float32)
    alpha = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    q, scale = qz.quantize_weight_int(w, alpha, bits)
    f = qz.pack_factor(bits)
    if k % f:                          # zero-pad K to the pack factor, as
        q = jnp.pad(q, ((0, 0), (0, f - k % f)))   # from_assignment does
    return qz.pack_int(q, bits), scale[:, 0], w


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("m,k,n", [
    (8, 32, 16),          # tiny, unaligned-ish
    (64, 256, 192),       # mid
    (128, 512, 128),      # exactly one tile
    (100, 384, 130),      # pad in every dim
])
def test_quant_matmul_matches_ref(bits, m, k, n):
    key = jax.random.PRNGKey(bits * 1000 + m + n)
    packed, scale, _ = _mk_packed(key, n, k, bits)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k), jnp.float32)
    # pre-round x to bf16 so kernel (bf16 inputs, f32 accum) and the f32
    # oracle see bit-identical inputs; int weights <= 127 are bf16-exact
    x = x.astype(jnp.bfloat16).astype(jnp.float32)
    y = ops.quant_matmul(x, packed, scale, bits, k,
                         out_dtype=jnp.float32)
    y_ref = ref.quant_matmul_ref(x, packed, scale, bits, k)
    err = np.abs(np.asarray(y, np.float32) - np.asarray(y_ref, np.float32))
    # identical inputs; only f32 accumulation order differs (chunked K loop)
    assert err.max() <= 1e-4 * np.abs(np.asarray(y_ref)).max()


@pytest.mark.parametrize("bits", BITS)
def test_quant_matmul_batched_leading_dims(bits):
    key = jax.random.PRNGKey(7)
    packed, scale, _ = _mk_packed(key, 64, 128, bits)
    x = jax.random.normal(key, (2, 3, 128), jnp.float32)
    x = x.astype(jnp.bfloat16).astype(jnp.float32)
    y = ops.quant_matmul(x, packed, scale, bits, 128,
                         out_dtype=jnp.float32)
    assert y.shape == (2, 3, 64)
    y_ref = ref.quant_matmul_ref(x, packed, scale, bits, 128)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_out_dtype(out_dtype):
    key = jax.random.PRNGKey(3)
    packed, scale, _ = _mk_packed(key, 32, 64, 4)
    x = jax.random.normal(key, (16, 64), jnp.float32)
    y = ops.quant_matmul(x, packed, scale, 4, 64, out_dtype=out_dtype)
    assert y.dtype == out_dtype


@pytest.mark.parametrize("n,k", [(16, 32), (256, 512), (200, 300), (8, 128)])
def test_fused_mix_matches_ref(n, k):
    key = jax.random.PRNGKey(n + k)
    w = jax.random.normal(key, (n, k), jnp.float32)
    gamma_hat = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 1), (n, 3)), axis=-1)
    alpha = jnp.max(jnp.abs(w), axis=-1)
    y = ops.fused_mix(w, gamma_hat, alpha)
    y_ref = ref.fused_mix_ref(w, gamma_hat, alpha)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_mix_onehot_equals_single_fq():
    """One-hot gamma through the kernel == plain fake-quant at that bits."""
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    alpha = jnp.max(jnp.abs(w), axis=-1)
    for i, bits in enumerate(BITS):
        gh = jnp.zeros((32, 3)).at[:, i].set(1.0)
        y = ops.fused_mix(w, gh, alpha)
        exp = qz.quantize_weight(w, alpha[:, None], bits)
        np.testing.assert_allclose(np.asarray(y), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("m,k,n", [
    (1, 64, 32),          # M=1: a single pixel/row
    (8, 128, 1),          # one-channel precision group (N=1)
    (5, 3, 7),            # K < pack factor (bits=2: f=4), nothing aligned
    (16, 100, 30),        # K and N not multiples of any tile size
    (3, 33, 130),         # c_in % pack factor != 0 AND N > one tile
])
def test_quant_matmul_edge_shapes(bits, m, k, n):
    """Off-happy-path shapes: padding/tile-selection must stay exact."""
    key = jax.random.PRNGKey(bits * 7919 + m * 31 + k * 7 + n)
    packed, scale, _ = _mk_packed(key, n, k, bits)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k), jnp.float32)
    x = x.astype(jnp.bfloat16).astype(jnp.float32)
    y = ops.quant_matmul(x, packed, scale, bits, k, out_dtype=jnp.float32)
    assert y.shape == (m, n)
    y_ref = ref.quant_matmul_ref(x, packed, scale, bits, k)
    err = np.abs(np.asarray(y, np.float32) - np.asarray(y_ref, np.float32))
    assert err.max() <= 1e-4 * max(1.0, np.abs(np.asarray(y_ref)).max())


@pytest.mark.parametrize("bits", (2, 4))
def test_quant_matmul_cin_not_multiple_of_pack_factor(bits):
    """Regression for the K-padding path (ops.py): c_in % pack_factor != 0
    means packed K (bytes * f) exceeds c_in and x must be zero-padded to
    exactly that — in full f32 so the comparison is tight."""
    k = 33                               # f=4 -> Kp=36; f=2 -> Kp=34
    assert k % qz.pack_factor(bits)
    key = jax.random.PRNGKey(bits)
    packed, scale, _ = _mk_packed(key, 24, k, bits)
    assert packed.shape[1] * qz.pack_factor(bits) > k
    x = jax.random.normal(jax.random.fold_in(key, 1), (6, k), jnp.float32)
    y = ops.quant_matmul(x, packed, scale, bits, k, out_dtype=jnp.float32,
                         compute_dtype=jnp.float32)
    y_ref = ref.quant_matmul_ref(x, packed, scale, bits, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def _mk_packed_conv(key, cout, cin, kh, kw, bits):
    w = jax.random.normal(key, (cout, cin, kh, kw), jnp.float32)
    w2 = w.reshape(cout, -1)
    alpha = jnp.max(jnp.abs(w2), axis=-1, keepdims=True)
    q, scale = qz.quantize_weight_int(w2, alpha, bits)
    f = qz.pack_factor(bits)
    k = w2.shape[-1]
    if k % f:
        q = jnp.pad(q, ((0, 0), (0, f - k % f)))
    dense = (q[:, :k].astype(jnp.float32) * scale).reshape(w.shape)
    return qz.pack_int(q, bits), scale[:, 0], dense


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("shape,stride,padding", [
    ((2, 9, 7, 5, 16, 3, 3), 1, "SAME"),    # c_in*kh*kw=45: % f != 0
    ((2, 8, 8, 4, 10, 3, 3), 2, "VALID"),
    ((1, 10, 4, 1, 8, 10, 4), 2, "SAME"),   # rect kernel, 1-channel input
    ((1, 3, 3, 2, 1, 3, 3), 1, "VALID"),    # M=1 (single output pixel), N=1
])
def test_quant_conv2d_matches_dense_conv(bits, shape, stride, padding):
    """ops.quant_conv2d (one precision group) == dense lax conv oracle."""
    n, h, w_, cin, cout, kh, kw = shape
    key = jax.random.PRNGKey(bits * 100 + cout)
    packed, scale, dense = _mk_packed_conv(key, cout, cin, kh, kw, bits)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, h, w_, cin),
                          jnp.float32)
    y = ops.quant_conv2d(x, packed, scale, bits, cin * kh * kw, (kh, kw),
                         stride=stride, padding=padding,
                         out_dtype=jnp.float32, compute_dtype=jnp.float32)
    kernel = jnp.transpose(dense, (2, 3, 1, 0))
    y_ref = jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_quant_matmul_rejects_mismatched_c_in():
    """The kernel wrapper must reject (not silently zero-pad) inputs whose
    contraction dim disagrees with c_in, and packed buffers whose byte count
    cannot correspond to c_in at the given bit-width."""
    key = jax.random.PRNGKey(0)
    packed, scale, _ = _mk_packed(key, 8, 32, 4)
    x_short = jax.random.normal(key, (4, 24), jnp.float32)
    with pytest.raises(ValueError, match="contraction"):
        ops.quant_matmul(x_short, packed, scale, 4, 32)
    with pytest.raises(ValueError, match="correspond"):
        # c_in=24 would need ceil(24/2)=12 packed bytes, not 16
        ops.quant_matmul(x_short, packed, scale, 4, 24)


def test_im2col_feature_order_is_channel_major():
    """Load-bearing layout contract: patch feature c*kh*kw + i*kw + j is
    channel c at tap (i, j) — identical to (c_out, c_in, kh, kw) flattening,
    so patches contract against packed QTensor groups with no reorder."""
    from repro.kernels import quant_conv as qc
    x = jnp.arange(1 * 4 * 4 * 3, dtype=jnp.float32).reshape(1, 4, 4, 3)
    p = qc.im2col(x, 2, 2, 1, "VALID")
    assert p.shape == (1, 3, 3, 12)
    # feature block [c*4:(c+1)*4] at output (0,0) = channel c's 2x2 window
    for c in range(3):
        np.testing.assert_array_equal(
            np.asarray(p[0, 0, 0, c * 4:(c + 1) * 4]),
            np.asarray(x[0, :2, :2, c]).reshape(-1))


def test_quant_matmul_zero_weight_rows():
    """All-zero packed weights -> exactly zero output (scale irrelevant)."""
    packed = jnp.zeros((16, 32), jnp.uint8)
    scale = jnp.ones((16,), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    y = ops.quant_matmul(x, packed, scale, 2, 128,
                         out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), 0.0)
