"""Paper Fig. 3 reproduction: accuracy-vs-cost Pareto fronts on the MLPerf
Tiny tasks — ours (channel-wise) vs EdMIPS (layer-wise) vs fixed precision.

Synthetic class-conditional data stands in for the MLPerf datasets (offline
container), so absolute scores differ from the paper; the *comparisons* the
paper makes — channel-wise Pareto-dominating layer-wise at iso-accuracy, and
both dominating fixed precision — are what this benchmark measures.

Run:  PYTHONPATH=src python -m benchmarks.pareto [--task dae-ad] [--fast]
Output: CSV rows  task,method,lambda,metric,size_bits,energy
        plus machine-readable BENCH_pareto.json (same records as the CSV,
        keyed by sweep name — the Pareto analog of BENCH_smoke.json)

`--kv-cache` runs the serving-side analog instead: the channel-wise
bit-assignment applied to the KV cache (`kv_bits` policies vs the int8
baseline), reporting token agreement against cache bytes.  It also emits
one row per device-mesh size (mesh1x1, and mesh2x4 when >= 8 devices are
visible): the mesh engine must sit at exact parity with the meshless
baseline — placement is not allowed to move the front.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PrecisionPolicy
from repro.core import edmips, mixedprec as mp, regularizers as reg, search
from repro.data import pipeline as pipe
from repro.models import tinyml


def eval_metric(cfg, apply_fn, params, nas, data,
                policy=PrecisionPolicy.FROZEN):
    scores = []
    for b in data.batches(32, seed=99):
        pred = apply_fn(params, nas, policy, b)
        scores.append(float(tinyml.task_metric(cfg, pred, b)))
    return float(np.mean(scores))


def run_one(task: str, qcfg: mp.MixedPrecConfig, lam: float, objective: str,
            epochs: tuple[int, int, int], n_data: int, seed: int = 0):
    cfg = dataclasses.replace(tinyml.TINY_CONFIGS[task], quant=qcfg)
    init_fn, apply_fn, specs = tinyml.build(cfg)
    params, nas = init_fn(jax.random.PRNGKey(seed))
    data = pipe.SyntheticTiny(cfg, n=n_data, seed=seed)
    settings = search.SearchSettings(
        cfg=qcfg, objective=objective, lam=lam, lut_name="mpic",
        warmup_epochs=epochs[0], search_epochs=epochs[1],
        finetune_epochs=epochs[2])
    res = search.run_search(apply_fn,
                            lambda p, b: tinyml.task_loss(cfg, p, b),
                            specs, params, nas,
                            lambda: data.batches(16, seed=seed), settings)
    metric = eval_metric(cfg, apply_fn, res.params, res.nas, data)
    size = reg.discrete_size_bits(res.nas, specs, qcfg)
    energy = reg.discrete_energy(res.nas, specs, qcfg, "mpic")
    return metric, size, energy


def fixed_baseline(task: str, w_bits: int, x_bits: int,
                   epochs: int, n_data: int, seed: int = 0):
    """wNxM fixed-precision QAT baseline."""
    qcfg = mp.MixedPrecConfig(weight_bits=(w_bits,), act_bits=(x_bits,),
                              search_acts=False, fixed_act_bits=x_bits,
                              per_channel=False)
    cfg = dataclasses.replace(tinyml.TINY_CONFIGS[task], quant=qcfg)
    init_fn, apply_fn, specs = tinyml.build(cfg)
    params, nas = init_fn(jax.random.PRNGKey(seed))
    data = pipe.SyntheticTiny(cfg, n=n_data, seed=seed)
    settings = search.SearchSettings(cfg=qcfg, objective="size", lam=0.0,
                                     warmup_epochs=epochs, search_epochs=0,
                                     finetune_epochs=epochs)
    res = search.run_search(apply_fn,
                            lambda p, b: tinyml.task_loss(cfg, p, b),
                            specs, params, nas,
                            lambda: data.batches(16, seed=seed), settings)
    metric = eval_metric(cfg, apply_fn, res.params, res.nas, data)
    size = sum(s.weights_per_channel * s.c_out * w_bits
               for s in specs.values())
    from repro.core import lut as lut_mod
    lut = np.asarray(lut_mod.get_lut("mpic"))
    bi = {2: 0, 4: 1, 8: 2}
    energy = sum(s.ops * lut[bi[x_bits], bi[w_bits]] for s in specs.values())
    return metric, size, energy


def kv_cache_sweep(fast: bool = False) -> list[str]:
    """Serving-side Pareto: token fidelity vs KV-cache bytes under `kv_bits`.

    The training sweep above trades task metric against weight bits; this
    is the same trade applied to the *cache* (models/kv_quant.py).  Each
    policy serves the identical staggered paged trace as an int8 baseline
    engine (same backend, same seeds) and reports how many generated
    tokens agree with the baseline before first divergence, next to the
    dense and peak-resident cache cost — 8-bit sits at exact parity by
    construction, sub-byte rows trade tokens for bytes.
    """
    from repro.api.scheduler import Request, ServingEngine
    from repro.config import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import serving as msrv

    rows = ["arch,kv_bits,agree_tok,total_tok,first_div,"
            "kv_dense_kB,kv_peak_kB"]
    archs = ["qwen1.5-4b"] if fast else ["qwen1.5-4b", "deepseek-v3-671b"]
    B, P, G = 3, 8, 12
    mts = [10, 3, 6, 4, 8, 5]
    arrivals = [0, 0, 1, 3, 5, 7]
    mesh_shapes = [(1, 1)]
    if len(jax.devices()) >= 8:
        mesh_shapes.append((2, 4))
    for arch in archs:
        cfg = get_config(arch).reduced()
        dp = msrv.init_deployed_model(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, (P,)).astype(np.int32)
                   for _ in mts]

        def run(kv_bits, mesh=None):
            eng = ServingEngine(cfg, dp, backend="jnp", max_slots=B,
                                max_len=P + G, prefill_len=P,
                                kv_bits=kv_bits, mesh=mesh)
            outs = eng.run([Request(p, max_tokens=m)
                            for p, m in zip(prompts, mts)], arrivals)
            return eng, [outs[i].tokens.tolist() for i in range(len(mts))]

        def agreement(base, toks):
            agree, first_div = 0, -1
            for b, t in zip(base, toks):
                n = next((i for i, (x, y) in enumerate(zip(b, t)) if x != y),
                         min(len(b), len(t)))
                agree += n
                if n < len(b) and first_div < 0:
                    first_div = n
            return agree, first_div

        _, base = run(None)
        total = sum(len(t) for t in base)
        for kv_bits in (None, 8, (4, 8), 4, (2, 4, 8), 2):
            eng, toks = run(kv_bits)
            agree, first_div = agreement(base, toks)
            tag = ("int8" if kv_bits is None else
                   "-".join(str(b) for b in kv_bits)
                   if isinstance(kv_bits, tuple) else str(kv_bits))
            rows.append(f"{arch},{tag},{agree},{total},{first_div},"
                        f"{eng.kv_bytes_dense() / 1e3:.2f},"
                        f"{eng.kv_bytes_peak() / 1e3:.2f}")
            print(rows[-1], flush=True)
        # one row per mesh size: the same trace through the mesh serving
        # engine — parity with the meshless baseline is the pinned result
        # (agree == total, first_div == -1), so a CI grep catches any
        # placement rule that starts moving tokens
        for d, m in mesh_shapes:
            eng, toks = run(None, mesh=make_test_mesh(d, m))
            agree, first_div = agreement(base, toks)
            rows.append(f"{arch},int8@mesh{d}x{m},{agree},{total},"
                        f"{first_div},"
                        f"{eng.kv_bytes_dense() / 1e3:.2f},"
                        f"{eng.kv_bytes_peak() / 1e3:.2f}")
            print(rows[-1], flush=True)
    return rows


def _dump_json(sweep: str, rows: list[str],
               path: str = "BENCH_pareto.json") -> None:
    """Machine-readable front, BENCH_smoke.json-style: ``{sweep: records}``
    where each record is the CSV row keyed by the header columns — so the
    per-PR Pareto trajectory diffs in CI instead of living in log text."""
    import json

    def coerce(v):
        for cast in (int, float):
            try:
                return cast(v)
            except ValueError:
                pass
        return v

    header = [c.strip() for c in rows[0].split(",")]
    records = []
    for row in rows[1:]:
        cells = [c.strip() for c in row.split(",")]
        records.append({k: coerce(v) for k, v in zip(header, cells)}
                       if len(cells) == len(header) else row)
    with open(path, "w") as f:
        json.dump({sweep: records}, f, indent=2, sort_keys=True)
    print(f"wrote {path}", flush=True)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--task", default="dae-ad",
                   choices=list(tinyml.TINY_CONFIGS))
    p.add_argument("--objective", default="size",
                   choices=["size", "energy"])
    p.add_argument("--lambdas", default="1e-8,1e-5,1e-4,1e-3")
    p.add_argument("--fast", action="store_true",
                   help="1-epoch phases, small data (CI speed)")
    p.add_argument("--kv-cache", action="store_true",
                   help="sweep serving KV-cache bit policies instead of "
                        "the weight-precision search")
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)

    if args.kv_cache:
        rows = kv_cache_sweep(fast=args.fast)
        _dump_json("kv_cache", rows)
        if args.out:
            with open(args.out, "w") as f:
                f.write("\n".join(rows) + "\n")
        return

    epochs = (1, 2, 1) if args.fast else (2, 6, 2)
    n_data = 96 if args.fast else 512
    lams = [float(x) for x in args.lambdas.split(",")]

    rows = ["task,method,lam,metric,size_bits,energy"]
    for lam in lams:
        m, s, e = run_one(args.task, edmips.channelwise_config(), lam,
                          args.objective, epochs, n_data)
        rows.append(f"{args.task},channelwise,{lam:g},{m:.4f},{s:.0f},{e:.0f}")
        print(rows[-1], flush=True)
        m, s, e = run_one(args.task, edmips.edmips_config(), lam,
                          args.objective, epochs, n_data)
        rows.append(f"{args.task},edmips,{lam:g},{m:.4f},{s:.0f},{e:.0f}")
        print(rows[-1], flush=True)
    for wb in (2, 4, 8):
        m, s, e = fixed_baseline(args.task, wb, 8, epochs[0] + epochs[2],
                                 n_data)
        rows.append(f"{args.task},w{wb}x8,0,{m:.4f},{s:.0f},{e:.0f}")
        print(rows[-1], flush=True)

    _dump_json(f"pareto-{args.task}", rows)
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(rows) + "\n")


if __name__ == "__main__":
    main()
