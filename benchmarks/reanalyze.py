"""Re-derive roofline terms for every dry-run record from the archived HLO
(results/hlo/*.txt.gz) with the CURRENT hlo_costs analyzer — no recompile.

Run:  PYTHONPATH=src python -m benchmarks.reanalyze [--results PATH]
"""
import argparse
import gzip
import json
import os

from repro.launch import hlo_analysis as ha
from repro.launch import hlo_costs


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--results", default="results/dryrun.jsonl")
    args = p.parse_args()

    out = []
    n_re = 0
    with open(args.results) as f:
        for line in f:
            r = json.loads(line)
            hf = r.get("hlo_file")
            if r.get("ok") and not r.get("skipped") and hf \
                    and os.path.exists(hf):
                with gzip.open(hf, "rt") as g:
                    text = g.read()
                costs = hlo_costs.analyze(text)
                terms = {
                    "compute": costs.flops / ha.PEAK_FLOPS_BF16,
                    "memory": costs.mem_bytes / ha.HBM_BW,
                    "collective": costs.coll_bytes / ha.ICI_BW,
                }
                bottleneck = max(terms, key=terms.get)
                r["roofline"] = {
                    "flops": costs.flops, "hbm_bytes": costs.mem_bytes,
                    "collective_bytes": costs.coll_bytes,
                    "compute_s": terms["compute"],
                    "memory_s": terms["memory"],
                    "collective_s": terms["collective"],
                    "bottleneck": bottleneck,
                    "collective_counts": dict(costs.coll_by_op),
                }
                r["unknown_trip_counts"] = costs.unknown_trip_counts
                chips = r.get("n_chips",
                              512 if r["mesh"] == "2x16x16" else 256)
                if costs.flops and r.get("model_flops"):
                    r["useful_flops_ratio"] = (
                        r["model_flops"] / (costs.flops * chips))
                n_re += 1
            out.append(r)
    with open(args.results, "w") as f:
        for r in out:
            f.write(json.dumps(r) + "\n")
    print(f"re-analyzed {n_re}/{len(out)} records")


if __name__ == "__main__":
    main()
