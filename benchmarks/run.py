"""Benchmark driver — one section per paper table/figure + system benches.

  pareto     — Fig. 3 analogue (channel-wise vs EdMIPS vs fixed), fast mode
  deploy     — Sec. III-C/Table-like: deployed model memory at several
               assignments vs fixed precision (the paper's memory axis)
  kernels    — quant_matmul / fused_mix microbenchmarks (CPU interpret mode:
               numbers are correctness-path timings, not TPU perf — TPU perf
               comes from the §Roofline dry-run terms)
  serving    — reduced-config prefill/decode throughput (jnp backend)
  roofline   — summary table from results/dryrun.jsonl if present

``python -m benchmarks.run`` runs everything in fast mode and prints CSV.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        out = jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n, out


def bench_pareto(smoke: bool = False) -> list[str]:
    from benchmarks import pareto
    print("# pareto (fast): task,method,lam,metric,size_bits,energy",
          flush=True)
    pareto.main(["--task", "dae-ad", "--fast", "--lambdas", "1e-8,1e-4"])
    return []


def bench_deploy(smoke: bool = False) -> list[str]:
    """Deployed memory per assignment — the paper's model-size axis."""
    from repro.config import get_config
    from repro.core import deploy as dpl, mixedprec as mp
    rows = ["deploy_memory:assignment,total_Mbit,vs_w8"]
    cfg = get_config("qwen1.5-4b").reduced()
    rng = np.random.default_rng(0)
    c_out, c_in, n_layers = 128, 256, 10
    w = rng.standard_normal((c_out, c_in)).astype(np.float32)
    alpha = np.abs(w).max(-1)
    base = None
    for name, gamma_fn in [
        ("all-8b", lambda: np.tile([0, 0, 9.0], (c_out, 1))),
        ("all-4b", lambda: np.tile([0, 9.0, 0], (c_out, 1))),
        ("fig4-mix (25/55/20)", lambda: np.asarray(
            [[9.0, 0, 0]] * 32 + [[0, 9.0, 0]] * 70 + [[0, 0, 9.0]] * 26)),
    ]:
        d = dpl.deploy_linear(w, gamma_fn(), alpha, None, 6.0,
                              mp.MixedPrecConfig(), align=1)
        bits = dpl.memory_bits(d) * n_layers
        if base is None:
            base = bits
        rows.append(f"deploy_memory:{name},{bits / 1e6:.3f},"
                    f"{bits / base:.3f}")
    return rows


def bench_kernels(smoke: bool = False) -> list[str]:
    from repro.core import quantizers as qz
    from repro.kernels import ops
    rows = ["kernel:name,bits,M,K,N,us_per_call"]
    key = jax.random.PRNGKey(0)
    M, K, N = 64, 512, 256
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(key, (N, K))
    alpha = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    for bits in (2, 4, 8):
        q, scale = qz.quantize_weight_int(w, alpha, bits)
        packed = qz.pack_int(q, bits)
        dt, _ = _time(lambda: ops.quant_matmul(x, packed, scale[:, 0],
                                               bits, K))
        rows.append(f"kernel:quant_matmul,{bits},{M},{K},{N},{dt * 1e6:.0f}")
    gh = jax.nn.softmax(jax.random.normal(key, (N, 3)), -1)
    dt, _ = _time(lambda: ops.fused_mix(w, gh, alpha[:, 0]))
    rows.append(f"kernel:fused_mix,-,{N},{K},-,{dt * 1e6:.0f}")
    return rows


def bench_tinyml(smoke: bool = False) -> list[str]:
    """Deployed MLPerf-Tiny forward, fully packed, per serving backend.

    Engine.deploy (tile-aligned) -> Engine.serve end-to-end: convs run as
    im2col patch-GEMMs over packed sub-byte groups (QTensor.conv2d),
    depthwise convs through the grouped per-channel path.  ``pallas`` is
    the fused single-launch path (one pallas_call per deployed
    linear/conv), ``pallas-pergroup`` the one-launch-per-precision-group
    reference — the ``launches`` column counts pallas_calls per forward,
    the headline dispatch saving.  CPU-interpret timings are
    correctness-path numbers, not TPU perf.
    """
    from repro.api import Engine, PrecisionPolicy
    from repro.data import pipeline as pipe
    from repro.kernels import ops
    from repro.models import tinyml
    rows = ["tinyml:model,backend,launches,ms_per_batch,packed_kB"]
    names = ("dae-ad",) if smoke else (
        "dae-ad", "resnet8-cifar10", "dscnn-kws", "mobilenetv1-vww")
    for name in names:
        cfg = tinyml.TINY_CONFIGS[name]
        eng = Engine.for_tinyml(cfg, key=jax.random.PRNGKey(0))
        # mixed per-channel groups without paying for a search
        eng.randomize_nas(0)
        eng.deploy(align=1)
        batch = next(iter(pipe.SyntheticTiny(cfg, n=8, seed=0).batches(4)))
        kb = eng.memory_bits() / 8e3
        counts = {}
        for backend in ("jnp", "pallas-pergroup", "pallas"):
            pol = PrecisionPolicy.deployed(backend)
            counts[backend] = ops.count_pallas_launches(
                lambda dp, b: eng.apply_fn(dp, None, pol, b),
                eng.deployed_params, batch)
            dt, _ = _time(lambda: eng.serve(batch, backend=backend),
                          n=3, warmup=1)
            rows.append(f"tinyml:{name},{backend},{counts[backend]},"
                        f"{dt * 1e3:.1f},{kb:.1f}")
        if smoke and not counts["pallas"] < counts["pallas-pergroup"]:
            # smoke gates on the deterministic dispatch count, not on
            # shared-runner wall clock: fused must really be fused
            raise SystemExit(
                f"fused path did not reduce kernel launches on {name}: "
                f"{counts}")
    return rows


def bench_moe_decode(smoke: bool = False) -> list[str]:
    """Small-MoE decode step: weight bytes moved + kernel launches.

    Decode is bandwidth-bound — every step reads every weight once, so the
    bytes column IS the paper's saving on the serving hot path.  PR 4
    routes MoE expert stacks (and MLA decode) through the expert-batched
    fused kernel: ``launches`` counts pallas_calls per decode step (ONE
    per QTensor site under ``pallas``; one per expert x precision group
    under ``pallas-pergroup``), ``packed_kB`` is the sub-byte weight bytes
    a step actually moves, ``dense_kB`` the bf16 stacks the pre-PR4
    ``dq_expert_weights``/``dense_view`` path re-materialized.
    """
    from repro.api.qtensor import QTensor
    from repro.config import get_config
    from repro.kernels import ops
    from repro.models import serving
    rows = ["moe_decode:arch,backend,launches,ms_per_step,packed_kB,dense_kB"]
    cfg = get_config("deepseek-v3-671b").reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    leaves = [t for t in jax.tree_util.tree_leaves(
        dp, is_leaf=lambda t: isinstance(t, QTensor))
        if isinstance(t, QTensor)]
    packed_kb = sum(qt.memory_bits for qt in leaves) / 8e3
    # bf16 dense stacks, layer/expert stacking included
    dense_kb = sum(int(np.prod(qt.packed[0].shape[:-2])) *
                   qt.c_out * qt.c_in * 2 for qt in leaves) / 1e3
    B = 2
    caches = serving.init_caches(cfg, B, 16)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.asarray(8, jnp.int32)
    counts = {}
    for backend in ("jnp", "pallas-pergroup", "pallas"):
        fn = (lambda bk: lambda d, t, c, p:
              serving.decode_step(d, cfg, t, c, p, bk))(backend)
        counts[backend] = ops.count_pallas_launches(fn, dp, tok, caches, pos)
        jfn = jax.jit(fn)
        dt, _ = _time(lambda: jfn(dp, tok, caches, pos)[0], n=3, warmup=1)
        rows.append(f"moe_decode:deepseek-v3-671b.reduced,{backend},"
                    f"{counts[backend]},{dt * 1e3:.1f},{packed_kb:.1f},"
                    f"{dense_kb:.1f}")
    if smoke:
        if not counts["pallas"] < counts["pallas-pergroup"]:
            raise SystemExit("expert-batched fused path did not reduce "
                             f"decode launches: {counts}")
        if not packed_kb < dense_kb:
            raise SystemExit("packed decode bytes not below dense: "
                             f"{packed_kb} vs {dense_kb}")
    return rows


def bench_continuous_batching(smoke: bool = False) -> list[str]:
    """Continuous batching vs lockstep on a staggered-arrival trace.

    The trace has ragged output lengths and staggered arrivals — the
    workload a lockstep wave schedule serves worst (every wave decodes to
    its longest request while finished rows ride along dead).
    ``ServingEngine`` reclaims finished slots and refills them from the
    admission queue without re-jitting, so the same trace takes fewer
    fixed-width launches.  The lockstep baseline is the SAME engine driven
    wave-at-a-time (submit a wave, drain it, repeat — what
    ``launch/serve.py --lockstep`` runs), so the two rows differ only in
    schedule.  ``tok_per_launch`` (useful tokens per device launch,
    prefills included) is the deterministic headline; wall-clock tok/s is
    reported but the smoke gate — like the tinyml/moe_decode sections —
    asserts only on launch/compile counters, never on shared-runner
    timing.  ``recompiles`` counts jit cache growth while serving a second
    trace after warmup: the slot pool must hold it at 0.
    """
    from repro.api.scheduler import Request, ServingEngine
    from repro.config import get_config
    from repro.models import serving
    rows = ["continuous_batching:mode,prefills,decode_steps,useful_tok,"
            "tok_per_launch,tok_per_s,occupancy,recompiles"]
    cfg = get_config("qwen1.5-4b").reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    B, P, G = 4, 8, 20
    max_len = P + G
    rng = np.random.default_rng(0)
    mts = [18, 3, 4, 5, 16, 3, 4, 6, 12, 5]
    arrivals = [0, 0, 0, 0, 1, 3, 5, 7, 9, 11]

    def trace():
        return [Request(rng.integers(0, cfg.vocab_size, (P,)).astype(np.int32),
                        max_tokens=m) for m in mts]

    def engine_run():
        eng = ServingEngine(cfg, dp, backend="jnp", max_slots=B,
                            max_len=max_len, prefill_len=P)
        t0 = time.perf_counter()
        eng.run(trace(), arrivals)
        return eng, time.perf_counter() - t0

    eng, _ = engine_run()                    # warmup: compiles both jits
    warm = eng.compile_counts()
    eng, dt_e = engine_run()                 # steady state: same shapes only
    recompiles = sum(eng.compile_counts().values()) - sum(warm.values())
    st = eng.stats
    launches_e = st["prefill_launches"] + st["decode_launches"]
    occ = st["occupancy_sum"] / max(st["decode_launches"], 1)
    rows.append(
        f"continuous_batching:continuous,{st['prefill_launches']},"
        f"{st['decode_launches']},{st['useful_tokens']},"
        f"{st['useful_tokens'] / launches_e:.2f},"
        f"{st['useful_tokens'] / dt_e:.1f},{occ:.2f},{recompiles}")

    def lockstep_run():
        eng = ServingEngine(cfg, dp, backend="jnp", max_slots=B,
                            max_len=max_len, prefill_len=P)
        reqs = trace()
        t0 = time.perf_counter()
        for w0 in range(0, len(reqs), B):
            for r in reqs[w0:w0 + B]:
                eng.submit(r)
            while eng.has_work():       # the wave barrier: drain fully
                eng.step()
            eng.collect()
        return eng, time.perf_counter() - t0

    lockstep_run()                           # warmup
    eng_l, dt_l = lockstep_run()
    st_l = eng_l.stats
    useful = st_l["useful_tokens"]
    launches_l = st_l["prefill_launches"] + st_l["decode_launches"]
    occ_l = st_l["occupancy_sum"] / max(st_l["decode_launches"], 1)
    rows.append(
        f"continuous_batching:lockstep,{st_l['prefill_launches']},"
        f"{st_l['decode_launches']},{useful},{useful / launches_l:.2f},"
        f"{useful / dt_l:.1f},{occ_l:.2f},-")

    if smoke:
        # deterministic gates: the slot pool must do strictly more useful
        # work per launch than the wave barrier, with zero recompiles
        if not st["useful_tokens"] / launches_e > useful / launches_l:
            raise SystemExit(
                "continuous batching did not beat lockstep tokens/launch: "
                f"{st['useful_tokens']}/{launches_e} vs "
                f"{useful}/{launches_l}")
        if recompiles != 0:
            raise SystemExit(
                f"continuous engine recompiled after warmup: {recompiles}")
    return rows


def bench_paged_cache(smoke: bool = False) -> list[str]:
    """Paged KV cache + radix prefix sharing vs the dense slot rings.

    The trace interleaves 8 requests drawn from 2 distinct prompts, so 6
    admissions find their full prompt prefix already cached: they map the
    shared pages by refcount bump and admit with ZERO prefill launches.
    ``kv_peak_kB`` is the high-water resident KV (pages in use priced in
    bytes) vs the dense ``(max_slots, max_len)`` rings which are resident
    wholesale.  Smoke gates (all deterministic): the paged engine emits
    token-for-token the dense engine's outputs, admits at least one
    request with zero prefill FLOPs, launches strictly fewer prefills,
    keeps peak resident KV strictly below dense, and never recompiles
    after warmup.
    """
    from repro.api.scheduler import Request, ServingEngine
    from repro.config import get_config
    from repro.models import serving
    rows = ["paged_cache:mode,prefills,decode_steps,useful_tok,occupancy,"
            "hit_rate,zero_prefill,cached_tok,kv_peak_kB,kv_dense_kB,"
            "recompiles"]
    cfg = get_config("qwen1.5-4b").reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    SLOTS, P, G, N_REQ = 4, 16, 8, 8
    max_len = P + G                             # auto page_size = 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (P,)).astype(np.int32)
               for _ in range(2)]
    reqs = lambda: [Request(prompts[i % 2], max_tokens=G)
                    for i in range(N_REQ)]
    arrivals = [0, 0, 0, 0, 1, 2, 3, 4]

    def run(page_size):
        eng = ServingEngine(cfg, dp, backend="jnp", max_slots=SLOTS,
                            max_len=max_len, prefill_len=P,
                            page_size=page_size)
        t0 = time.perf_counter()
        outs = eng.run(reqs(), arrivals)
        return eng, outs, time.perf_counter() - t0

    eng_p, outs_p, _ = run("auto")              # warmup compiles both jits
    warm = eng_p.compile_counts()
    eng_p, outs_p, _ = run("auto")              # steady state
    recompiles = sum(eng_p.compile_counts().values()) - sum(warm.values())
    eng_d, outs_d, _ = run(None)

    def fmt(mode, eng, rec):
        st = eng.stats
        occ = st["occupancy_sum"] / max(st["decode_launches"], 1)
        return (f"paged_cache:{mode},{st['prefill_launches']},"
                f"{st['decode_launches']},{st['useful_tokens']},{occ:.2f},"
                f"{st['prefix_hits'] / N_REQ:.2f},"
                f"{st['zero_prefill_admits']},{st['cached_tokens']},"
                f"{eng.kv_bytes_peak() / 1e3:.1f},"
                f"{eng.kv_bytes_dense() / 1e3:.1f},{rec}")

    rows.append(fmt("paged", eng_p, recompiles))
    rows.append(fmt("dense", eng_d, "-"))
    if smoke:
        for i in sorted(outs_d):
            if not np.array_equal(outs_p[i].tokens, outs_d[i].tokens):
                raise SystemExit(
                    f"paged request {i} diverged from the dense engine")
        if eng_p.stats["zero_prefill_admits"] < 1:
            raise SystemExit("no zero-prefill admission on a trace of "
                             "repeated prompts")
        if not eng_p.stats["prefill_launches"] < eng_d.stats[
                "prefill_launches"]:
            raise SystemExit(
                "prefix sharing did not reduce prefill launches: "
                f"{eng_p.stats['prefill_launches']} vs "
                f"{eng_d.stats['prefill_launches']}")
        if not eng_p.kv_bytes_peak() < eng_d.kv_bytes_dense():
            raise SystemExit(
                f"peak resident KV {eng_p.kv_bytes_peak()} not below dense "
                f"{eng_d.kv_bytes_dense()} at equal trace output")
        if recompiles != 0:
            raise SystemExit(
                f"paged engine recompiled after warmup: {recompiles}")
    return rows


def bench_kv_quant(smoke: bool = False) -> list[str]:
    """Channel-wise packed KV cache vs the legacy int8 rings.

    The paper's per-channel bit assignment applied to the cache itself
    (models/kv_quant.py): rings store packed sub-byte channel groups and
    decode attention dequantizes per tile — in VMEM under
    ``backend="pallas"`` (kernels/decode_attention.py).  All variants serve
    the SAME staggered paged trace as an int8 baseline engine on the same
    backend (backends may differ from EACH OTHER in low bf16 bits of the
    linears; within a backend the packed cache must change nothing).  Smoke
    gates (deterministic): 8-bit packed engines (jnp AND fused pallas) are
    token-for-token their backend's int8 engine, zero recompiles after
    warmup, and the 4-bit pool prices strictly below int8 on both the
    dense-ring baseline and the peak resident pages.
    """
    from repro.api.scheduler import Request, ServingEngine
    from repro.config import get_config
    from repro.models import serving
    rows = ["kv_quant:mode,prefills,decode_steps,useful_tok,kv_dense_kB,"
            "kv_peak_kB,match_int8,recompiles"]
    cfg = get_config("qwen1.5-4b").reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    B, P, G = 3, 8, 12
    max_len = P + G                             # auto page_size
    rng = np.random.default_rng(0)
    mts = [10, 3, 6, 4, 8, 5]
    arrivals = [0, 0, 1, 3, 5, 7]
    prompts = [rng.integers(0, cfg.vocab_size, (P,)).astype(np.int32)
               for _ in mts]

    def run(kv_bits, backend):
        eng = ServingEngine(cfg, dp, backend=backend, max_slots=B,
                            max_len=max_len, prefill_len=P, kv_bits=kv_bits)
        outs = eng.run([Request(p, max_tokens=m)
                        for p, m in zip(prompts, mts)], arrivals)
        return eng, [outs[i].tokens.tolist() for i in range(len(mts))]

    base = {bk: run(None, bk)[1] for bk in ("jnp", "pallas")}
    results = {}
    for mode, kv_bits, backend in [("int8", None, "jnp"),
                                   ("packed8-jnp", 8, "jnp"),
                                   ("packed8-pallas", 8, "pallas"),
                                   ("packed4", 4, "jnp"),
                                   ("packed2-4-8", (2, 4, 8), "jnp")]:
        eng, toks = run(kv_bits, backend)      # jits warmed by earlier runs
        warm = eng.compile_counts()
        eng, toks = run(kv_bits, backend)      # steady state
        rec = sum(eng.compile_counts().values()) - sum(warm.values())
        st = eng.stats
        match = toks == base[backend]
        results[mode] = (eng, match, rec)
        rows.append(
            f"kv_quant:{mode},{st['prefill_launches']},"
            f"{st['decode_launches']},{st['useful_tokens']},"
            f"{eng.kv_bytes_dense() / 1e3:.2f},"
            f"{eng.kv_bytes_peak() / 1e3:.2f},{int(match)},{rec}")
    if smoke:
        for mode in ("packed8-jnp", "packed8-pallas"):
            eng, match, rec = results[mode]
            if not match:
                raise SystemExit(f"{mode} diverged from the int8 engine")
            if rec != 0:
                raise SystemExit(f"{mode} recompiled after warmup: {rec}")
        e4, e8 = results["packed4"][0], results["int8"][0]
        if not (e4.kv_bytes_dense() < e8.kv_bytes_dense()
                and e4.kv_bytes_peak() < e8.kv_bytes_peak()):
            raise SystemExit(
                f"4-bit cache not strictly below int8: dense "
                f"{e4.kv_bytes_dense()} vs {e8.kv_bytes_dense()}, peak "
                f"{e4.kv_bytes_peak()} vs {e8.kv_bytes_peak()}")
    return rows


def bench_speculative(smoke: bool = False) -> list[str]:
    """Speculative decoding vs the plain continuous-batching engine.

    Same staggered paged trace, three engines: the non-speculative
    baseline, a self-drafting speculative engine (draft == verifier — the
    degenerate case where greedy acceptance keeps every proposal), and a
    2-bit re-quantized draft (``serving.draft_model`` — the aggressive end
    of the paper's channel-wise Pareto front driving a cheap proposer).
    ``tok_per_vlaunch`` counts useful tokens per VERIFIER-model launch
    (prefills + fallback decode ticks + verifies) — the serving headline
    speculation buys; draft launches are reported separately (they price
    at draft bits, not verifier bits).  Smoke gates (deterministic):
    greedy speculative output is token-for-token the baseline's for BOTH
    drafts, the self-draft accepts all k proposals every round
    (``acc_per_verify`` floor), the speculative engine emits strictly more
    useful tokens per verifier launch than the baseline, and nothing
    recompiles after warmup.
    """
    from repro.api.scheduler import Request, ServingEngine
    from repro.config import get_config
    from repro.models import serving
    rows = ["speculative:mode,prefills,decode_steps,draft_launches,"
            "verify_launches,useful_tok,acc_per_verify,tok_per_vlaunch,"
            "match_base,recompiles"]
    cfg = get_config("qwen1.5-4b").reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    B, P, G, K = 3, 8, 12, 2
    max_len = P + G
    rng = np.random.default_rng(0)
    mts = [10, 3, 6, 4, 8, 5]
    arrivals = [0, 0, 1, 3, 5, 7]
    prompts = [rng.integers(0, cfg.vocab_size, (P,)).astype(np.int32)
               for _ in mts]

    def run(k, draft=None):
        eng = ServingEngine(cfg, dp, backend="jnp", max_slots=B,
                            max_len=max_len, prefill_len=P, speculate_k=k,
                            draft_dparams=draft)
        outs = eng.run([Request(p, max_tokens=m)
                        for p, m in zip(prompts, mts)], arrivals)
        return eng, [outs[i].tokens.tolist() for i in range(len(mts))]

    draft2 = serving.draft_model(dp, cfg, 2)
    base_toks = None
    metrics = {}
    for mode, k, draft in [("baseline", 0, None),
                           ("spec-self-k2", K, None),
                           ("spec-draft2-k2", K, draft2)]:
        eng, _ = run(k, draft)                 # warmup compiles this mode
        warm = eng.compile_counts()
        eng, toks = run(k, draft)              # steady state
        rec = sum(eng.compile_counts().values()) - sum(warm.values())
        st = eng.stats
        if base_toks is None:
            base_toks = toks
        vlaunch = (st["prefill_launches"] + st["decode_launches"]
                   + st["verify_launches"])
        acc = (st["accepted_tokens"] / st["verify_launches"]
               if st["verify_launches"] else 0.0)
        tpv = st["useful_tokens"] / vlaunch
        match = toks == base_toks
        metrics[mode] = (st, tpv, match, rec)
        rows.append(
            f"speculative:{mode},{st['prefill_launches']},"
            f"{st['decode_launches']},{st['draft_launches']},"
            f"{st['verify_launches']},{st['useful_tokens']},{acc:.2f},"
            f"{tpv:.2f},{int(match)},{rec}")
    if smoke:
        for mode in ("spec-self-k2", "spec-draft2-k2"):
            st, tpv, match, rec = metrics[mode]
            if not match:
                raise SystemExit(f"{mode} diverged from the baseline "
                                 "engine under greedy sampling")
            if rec != 0:
                raise SystemExit(f"{mode} recompiled after warmup: {rec}")
        st, tpv, _, _ = metrics["spec-self-k2"]
        if st["accepted_tokens"] < K * st["verify_launches"]:
            raise SystemExit(
                "self-draft did not accept all proposals: "
                f"{st['accepted_tokens']} accepted over "
                f"{st['verify_launches']} verifies at k={K}")
        if not tpv > metrics["baseline"][1]:
            raise SystemExit(
                "speculation did not raise useful tokens per verifier "
                f"launch: {tpv:.2f} vs {metrics['baseline'][1]:.2f}")
    return rows


def bench_qtrain(smoke: bool = False) -> list[str]:
    """int8 vs f32 train_compute on the dae-ad search phase (repro.qtrain).

    Runs the SAME SearchDriver W-step sequence (same init, same batches,
    same optimizer) once per compute mode and reports the loss curve
    agreement plus a step-time / GEMM-bytes-moved row.  ``dev_vs_f32`` is
    ``|final - final_f32| / |first_f32 - final_f32|`` — deviation of the
    int8 endpoint in units of the f32 run's total loss improvement, the
    deterministic-ish headline the smoke gate asserts on (wall-clock
    columns are informational; CPU interpret-mode kernel timings are
    correctness-path numbers, not TPU perf).  ``gemm_kB_per_step`` counts
    operand bytes of the three training matmuls of every searched linear
    (fwd + grad-input + grad-weight) at the mode's operand width — the
    bytes axis int8 training actually moves.
    """
    from repro.core import search as search_mod
    from repro.data import pipeline as pipe
    from repro.models import tinyml
    rows = ["qtrain:train_compute,steps,first_loss,final_loss,dev_vs_f32,"
            "ms_per_step,gemm_kB_per_step"]
    cfg = tinyml.TINY_CONFIGS["dae-ad"]
    init_fn, apply_fn, specs = tinyml.build(cfg)
    params0, nas0 = init_fn(jax.random.PRNGKey(0))
    loss_fn = lambda pred, batch: tinyml.task_loss(cfg, pred, batch)
    B = 16
    steps = 12 if smoke else 40
    # one fixed batch: on synthetic data a fresh batch per step keeps the
    # loss pinned at the data variance; descent on a fixed batch is the
    # signal the two compute modes must agree on
    data = pipe.SyntheticTiny(cfg, n=B * 2, seed=0)
    batch = next(iter(data.batches(B)))
    batches = [batch] * steps

    def gemm_kb(bytes_per_el):
        per_step = sum(
            2 * (B * sp.weights_per_channel + B * sp.c_out
                 + sp.c_out * sp.weights_per_channel)
            for sp in specs.values())
        return per_step * bytes_per_el / 1e3

    results = {}
    for tc in ("f32", "int8"):
        settings = search_mod.SearchSettings(cfg=cfg.quant, train_compute=tc)
        drv = search_mod.SearchDriver(apply_fn, loss_fn, specs,
                                      params0, nas0, settings)
        losses = []
        t0 = time.perf_counter()
        for i, batch in enumerate(batches):
            drv.params, drv._ow, loss = drv._w_step(
                drv.params, drv.nas, drv.tau, drv._ow,
                jnp.asarray(i), batch)
            losses.append(float(loss))
        dt = (time.perf_counter() - t0) / steps
        results[tc] = losses
        drop_f32 = results["f32"][0] - results["f32"][-1]
        dev = abs(losses[-1] - results["f32"][-1]) / max(abs(drop_f32), 1e-9)
        rows.append(f"qtrain:{tc},{steps},{losses[0]:.5f},{losses[-1]:.5f},"
                    f"{dev:.4f},{dt * 1e3:.1f},"
                    f"{gemm_kb(1 if tc == 'int8' else 4):.1f}")
    if smoke:
        f32, i8 = results["f32"], results["int8"]
        if not f32[-1] < f32[0]:
            raise SystemExit(f"f32 search loss did not decrease: {f32}")
        if not i8[-1] < i8[0]:
            raise SystemExit(f"int8 search loss did not decrease: {i8}")
        drop = f32[0] - f32[-1]
        if abs(i8[-1] - f32[-1]) > 0.5 * abs(drop):
            raise SystemExit(
                "int8 final loss deviates from f32 by more than 50% of "
                f"the f32 improvement: {i8[-1]} vs {f32[-1]} (drop {drop})")
    return rows


def bench_serving(smoke: bool = False) -> list[str]:
    from repro.config import get_config
    from repro.models import serving
    rows = ["serving:arch,phase,tok_per_s"]
    for arch in ("qwen1.5-4b", "mamba2-780m"):
        cfg = get_config(arch).reduced()
        dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
        B, S = 4, 64
        batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
        pf = jax.jit(lambda d, b: serving.prefill(d, cfg, b)[0])
        dt, _ = _time(lambda: pf(dp, batch), n=3, warmup=1)
        rows.append(f"serving:{arch},prefill,{B * S / dt:.0f}")
        caches = serving.init_caches(cfg, B, S + 8)
        dec = jax.jit(lambda d, t, c, p: serving.decode_step(cfg=cfg,
                      dparams=d, tokens=t, caches=c, pos=p))
        tok = jnp.zeros((B, 1), jnp.int32)
        dt, out = _time(lambda: dec(dp, tok, caches, jnp.asarray(S))[0],
                        n=5, warmup=1)
        rows.append(f"serving:{arch},decode,{B / dt:.0f}")
    return rows


def bench_mesh_serving(smoke: bool = False) -> list[str]:
    """Mesh-threaded engine vs the single-device engine: same trace, same
    tokens (the PR-9 token-identity contract), plus tok/s per mesh shape.

    On a 1-device host only the trivial (1,1) mesh runs; with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 the 8-way (2,4)
    parity row appears too.  The ``identical`` column is the CI assertion.
    """
    from jax.sharding import Mesh
    from repro.api.scheduler import Request, ServingEngine
    from repro.config import get_config
    from repro.models import serving
    rows = ["mesh_serving:arch,mesh,requests,tokens,tok_per_s,identical"]
    arch = "qwen1.5-4b"
    cfg = get_config(arch).reduced()
    dp = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    reqs = [Request(rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32),
                    max_tokens=m)
            for l, m in zip((8, 6, 7, 5), (10, 3, 6, 4))]
    arrivals = (0, 0, 2, 5)

    def run(mesh):
        eng = ServingEngine(cfg, dp, backend="jnp", max_slots=2, max_len=24,
                            prefill_len=8, mesh=mesh)
        t0 = time.time()
        outs = eng.run(reqs, arrivals)
        dt = time.time() - t0
        toks = {i: np.asarray(outs[i].tokens) for i in range(len(reqs))}
        return toks, sum(len(t) for t in toks.values()) / dt

    base, base_tps = run(None)
    shapes = [(1, 1)]
    if len(jax.devices()) >= 8:
        shapes.append((2, 4))
    for d, m in shapes:
        mesh = Mesh(np.asarray(jax.devices()[:d * m]).reshape(d, m),
                    ("data", "model"))
        toks, tps = run(mesh)
        same = int(all(np.array_equal(base[i], toks[i]) for i in base))
        rows.append(f"mesh_serving:{arch},mesh{d}x{m},{len(reqs)},"
                    f"{sum(len(t) for t in toks.values())},{tps:.1f},{same}")
    return rows


def bench_roofline(smoke: bool = False) -> list[str]:
    import os
    path = "results/dryrun.jsonl"
    if not os.path.exists(path):
        return ["roofline: (results/dryrun.jsonl not present — run "
                "python -m repro.launch.dryrun --all first)"]
    from benchmarks import roofline as rl
    recs = rl.load(path)
    ok = [r for r in recs if r.get("ok") and not r.get("skipped")]
    rows = [f"roofline: {len(ok)} compiled cells in {path}"]
    by_bn = {}
    for r in ok:
        bn = r["roofline"]["bottleneck"]
        by_bn[bn] = by_bn.get(bn, 0) + 1
    rows.append(f"roofline:bottlenecks,{by_bn}")
    return rows


SECTIONS = {
    "deploy": bench_deploy,
    "kernels": bench_kernels,
    "tinyml": bench_tinyml,
    "moe_decode": bench_moe_decode,
    "continuous_batching": bench_continuous_batching,
    "paged_cache": bench_paged_cache,
    "kv_quant": bench_kv_quant,
    "speculative": bench_speculative,
    "qtrain": bench_qtrain,
    "serving": bench_serving,
    "mesh_serving": bench_mesh_serving,
    "roofline": bench_roofline,
    "pareto": bench_pareto,
}


# fast, allocation-light; tinyml runs its dae-ad-only smoke variant so CI
# exercises (and asserts on) the fused single-launch serving path,
# moe_decode asserts the expert-batched fused decode really reduces
# launches and moves sub-byte (not dense) weight bytes, and
# continuous_batching asserts the slot-pooled engine beats the lockstep
# wave barrier on useful tokens per launch with zero post-warmup recompiles,
# and paged_cache asserts prefix sharing really elides prefills and keeps
# peak resident KV below the dense rings at bit-identical trace output,
# and kv_quant asserts the channel-wise packed cache is token-identical to
# int8 at 8 bits (jnp + fused pallas) and strictly cheaper at 4 bits,
# and speculative asserts greedy draft/verify serving is token-identical
# to the baseline engine while emitting strictly more useful tokens per
# verifier launch (self-draft accepts everything; 2-bit draft still exact),
# and qtrain asserts the int8 train_compute search loop tracks the f32 loss
# curve on dae-ad (both decrease; endpoints agree within half the f32 drop)
SMOKE_SECTIONS = ("deploy", "kernels", "tinyml", "moe_decode",
                  "continuous_batching", "paged_cache", "kv_quant",
                  "speculative", "mesh_serving", "qtrain")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default=None, choices=list(SECTIONS))
    p.add_argument("--smoke", action="store_true",
                   help="CI dry-run: fast sections only, fail on empty output")
    args = p.parse_args()
    if args.smoke:
        names = [args.only] if args.only else list(SMOKE_SECTIONS)
    else:
        names = [args.only] if args.only else list(SECTIONS)
    report = {}
    for name in names:
        print(f"\n== {name} ==", flush=True)
        rows = SECTIONS[name](smoke=args.smoke)
        for row in rows:
            print(row, flush=True)
        # sections emit a header row first; smoke requires actual data rows
        if args.smoke and len(rows) <= 1:
            raise SystemExit(f"smoke section {name} produced no data rows")
        report[name] = _parse_rows(rows)
    if args.smoke:
        # machine-readable trajectory: section -> headline metric records,
        # so per-PR perf history is diffable instead of buried in CI logs
        import json
        with open("BENCH_smoke.json", "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print("\nwrote BENCH_smoke.json", flush=True)
        print("SMOKE OK", flush=True)


def _parse_rows(rows: list[str]) -> list:
    """CSV rows ``section:a,b,...`` (header first) -> list of dicts keyed by
    the header columns; non-CSV informational rows pass through verbatim."""
    def split(row):
        body = row.split(":", 1)[1] if ":" in row else row
        return [c.strip() for c in body.split(",")]

    def coerce(v):
        for cast in (int, float):
            try:
                return cast(v)
            except ValueError:
                pass
        return v

    if len(rows) < 2 or ":" not in rows[0]:
        return rows
    header = split(rows[0])
    out = []
    for row in rows[1:]:
        cells = split(row)
        if len(cells) != len(header):
            out.append(row)                    # ragged info row, keep raw
            continue
        out.append({k: coerce(v) for k, v in zip(header, cells)})
    return out


if __name__ == "__main__":
    main()
