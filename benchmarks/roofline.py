import os
if "--relower" in __import__("sys").argv or "--cell" in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline report + per-cell re-lowering for the §Perf hillclimb.

Modes:
  report   (default) — read results/dryrun.jsonl and print the §Roofline
           markdown table: three terms (s), bottleneck, MODEL_FLOPS ratio,
           and a one-line improvement note per cell.
  --cell ARCH/SHAPE [--knob k=v ...] — re-lower one cell with modified
           knobs (remat on/off, fsdp on/off, act-bits, kv-bits, mesh shape)
           and print the before/after terms.  This is the measurement step
           of the hypothesis->change->measure loop recorded in
           EXPERIMENTS.md §Perf.

Run:  PYTHONPATH=src python -m benchmarks.roofline [--results PATH]
"""
import argparse
import json
import sys


def load(path: str) -> list[dict]:
    recs = []
    with open(path) as f:
        for line in f:
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # keep last record per cell
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    return list(seen.values())


IMPROVE_NOTE = {
    "compute": "raise arithmetic intensity: larger per-chip tiles or fewer "
               "redundant (remat) FLOPs",
    "memory": "cut HBM traffic: lower-bit weights (the paper's knob), "
              "fused dequant-matmul, int8 KV, better remat policy",
    "collective": "reshard to cut all-gathers: 2D sharding of the big "
                  "matmuls, overlap collectives with compute, or shrink "
                  "the model axis",
}


def fmt_table(recs: list[dict], mesh: str = "16x16") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "bottleneck | MODEL/HLO flops | note |",
            "|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = [r for r in recs if r.get("mesh", mesh) == mesh]
    for r in sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped | — | {r.get('reason', '')} |")
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"FAILED | — | {r.get('error', '')[:60]} |")
            continue
        ro = r["roofline"]
        ratio = r.get("useful_flops_ratio", 0.0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"{ro['bottleneck']} | {ratio:.2f} | "
            f"{IMPROVE_NOTE[ro['bottleneck']][:58]} |")
    return "\n".join(rows)


def relower_cell(cell: str, knobs: dict) -> dict:
    """Re-lower one cell with knob overrides (hillclimb measurement)."""
    import dataclasses
    import jax
    from repro.config import get_config
    from repro.launch import hlo_analysis as ha
    from repro.launch import workloads as wk
    from repro.launch.mesh import make_production_mesh
    from repro.train import steps as steps_mod

    arch, shape = cell.split("/")
    cfg = get_config(arch)
    cfg_over = {k[4:]: _parse(v) for k, v in knobs.items()
                if k.startswith("cfg.")}
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    if "deploy.fractions" in knobs:
        from repro.config import DeploySpec
        fr = tuple(float(x) for x in knobs["deploy.fractions"].split(","))
        cfg = dataclasses.replace(
            cfg, deploy=dataclasses.replace(cfg.deploy, fractions=fr))
    hp = steps_mod.TrainHParams.for_arch(cfg)
    hp_over = {k[3:]: _parse(v) for k, v in knobs.items()
               if k.startswith("hp.")}
    if hp_over:
        hp = dataclasses.replace(hp, **hp_over)
    mesh = make_production_mesh(multi_pod=knobs.get("mesh") == "multi")
    wl = wk.build(cfg, shape, hp if shape == "train_4k" else None)
    fsdp = knobs.get("fsdp", "1") not in ("0", "false")
    ep2d = knobs.get("ep2d", "0") in ("1", "true")
    kvs = knobs.get("kv_seq_shard", "0") in ("1", "true")
    lowered = wk.lower(wl, mesh, fsdp=fsdp, moe_ep2d=ep2d,
                       kv_seq_shard=kvs)
    compiled = lowered.compile()
    text = compiled.as_text()
    import gzip
    os.makedirs("results/hlo_hillclimb", exist_ok=True)
    tag = "_".join(f"{k}-{v}" for k, v in sorted(knobs.items()))
    fn = f"results/hlo_hillclimb/{cell.replace('/', '_')}_{tag or 'base'}"          f".txt.gz"
    with gzip.open(fn, "wt") as f:
        f.write(text)
    roof = ha.roofline_terms(compiled, text)
    out = roof.as_dict()
    out["hlo_file"] = fn
    try:
        mem = compiled.memory_analysis()
        out["bytes_per_device"] = int(mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes)
    except Exception:
        pass
    return out


def _parse(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            continue
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--results", default="results/dryrun.jsonl")
    p.add_argument("--mesh", default="16x16")
    p.add_argument("--cell", default=None, help="ARCH/SHAPE to re-lower")
    p.add_argument("--knob", action="append", default=[],
                   help="k=v overrides: cfg.*, hp.*, fsdp, mesh")
    args = p.parse_args(argv)

    if args.cell:
        knobs = dict(kv.split("=", 1) for kv in args.knob)
        out = relower_cell(args.cell, knobs)
        print(json.dumps(out, indent=2))
        return

    recs = load(args.results)
    print(fmt_table(recs, args.mesh))


if __name__ == "__main__":
    main()
