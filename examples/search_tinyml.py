"""Paper experiment flow on one MLPerf-Tiny task: channel-wise (ours) vs
EdMIPS (layer-wise) under the identical protocol, one lambda.

This is the per-point unit of Fig. 3; benchmarks/pareto.py sweeps lambda to
trace whole fronts.

Run:  PYTHONPATH=src python examples/search_tinyml.py [task] [lambda]
      task in {resnet8-cifar10, dscnn-kws, mobilenetv1-vww, dae-ad}
"""
import dataclasses
import sys

import jax
import numpy as np

from repro.api import PrecisionPolicy
from repro.core import edmips, regularizers as reg, search
from repro.data import pipeline as pipe
from repro.models import tinyml

task = sys.argv[1] if len(sys.argv) > 1 else "dscnn-kws"
lam = float(sys.argv[2]) if len(sys.argv) > 2 else 1e-5

for method, qcfg in [("channel-wise (ours)", edmips.channelwise_config()),
                     ("EdMIPS (layer-wise)", edmips.edmips_config())]:
    cfg = dataclasses.replace(tinyml.TINY_CONFIGS[task], quant=qcfg)
    init_fn, apply_fn, specs = tinyml.build(cfg)
    params, nas = init_fn(jax.random.PRNGKey(0))
    data = pipe.SyntheticTiny(cfg, n=128, seed=0)
    settings = search.SearchSettings(
        cfg=qcfg, objective="energy", lam=lam, lut_name="mpic",
        warmup_epochs=1, search_epochs=3, finetune_epochs=1)
    res = search.run_search(apply_fn,
                            lambda p, b: tinyml.task_loss(cfg, p, b),
                            specs, params, nas, lambda: data.batches(16),
                            settings)
    scores = [float(tinyml.task_metric(
        cfg, apply_fn(res.params, res.nas, PrecisionPolicy.FROZEN, b), b))
        for b in data.batches(32, seed=7)]
    size = reg.discrete_size_bits(res.nas, specs, qcfg)
    energy = reg.discrete_energy(res.nas, specs, qcfg, "mpic")
    print(f"{method:22s} task={task} lam={lam:g} "
          f"metric={np.mean(scores):.4f} size={size / 8e3:.1f}KB "
          f"energy={energy:.3g}")
