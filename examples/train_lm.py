"""End-to-end LM training driver: the paper's search phase on a transformer,
with checkpoint/restart — the production train loop at CPU-runnable scale.

Default is a ~10M-param model for a quick run; ``--preset 100m`` selects a
~100M-param config (slower on CPU; the same config trains for a few hundred
steps comfortably on one TPU host).  Both reuse the qwen1.5 family config,
scaled — every line of the production path (pjit shardings, checkpoint
manager, tau annealing, 20/80 theta/W alternation) is exercised.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 60
      PYTHONPATH=src python examples/train_lm.py --resume   # restart test
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.config import get_config
from repro.data import pipeline as pipe
from repro.dist import sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.train import checkpoint as ck
from repro.train import steps as steps_mod

PRESETS = {
    # (layers, d_model, heads, kv, d_ff, vocab)  ~params
    "10m": (4, 256, 8, 8, 1024, 8192),       # ~10M
    "100m": (12, 768, 12, 12, 3072, 32000),  # ~160M (GPT-2-medium-ish)
}

p = argparse.ArgumentParser()
p.add_argument("--preset", default="10m", choices=list(PRESETS))
p.add_argument("--steps", type=int, default=60)
p.add_argument("--seq", type=int, default=128)
p.add_argument("--batch", type=int, default=8)
p.add_argument("--ckpt", default="/tmp/repro_train_lm")
p.add_argument("--resume", action="store_true")
p.add_argument("--train-compute", default="f32",
               choices=["f32", "bf16", "int8"],
               help="matmul arithmetic of the search steps (int8 = dynamic "
                    "int8 GEMMs with stochastically rounded backward)")
args = p.parse_args()

L, d, H, KV, ff, V = PRESETS[args.preset]
cfg = dataclasses.replace(
    get_config("qwen1.5-4b"), n_layers=L, d_model=d, n_heads=H,
    n_kv_heads=KV, head_dim=d // H, d_ff=ff, vocab_size=V, qkv_bias=True)
hp = steps_mod.TrainHParams.for_arch(cfg, lr=1e-3, lam=1e-10,
                                     total_steps=args.steps,
                                     warmup_steps=5,
                                     train_compute=args.train_compute)
from repro.api.policy import PrecisionPolicy  # noqa: E402
print("resolved policy:",
      steps_mod._train_policy(hp, PrecisionPolicy.search(cfg.quant.tau0),
                              jax.numpy.zeros((), jax.numpy.int32)))

mesh = make_test_mesh()
rules = shd.ShardingRules(mesh)
state = steps_mod.init_train_state(cfg, hp, jax.random.PRNGKey(0))
n_params = sum(x.size for x in jax.tree_util.tree_leaves(state["params"]))
print(f"preset={args.preset}: {n_params / 1e6:.1f}M params "
      f"(incl. PACT clips)")
state = jax.device_put(state, rules.tree_shardings(state))

mgr = ck.CheckpointManager(args.ckpt)
data = pipe.SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
if args.resume:
    restored, step0, meta = mgr.restore_latest(state)
    if restored is not None:
        state = restored
        data.state.step = int(meta["data_step"])
        print(f"resumed at step {step0}")

train = jax.jit(steps_mod.make_train_step(cfg, hp), donate_argnums=(0,))
theta = jax.jit(steps_mod.make_theta_step(cfg, hp, args.seq * args.batch),
                donate_argnums=(0,))

it = iter(data)
losses = []
t0 = time.time()
while int(state["step"]) < args.steps:
    batch = next(it)
    if int(state["step"]) % 5 == 0:
        state, m = theta(state, batch)
    else:
        state, m = train(state, batch)
    losses.append(float(m["loss"]))
    step = int(state["step"])
    if step % 10 == 0:
        state = steps_mod.anneal_epoch(state, cfg)
        dt = (time.time() - t0) / step
        print(f"step {step:4d} loss={np.mean(losses[-10:]):.4f} "
              f"tau={float(state['tau']):.3f} {dt:.2f}s/step", flush=True)
    if step % 25 == 0:
        mgr.save(step, state, meta={"data_step": data.state.step})

mgr.save(int(state["step"]), state,
         meta={"data_step": data.state.step}, block=True)
print(f"final loss {np.mean(losses[-10:]):.4f} "
      f"(start {np.mean(losses[:5]):.4f}); checkpoints in {args.ckpt}")
