"""Quickstart: the paper's pipeline end-to-end in ~a minute on CPU.

1. Build a small FC model (the AD autoencoder family) with channel-wise
   mixed-precision search sites, wrapped in the `repro.api.Engine` facade.
2. Run Alg. 1 (warmup -> DNAS search -> fine-tune) against the Eq. 7
   model-size regularizer.
3. Inspect the learned per-channel bit-widths.
4. Deploy (Sec. III-C): every searched weight becomes a packed `QTensor`,
   then serve the deployed model and verify it computes the same function
   as the frozen (argmax fake-quant) reference.
5. Packed conv forward: a ResNet-8 deploys and serves through the
   im2col patch-GEMM conv path (`QTensor.conv2d` -> Pallas quant_matmul)
   — no dense kernel is materialized (docs/deployed_conv.md).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Engine, PrecisionPolicy
from repro.core import mixedprec as mp
from repro.core import regularizers as reg
from repro.core import search
from repro.data import pipeline as pipe
from repro.models import tinyml

# 1. model + data + engine ----------------------------------------------------
cfg = tinyml.TINY_CONFIGS["dae-ad"]
settings = search.SearchSettings(
    cfg=cfg.quant, objective="size", lam=3e-5,
    warmup_epochs=1, search_epochs=4, finetune_epochs=1)
eng = Engine.for_tinyml(cfg, settings, key=jax.random.PRNGKey(0))
data = pipe.SyntheticTiny(cfg, n=128, seed=0)
epochs = lambda: data.batches(16)

# 2. Alg. 1 via the engine facade --------------------------------------------
eng.search(epochs).finetune(epochs)
print("search history:")
for h in eng.history:
    print("  ", h)

# 3. learned assignment -------------------------------------------------------
print("\nper-channel bit-widths (first FC layer):")
site = sorted(eng.nas)[0]
bits = mp.argmax_weight_bits(eng.nas[site]["gamma"], cfg.quant)
uniq, counts = np.unique(np.asarray(bits), return_counts=True)
print(f"  {site}: " + ", ".join(f"{c} ch @ {b}b"
                                for b, c in zip(uniq, counts)))
specs = eng.specs
size_bits = reg.discrete_size_bits(eng.nas, specs, cfg.quant)
print(f"  total model size: {size_bits / 8e3:.1f} KB "
      f"(all-8b baseline: {sum(s.weights_per_channel * s.c_out for s in specs.values()) / 1e3:.1f} KB)")

# 4. deploy + serve + verify --------------------------------------------------
eng.deploy(align=1)
print(f"\ndeployed model: {eng.memory_bits() / 8e3:.1f} KB packed")
qt = eng.deployed_params[site]["w"]
print("deployed groups: " + ", ".join(
    f"{n} rows @ {b}b" for b, n in sorted(qt.group_sizes.items())))

batch = next(iter(data.batches(16, seed=7)))
served = eng.serve(batch, backend="pallas")         # Pallas quant_matmul path
frozen = eng.apply_fn(eng.params, eng.nas, PrecisionPolicy.FROZEN, batch)
err = float(jnp.max(jnp.abs(served - frozen)))
print(f"\n|served (deployed, Pallas) - frozen reference| max = {err:.2e}")

# 5. packed conv forward: ResNet-8 through the im2col patch-GEMM path -------
conv_cfg = tinyml.TINY_CONFIGS["resnet8-cifar10"]
conv_eng = Engine.for_tinyml(conv_cfg, key=jax.random.PRNGKey(1))
conv_eng.randomize_nas(1)   # mixed per-channel groups without a search
conv_eng.deploy(align=1)
conv_batch = next(iter(pipe.SyntheticTiny(conv_cfg, n=8, seed=1).batches(4)))
conv_served = conv_eng.serve(conv_batch, backend="pallas")
conv_frozen = conv_eng.apply_fn(conv_eng.params, conv_eng.nas,
                                PrecisionPolicy.FROZEN, conv_batch)
conv_err = float(jnp.max(jnp.abs(conv_served - conv_frozen)))
print(f"\nresnet8 packed conv (Pallas, {conv_eng.memory_bits() / 8e3:.1f} KB):"
      f" |served - frozen| max = {conv_err:.2e}")

# the tile-aligned deploy serves every linear/conv as ONE fused
# multi-precision kernel launch (vs one per precision group)
from repro.kernels import ops as kops
for bk in ("pallas", "pallas-pergroup"):
    pol = PrecisionPolicy.deployed(bk)
    n = kops.count_pallas_launches(
        lambda dp, b: conv_eng.apply_fn(dp, None, pol, b),
        conv_eng.deployed_params, conv_batch)
    print(f"  kernel launches per forward [{bk}]: {n}")
