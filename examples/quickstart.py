"""Quickstart: the paper's pipeline end-to-end in ~a minute on CPU.

1. Build a small FC model (the AD autoencoder family) with channel-wise
   mixed-precision search sites.
2. Run Alg. 1 (warmup -> DNAS search -> fine-tune) against the Eq. 7
   model-size regularizer.
3. Inspect the learned per-channel bit-widths.
4. Deploy (Sec. III-C): reorder channels by precision, pack sub-byte,
   and verify the deployed model computes the same function.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import deploy as dpl
from repro.core import mixedprec as mp
from repro.core import regularizers as reg
from repro.core import search
from repro.data import pipeline as pipe
from repro.models import tinyml

# 1. model + data ------------------------------------------------------------
cfg = tinyml.TINY_CONFIGS["dae-ad"]
init_fn, apply_fn, specs = tinyml.build(cfg)
params, nas = init_fn(jax.random.PRNGKey(0))
data = pipe.SyntheticTiny(cfg, n=128, seed=0)

# 2. Alg. 1 ------------------------------------------------------------------
settings = search.SearchSettings(
    cfg=cfg.quant, objective="size", lam=3e-5,
    warmup_epochs=1, search_epochs=4, finetune_epochs=1)
result = search.run_search(
    apply_fn, lambda p, b: tinyml.task_loss(cfg, p, b), specs,
    params, nas, lambda: data.batches(16), settings)
print("search history:")
for h in result.history:
    print("  ", h)

# 3. learned assignment -------------------------------------------------------
print("\nper-channel bit-widths (first FC layer):")
site = sorted(result.nas)[0]
bits = mp.argmax_weight_bits(result.nas[site]["gamma"], cfg.quant)
uniq, counts = np.unique(np.asarray(bits), return_counts=True)
print(f"  {site}: " + ", ".join(f"{c} ch @ {b}b"
                                for b, c in zip(uniq, counts)))
size_bits = reg.discrete_size_bits(result.nas, specs, cfg.quant)
print(f"  total model size: {size_bits / 8e3:.1f} KB "
      f"(all-8b baseline: {sum(s.weights_per_channel * s.c_out for s in specs.values()) / 1e3:.1f} KB)")

# 4. deploy + verify -----------------------------------------------------------
w = np.asarray(result.params[site]["w"])
d = dpl.deploy_linear(w, np.asarray(result.nas[site]["gamma"]),
                      np.asarray(result.params[site]["aw"]), None, 6.0,
                      cfg.quant, align=1)
frozen = mp.frozen_weight(jnp.asarray(w),
                          jnp.asarray(result.nas[site]["gamma"]),
                          jnp.asarray(result.params[site]["aw"]), cfg.quant)
err = np.abs(dpl.dequantize_deployed(d) - np.asarray(frozen)).max()
print(f"\ndeploy transform max |deployed - frozen| = {err:.2e} (lossless)")
print(f"deployed groups: " + ", ".join(
    f"{grp['packed'].shape[0]} rows @ {b}b" for b, grp in
    sorted(d.groups.items())))
