"""Serve a deployed mixed-precision model with batched requests.

Demonstrates the Sec. III-C deployment running as a service: packed
sub-byte weights, per-precision sub-GEMMs, int8 KV caches, continuous
batched decode.  Shows the memory saving of the searched assignment vs an
all-8-bit deployment — the paper's headline number, on the serving path.

Run:  PYTHONPATH=src python examples/serve_mixed_precision.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.api import Request, ServingEngine
from repro.config import DeploySpec, get_config
from repro.models import serving

cfg = get_config("qwen1.5-4b").reduced()

def model_bytes(dp):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(dp))

# searched assignment (Fig. 4-like: 25% @2b, 55% @4b, 20% @8b)
dp_mixed = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
# all-8b deployment of the same family
cfg8 = dataclasses.replace(cfg, deploy=DeploySpec(fractions=(0.0, 0.0, 1.0),
                                                  align=8))
dp_8 = serving.init_deployed_model(cfg8, jax.random.PRNGKey(0))
mb_mixed, mb_8 = model_bytes(dp_mixed), model_bytes(dp_8)
print(f"deployed weights: mixed {mb_mixed / 1e6:.2f} MB vs "
      f"all-8b {mb_8 / 1e6:.2f} MB -> {100 * (1 - mb_mixed / mb_8):.0f}% "
      f"smaller (paper: up to 63% vs layer-wise)")

# request-level serving ------------------------------------------------------
# ragged prompts and output budgets arriving over time, multiplexed onto a
# fixed-width slot pool (continuous batching; docs/serving.md).  The KV
# cache is PAGED by default (page_size="auto"): slots map fixed-size pages
# from a shared pool instead of owning a dense (max_slots, max_len) ring,
# and a radix index shares the pages of repeated prompt prefixes copy-free
# — the last request below repeats the first one's prompt, so its cached
# prefix pages are mapped by refcount bump instead of being recomputed.
# Pass page_size=None for the dense rings (bit-identical tokens).
SLOTS, S, GEN = 4, 48, 24
rng = np.random.default_rng(0)
reqs = [Request(tokens=rng.integers(0, cfg.vocab_size,
                                    (int(rng.integers(S // 2, S + 1)),)
                                    ).astype(np.int32),
                max_tokens=int(rng.integers(GEN // 3, GEN + 1)))
        for _ in range(8)]
reqs[-1] = dataclasses.replace(reqs[-1], tokens=reqs[0].tokens)
arrivals = sorted(int(a) for a in rng.integers(0, 12, len(reqs)))
eng = ServingEngine(cfg, dp_mixed, backend="jnp", max_slots=SLOTS,
                    max_len=S + GEN, prefill_len=S)
t0 = time.time()
outs = eng.run(reqs, arrivals)
dt = time.time() - t0
st = eng.stats
occ = st["occupancy_sum"] / max(st["decode_launches"], 1)
print(f"served {len(outs)} requests / {st['useful_tokens']} tokens in "
      f"{dt:.2f}s ({st['useful_tokens'] / dt:.0f} tok/s incl. compile; "
      f"{st['prefill_launches']} prefills + {st['decode_launches']} decode "
      f"launches, slot occupancy {occ:.2f})")
print(f"paged KV: page_size {eng.page_size}, peak {st['pages_peak']}/"
      f"{eng.pool.capacity} pages resident "
      f"({eng.kv_bytes_peak() / 1e3:.0f} kB vs dense "
      f"{eng.kv_bytes_dense() / 1e3:.0f} kB), {st['prefix_hits']} prefix "
      f"hits / {st['cached_tokens']} prompt tokens served from cache")
print("generated ids (req 0):", outs[0].tokens[:12])
