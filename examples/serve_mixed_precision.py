"""Serve a deployed mixed-precision model with batched requests.

Demonstrates the Sec. III-C deployment running as a service: packed
sub-byte weights, per-precision sub-GEMMs, int8 KV caches, continuous
batched decode.  Shows the memory saving of the searched assignment vs an
all-8-bit deployment — the paper's headline number, on the serving path.

Run:  PYTHONPATH=src python examples/serve_mixed_precision.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DeploySpec, get_config
from repro.models import serving

cfg = get_config("qwen1.5-4b").reduced()

def model_bytes(dp):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(dp))

# searched assignment (Fig. 4-like: 25% @2b, 55% @4b, 20% @8b)
dp_mixed = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
# all-8b deployment of the same family
cfg8 = dataclasses.replace(cfg, deploy=DeploySpec(fractions=(0.0, 0.0, 1.0),
                                                  align=8))
dp_8 = serving.init_deployed_model(cfg8, jax.random.PRNGKey(0))
mb_mixed, mb_8 = model_bytes(dp_mixed), model_bytes(dp_8)
print(f"deployed weights: mixed {mb_mixed / 1e6:.2f} MB vs "
      f"all-8b {mb_8 / 1e6:.2f} MB -> {100 * (1 - mb_mixed / mb_8):.0f}% "
      f"smaller (paper: up to 63% vs layer-wise)")

# batched serving ------------------------------------------------------------
B, S, GEN = 8, 48, 24
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
prefill = jax.jit(lambda d, b: serving.prefill(d, cfg, b))
decode = jax.jit(lambda d, t, c, p: serving.decode_step(d, cfg, t, c, p),
                 donate_argnums=(2,))

logits, _ = prefill(dp_mixed, batch)
caches = serving.init_caches(cfg, B, S + GEN)
tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
t0 = time.time()
outs = [tok]
for i in range(GEN):
    logits, caches = decode(dp_mixed, tok, caches,
                            jnp.asarray(S + i, jnp.int32))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    outs.append(tok)
jax.block_until_ready(tok)
dt = time.time() - t0
print(f"decoded {GEN} steps x {B} requests in {dt:.2f}s "
      f"({GEN * B / dt:.0f} tok/s)")
print("generated ids (req 0):", np.asarray(jnp.concatenate(outs, 1))[0][:12])
