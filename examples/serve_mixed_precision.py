"""Serve a deployed mixed-precision model with batched requests.

Demonstrates the Sec. III-C deployment running as a service: packed
sub-byte weights, per-precision sub-GEMMs, int8 KV caches, continuous
batched decode.  Shows the memory saving of the searched assignment vs an
all-8-bit deployment — the paper's headline number, on the serving path.

Run:  PYTHONPATH=src python examples/serve_mixed_precision.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engine import ServingSession
from repro.config import DeploySpec, get_config
from repro.models import serving

cfg = get_config("qwen1.5-4b").reduced()

def model_bytes(dp):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(dp))

# searched assignment (Fig. 4-like: 25% @2b, 55% @4b, 20% @8b)
dp_mixed = serving.init_deployed_model(cfg, jax.random.PRNGKey(0))
# all-8b deployment of the same family
cfg8 = dataclasses.replace(cfg, deploy=DeploySpec(fractions=(0.0, 0.0, 1.0),
                                                  align=8))
dp_8 = serving.init_deployed_model(cfg8, jax.random.PRNGKey(0))
mb_mixed, mb_8 = model_bytes(dp_mixed), model_bytes(dp_8)
print(f"deployed weights: mixed {mb_mixed / 1e6:.2f} MB vs "
      f"all-8b {mb_8 / 1e6:.2f} MB -> {100 * (1 - mb_mixed / mb_8):.0f}% "
      f"smaller (paper: up to 63% vs layer-wise)")

# batched serving ------------------------------------------------------------
B, S, GEN = 8, 48, 24
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
sess = ServingSession(cfg, dp_mixed, backend="jnp")
t0 = time.time()
gen_ids, _ = sess.generate(batch, gen=GEN, max_len=S + GEN)
jax.block_until_ready(gen_ids)
dt = time.time() - t0
print(f"decoded {GEN} steps x {B} requests in {dt:.2f}s "
      f"({GEN * B / dt:.0f} tok/s, incl. prefill + compile)")
print("generated ids (req 0):", np.asarray(gen_ids)[0][:12])
